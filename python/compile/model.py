"""L2 model: the per-stage batched statistics graph.

Composes the L1 Pallas kernels (moments, quantile grid, edge means) with
XLA-native glue (sort, Pearson from moments) into the single function the
rust runtime executes per stage:

    stage_stats(x, dur, mask, node_onehot) →
        (col, dur_stats, node_sum, node_count, quantiles, pearson)

The function is shape-polymorphic only through the AOT bucket list — see
``aot.py``; rust pads every stage to the smallest bucket that fits.

Build-time only: nothing here is imported at analysis time.
"""

import jax
import jax.numpy as jnp

from .kernels import edge as edge_kernel
from .kernels import quantile as quantile_kernel
from .kernels import ref
from .kernels import stats as stats_kernel

NUM_FEATURES = ref.NUM_FEATURES
GRID_Q = ref.GRID_Q
# Task-axis padding buckets compiled as separate artifacts.
BUCKETS = (128, 512, 2048)
# Max nodes (padded; the paper's cluster has 5 slaves).
MAX_NODES = 8
# Edge window samples per resource (edge_width 3 s at 1 Hz → 4 buckets).
EDGE_W = 4


def _sorted_columns(x, mask):
    """Sort each column ascending with padded rows pushed to the end, then
    replace the +inf padding by each column's max so downstream matmuls stay
    finite. (For q ≤ 1 the interpolation weights never touch rows ≥ n when
    n ≥ 1, so the replacement value is irrelevant — it just must be finite.)
    """
    big = jnp.where(mask[:, None] > 0, x, jnp.inf)
    xs = jnp.sort(big, axis=0)
    finite_max = jnp.max(jnp.where(jnp.isfinite(xs), xs, -jnp.inf), axis=0)
    finite_max = jnp.where(jnp.isfinite(finite_max), finite_max, 0.0)
    return jnp.where(jnp.isfinite(xs), xs, finite_max[None, :])


def build_stage_stats(use_pallas=True, presorted=False):
    """Return the stage_stats function (Pallas or pure-jnp reference path).

    With ``presorted=True`` the function takes an extra ``x_sorted``
    argument (columns ascending, padding replaced by the column max) and
    skips the in-graph sort. §Perf iteration 4: XLA-CPU's generic Sort op
    costs ~4.4 ms at T=2048 — 94% of the artifact — while the rust caller
    sorts the same columns in ~0.25 ms, so the AOT artifact ships the
    presorted variant and the coordinator supplies ``x_sorted``.
    """

    def core(x, x_sorted, dur, mask, node_onehot):
        if use_pallas:
            col, dur_stats, node_sum, node_count = stats_kernel.moments(
                x, dur, mask, node_onehot
            )
        else:
            col, dur_stats, node_sum, node_count = ref.moments_ref(
                x, dur, mask, node_onehot
            )
        n = dur_stats[0, 2]
        if use_pallas:
            quantiles = quantile_kernel.quantile_grid(x_sorted, n)
        else:
            quantiles = ref.quantile_grid_ref(x_sorted, n)
        pearson = ref.pearson_from_moments(col, dur_stats)
        return col, dur_stats, node_sum, node_count, quantiles, pearson

    if presorted:
        return core

    def stage_stats(x, dur, mask, node_onehot):
        return core(x, _sorted_columns(x, mask), dur, mask, node_onehot)

    return stage_stats


def build_edge_means(use_pallas=True):
    """Return the edge_means function (head/tail window reduction)."""

    def edge_means(head, tail):
        if use_pallas:
            return edge_kernel.edge_means(head, tail, EDGE_W)
        return ref.edge_means_ref(head, tail, EDGE_W)

    return edge_means


def example_args(t):
    """ShapeDtypeStructs for lowering at bucket size ``t`` (presorted
    artifact interface: x, x_sorted, dur, mask, node_onehot)."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((t, NUM_FEATURES), f32),  # x
        jax.ShapeDtypeStruct((t, NUM_FEATURES), f32),  # x_sorted
        jax.ShapeDtypeStruct((t,), f32),  # dur
        jax.ShapeDtypeStruct((t,), f32),  # mask
        jax.ShapeDtypeStruct((MAX_NODES, t), f32),  # node_onehot
    )


def edge_example_args(t):
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((t, 3 * EDGE_W), f32),
        jax.ShapeDtypeStruct((t, 3 * EDGE_W), f32),
    )
