"""Pure-jnp oracle for the L1 Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with plain jax.numpy ops. pytest compares kernel vs reference
(`python/tests/test_kernels.py`), and the L2 model is free to swap either
in (`model.build_stage_stats(use_pallas=False)` uses these).

Conventions (shared with the rust side — see
`rust/src/analysis/stats.rs` and `rust/src/runtime/stats_exec.rs`):

- ``x``: f32[T, F] feature matrix, rows past the valid count zeroed.
- ``dur``: f32[T] task durations, same padding.
- ``mask``: f32[T] with 1.0 for valid rows.
- ``node_onehot``: f32[N, T]; column t is the one-hot node of task t
  (all-zero for padded rows).
- Quantiles use numpy's linear-interpolation definition on the fixed grid
  q = i/(Q-1), i in 0..Q.
"""

import jax.numpy as jnp

# Fixed quantile-grid size (keep in sync with rust analysis::stats::GRID_Q).
GRID_Q = 21
# Feature count (rust analysis::features::FeatureKind::COUNT).
NUM_FEATURES = 12


def moments_ref(x, dur, mask, node_onehot):
    """Masked column moments + per-node aggregation.

    Returns:
      col: f32[3, F] — rows are (sum, sum of squares, dot with duration)
      dur_stats: f32[1, 4] — (sum, sumsq, count, 0) of masked durations
      node_sum: f32[N, F]
      node_count: f32[N, 1]
    """
    m = mask[:, None]  # [T, 1]
    xm = x * m
    col_sum = xm.sum(axis=0)
    col_sumsq = (xm * xm).sum(axis=0)
    col_dot = (xm * (dur * mask)[:, None]).sum(axis=0)
    col = jnp.stack([col_sum, col_sumsq, col_dot], axis=0)
    dm = dur * mask
    dur_stats = jnp.array(
        [[0.0, 0.0, 0.0, 0.0]], dtype=x.dtype
    ) + jnp.stack([dm.sum(), (dm * dm).sum(), mask.sum(), 0.0])[None, :]
    node_sum = node_onehot @ xm
    node_count = (node_onehot @ mask)[:, None]
    return col, dur_stats, node_sum, node_count


def quantile_grid_ref(x_sorted, n):
    """Quantile grid over pre-sorted columns.

    ``x_sorted``: f32[T, F], each column ascending with padded entries
    placed at the END (the model sorts ``where(mask, x, +inf)`` and then
    replaces +inf by the column max so the matmul formulation below stays
    finite; entries at index >= n are never touched when n >= 1).

    ``n``: f32[] — valid count.

    Returns f32[GRID_Q, F].
    """
    t = x_sorted.shape[0]
    q = jnp.arange(GRID_Q, dtype=x_sorted.dtype) / (GRID_Q - 1)
    pos = q * jnp.maximum(n - 1.0, 0.0)  # [Q]
    rows = jnp.arange(t, dtype=x_sorted.dtype)  # [T]
    # Linear-interpolation "hat" weights: 1 at pos, sloping to 0 one row away.
    w = jnp.clip(1.0 - jnp.abs(pos[:, None] - rows[None, :]), 0.0, 1.0)  # [Q, T]
    return w @ x_sorted


def edge_means_ref(head, tail, window):
    """Head/tail window means for edge detection (Eq. 6).

    ``head``/``tail``: f32[T, 3*W] — per-task pre-gathered resource samples
    (cpu | disk | net segments of W samples each) before start / after end.
    ``window``: static int W.

    Returns (head_mean, tail_mean): each f32[T, 3].
    """
    t = head.shape[0]
    h = head.reshape(t, 3, window).mean(axis=2)
    ta = tail.reshape(t, 3, window).mean(axis=2)
    return h, ta


def pearson_from_moments(col, dur_stats):
    """Pearson correlation of each feature column with duration, from the
    moment outputs (shared by the reference and the Pallas path — this part
    is plain jnp in the L2 graph either way).

    Returns f32[F].
    """
    n = jnp.maximum(dur_stats[0, 2], 1.0)
    col_mean = col[0] / n
    col_var = jnp.maximum(col[1] / n - col_mean * col_mean, 0.0)
    dur_mean = dur_stats[0, 0] / n
    dur_var = jnp.maximum(dur_stats[0, 1] / n - dur_mean * dur_mean, 0.0)
    cov = col[2] / n - col_mean * dur_mean
    denom = jnp.sqrt(col_var * dur_var)
    rho = jnp.where(denom > 1e-30, cov / jnp.maximum(denom, 1e-30), 0.0)
    return jnp.clip(rho, -1.0, 1.0)
