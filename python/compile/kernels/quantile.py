"""L1 Pallas kernel: quantile grid by interpolation matmul.

The columns arrive pre-sorted (the L2 graph does ``jnp.sort`` — sorting is
an XLA-native op with no Pallas benefit). The kernel evaluates the whole
λ_q grid at once as a single ``(Q, T) @ (T, F)`` matmul against
linear-interpolation *hat weights*:

    pos_q = q · (n − 1)            (numpy's quantile position)
    w[q, t] = clip(1 − |pos_q − t|, 0, 1)

Each weight row has at most two non-zeros (floor/ceil of pos) summing to 1,
so the matmul IS numpy's interpolated quantile — but expressed as a dense
MXU-shaped contraction instead of a dynamic gather, which is exactly the
GPU→TPU rethink the hardware-adaptation guide asks for: gathers are slow on
TPU, matmuls are free.

``n`` (the valid-row count) is a runtime scalar, passed as a (1, 1) array.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

GRID_Q = ref.GRID_Q


def _quantile_kernel(n_ref, xs_ref, out_ref):
    t = xs_ref.shape[0]
    n = n_ref[0, 0]
    dtype = xs_ref.dtype
    q = jax.lax.broadcasted_iota(dtype, (GRID_Q, 1), 0) / (GRID_Q - 1)
    pos = q * jnp.maximum(n - 1.0, 0.0)  # [Q, 1]
    rows = jax.lax.broadcasted_iota(dtype, (1, t), 1)  # [1, T]
    w = jnp.clip(1.0 - jnp.abs(pos - rows), 0.0, 1.0)  # [Q, T]
    out_ref[...] = w @ xs_ref[...]


@functools.partial(jax.jit, static_argnames=())
def quantile_grid(x_sorted, n):
    """Pallas-backed quantile grid; same contract as ``ref.quantile_grid_ref``.

    ``x_sorted``: f32[T, F] column-ascending, padding at the end replaced by
    the column max (finite). ``n``: f32[] valid count.
    """
    t, f = x_sorted.shape
    return pl.pallas_call(
        _quantile_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((t, f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((GRID_Q, f), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((GRID_Q, f), x_sorted.dtype),
        interpret=True,
    )(jnp.asarray(n, x_sorted.dtype).reshape(1, 1), x_sorted)
