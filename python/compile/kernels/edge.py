"""L1 Pallas kernel: edge-detection window means (Eq. 6).

Input rows carry each task's pre-gathered resource samples for the window
*before* task start and *after* task end — three segments of W samples
(cpu | disk | net) per row. The kernel reduces each segment to its mean in
one VMEM pass, tiled along the task axis like ``stats.py``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_T_MAX = 512


def _tile(t):
    # Largest power-of-two tile ≤ TILE_T_MAX that divides the task axis.
    tile = min(TILE_T_MAX, t)
    while t % tile != 0:
        tile //= 2
    return max(tile, 1)


def _edge_kernel(window, head_ref, tail_ref, hout_ref, tout_ref):
    tt = head_ref.shape[0]
    h = head_ref[...].reshape(tt, 3, window)
    t = tail_ref[...].reshape(tt, 3, window)
    hout_ref[...] = h.mean(axis=2)
    tout_ref[...] = t.mean(axis=2)


@functools.partial(jax.jit, static_argnames=("window",))
def edge_means(head, tail, window):
    """Pallas-backed window means; contract of ``ref.edge_means_ref``."""
    t, cw = head.shape
    tile_t = _tile(t)
    assert cw == 3 * window, f"expected 3*{window} columns, got {cw}"
    assert t % tile_t == 0, f"task axis {t} must be a multiple of {tile_t}"
    grid = (t // tile_t,)
    kernel = functools.partial(_edge_kernel, window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_t, cw), lambda i: (i, 0)),
            pl.BlockSpec((tile_t, cw), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_t, 3), lambda i: (i, 0)),
            pl.BlockSpec((tile_t, 3), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, 3), head.dtype),
            jax.ShapeDtypeStruct((t, 3), head.dtype),
        ],
        interpret=True,
    )(head, tail)
