"""L1 Pallas kernel: fused masked moments + per-node aggregation.

One pass over the ``tasks × features`` matrix produces every reduction the
BigRoots rules need (Eq. 5 global thresholds, peer means by node exclusion,
Eq. 8 Pearson numerators):

- column sum / sum-of-squares / dot-with-duration,
- masked duration sum / sumsq / count,
- per-node feature sums (``node_onehot @ x`` — an MXU matmul on real TPU),
- per-node task counts.

TPU shaping: the grid walks the task axis in ``TILE_T``-row blocks; each
block's ``(TILE_T, F)`` tile and its ``(N, TILE_T)`` one-hot slice live in
VMEM, outputs are accumulated in-place across the sequential grid (the
standard Pallas revisiting-output pattern). VMEM per step ≈
TILE_T·(F+N+2)·4 B ≈ 512·22·4 ≈ 45 KiB — far under budget; see DESIGN.md
§Perf for the roofline discussion.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the AOT
artifact ships.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Max tile along the task axis. §Perf iteration 3: 128 → 512 quarters the
# interpret-mode grid steps (each step lowers to a while-loop iteration of
# dynamic-update-slices in the AOT HLO) at t=2048 while keeping the VMEM
# estimate at 512·(F+N+2)·4 B ≈ 45 KiB — far inside a real TPU's ~16 MiB.
TILE_T_MAX = 512


def _tile(t):
    # Largest power-of-two tile ≤ TILE_T_MAX that divides the task axis.
    tile = min(TILE_T_MAX, t)
    while t % tile != 0:
        tile //= 2
    return max(tile, 1)


def _moments_kernel(x_ref, dur_ref, mask_ref, onehot_ref, col_ref, dur_out_ref,
                    node_sum_ref, node_count_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        col_ref[...] = jnp.zeros_like(col_ref)
        dur_out_ref[...] = jnp.zeros_like(dur_out_ref)
        node_sum_ref[...] = jnp.zeros_like(node_sum_ref)
        node_count_ref[...] = jnp.zeros_like(node_count_ref)

    m = mask_ref[...]  # [Tt, 1]
    xm = x_ref[...] * m  # [Tt, F]
    dm = dur_ref[...] * m  # [Tt, 1]
    onehot = onehot_ref[...]  # [N, Tt]

    col_ref[0, :] += xm.sum(axis=0)
    col_ref[1, :] += (xm * xm).sum(axis=0)
    col_ref[2, :] += (xm * dm).sum(axis=0)

    dur_out_ref[0, 0] += dm.sum()
    dur_out_ref[0, 1] += (dm * dm).sum()
    dur_out_ref[0, 2] += m.sum()

    # Per-node aggregation: (N, Tt) @ (Tt, F) → MXU-shaped on real TPU.
    node_sum_ref[...] += onehot @ xm
    node_count_ref[...] += onehot @ m


@functools.partial(jax.jit, static_argnames=())
def moments(x, dur, mask, node_onehot):
    """Pallas-backed masked moments; same contract as ``ref.moments_ref``."""
    t, f = x.shape
    n = node_onehot.shape[0]
    tile_t = _tile(t)
    assert t % tile_t == 0, f"task axis {t} must be a multiple of {tile_t}"
    grid = (t // tile_t,)
    return pl.pallas_call(
        _moments_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_t, f), lambda i: (i, 0)),
            pl.BlockSpec((tile_t, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_t, 1), lambda i: (i, 0)),
            pl.BlockSpec((n, tile_t), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((3, f), lambda i: (0, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
            pl.BlockSpec((n, f), lambda i: (0, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((3, f), x.dtype),
            jax.ShapeDtypeStruct((1, 4), x.dtype),
            jax.ShapeDtypeStruct((n, f), x.dtype),
            jax.ShapeDtypeStruct((n, 1), x.dtype),
        ],
        interpret=True,
    )(x, dur[:, None], mask[:, None], node_onehot)
