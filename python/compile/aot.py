"""AOT compilation: lower the L2 stage-stats graph to HLO **text** for the
rust PJRT runtime.

HLO text — NOT ``lowered.compile()`` or a serialized HloModuleProto — is the
interchange format: jax ≥ 0.5 emits protos with 64-bit instruction ids that
the crate's xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (one per task-axis bucket):

    artifacts/stage_stats_t{T}.hlo.txt
    artifacts/edge_means_t{T}.hlo.txt
    artifacts/manifest.json          — shapes the rust loader validates

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from python/).
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_stage_stats(t: int) -> str:
    # The artifact takes presorted columns (see model.build_stage_stats).
    fn = model.build_stage_stats(use_pallas=True, presorted=True)
    lowered = jax.jit(fn).lower(*model.example_args(t))
    return to_hlo_text(lowered)


def lower_edge_means(t: int) -> str:
    fn = model.build_edge_means(use_pallas=True)
    lowered = jax.jit(fn).lower(*model.edge_example_args(t))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--buckets", default=",".join(str(b) for b in model.BUCKETS))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    buckets = [int(b) for b in args.buckets.split(",") if b]
    manifest = {
        "version": 2,
        "presorted": True,
        "num_features": model.NUM_FEATURES,
        "grid_q": model.GRID_Q,
        "max_nodes": model.MAX_NODES,
        "edge_window": model.EDGE_W,
        "buckets": buckets,
        "outputs": {
            "stage_stats": [
                {"name": "col", "shape": [3, model.NUM_FEATURES]},
                {"name": "dur_stats", "shape": [1, 4]},
                {"name": "node_sum", "shape": [model.MAX_NODES, model.NUM_FEATURES]},
                {"name": "node_count", "shape": [model.MAX_NODES, 1]},
                {"name": "quantiles", "shape": [model.GRID_Q, model.NUM_FEATURES]},
                {"name": "pearson", "shape": [model.NUM_FEATURES]},
            ]
        },
        "artifacts": {},
    }

    for t in buckets:
        path = os.path.join(args.out_dir, f"stage_stats_t{t}.hlo.txt")
        text = lower_stage_stats(t)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][f"stage_stats_t{t}"] = os.path.basename(path)
        print(f"wrote {path} ({len(text)} chars)")

        epath = os.path.join(args.out_dir, f"edge_means_t{t}.hlo.txt")
        etext = lower_edge_means(t)
        with open(epath, "w") as f:
            f.write(etext)
        manifest["artifacts"][f"edge_means_t{t}"] = os.path.basename(epath)
        print(f"wrote {epath} ({len(etext)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
