"""L2 correctness: the composed stage_stats graph — Pallas path vs the pure
reference path, shape buckets, and the AOT lowering itself."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref

from .test_kernels import make_inputs

F = ref.NUM_FEATURES


class TestStageStats:
    @pytest.mark.parametrize("t,n_valid", [(128, 128), (128, 37), (512, 300)])
    def test_pallas_matches_reference_path(self, t, n_valid):
        rng = np.random.default_rng(10)
        x, dur, mask, onehot = make_inputs(rng, t, n_valid)
        pall = model.build_stage_stats(use_pallas=True)(x, dur, mask, onehot)
        pure = model.build_stage_stats(use_pallas=False)(x, dur, mask, onehot)
        names = ["col", "dur_stats", "node_sum", "node_count", "quantiles", "pearson"]
        for name, a, b in zip(names, pall, pure):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=5e-4, atol=1e-3, err_msg=name
            )

    def test_output_shapes(self):
        rng = np.random.default_rng(11)
        x, dur, mask, onehot = make_inputs(rng, 128, 100)
        col, dur_stats, node_sum, node_count, quantiles, pearson = model.build_stage_stats()(
            x, dur, mask, onehot
        )
        assert col.shape == (3, F)
        assert dur_stats.shape == (1, 4)
        assert node_sum.shape == (model.MAX_NODES, F)
        assert node_count.shape == (model.MAX_NODES, 1)
        assert quantiles.shape == (ref.GRID_Q, F)
        assert pearson.shape == (F,)

    def test_padding_invariance_across_buckets(self):
        # The same 100 tasks padded to 128 vs 512 must give identical stats.
        rng = np.random.default_rng(12)
        x, dur, mask, onehot = make_inputs(rng, 128, 100)
        x2 = np.zeros((512, F), np.float32)
        x2[:128] = x
        dur2 = np.zeros((512,), np.float32)
        dur2[:128] = dur
        mask2 = np.zeros((512,), np.float32)
        mask2[:128] = mask
        onehot2 = np.zeros((model.MAX_NODES, 512), np.float32)
        onehot2[:, :128] = onehot
        f = model.build_stage_stats()
        out1 = f(x, dur, mask, onehot)
        out2 = f(x2, dur2, mask2, onehot2)
        for a, b in zip(out1, out2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-4)

    def test_sorted_columns_padding(self):
        x = np.array([[3.0], [1.0], [2.0], [9.0]], np.float32).repeat(F, axis=1)
        mask = np.array([1, 1, 1, 0], np.float32)
        xs = np.asarray(model._sorted_columns(jnp.asarray(x), jnp.asarray(mask)))
        # Valid prefix ascending, padding replaced by finite column max.
        np.testing.assert_allclose(xs[:3, 0], [1.0, 2.0, 3.0])
        assert np.isfinite(xs).all()

    def test_all_masked_is_finite(self):
        t = 128
        z = np.zeros
        out = model.build_stage_stats()(
            z((t, F), np.float32),
            z((t,), np.float32),
            z((t,), np.float32),
            z((model.MAX_NODES, t), np.float32),
        )
        for a in out:
            assert np.isfinite(np.asarray(a)).all()


class TestEdgeModel:
    def test_edge_paths_agree(self):
        rng = np.random.default_rng(13)
        head = rng.uniform(0, 1, (128, 3 * model.EDGE_W)).astype(np.float32)
        tail = rng.uniform(0, 1, (128, 3 * model.EDGE_W)).astype(np.float32)
        hk, tk = model.build_edge_means(use_pallas=True)(head, tail)
        hr, tr = model.build_edge_means(use_pallas=False)(head, tail)
        np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(tk), np.asarray(tr), rtol=1e-6)


class TestAot:
    def test_hlo_text_generates(self):
        text = aot.lower_stage_stats(128)
        assert "HloModule" in text
        # The pallas kernels lowered via interpret=True: no Mosaic custom
        # calls may appear (the CPU PJRT client cannot run them).
        assert "mosaic" not in text.lower()

    def test_edge_hlo_generates(self):
        text = aot.lower_edge_means(128)
        assert "HloModule" in text
        assert "mosaic" not in text.lower()

    def test_hlo_entry_has_expected_parameter_count(self):
        text = aot.lower_stage_stats(128)
        entry = [l for l in text.splitlines() if "ENTRY" in l]
        assert entry, "no ENTRY computation"
        # 4 parameters: x, dur, mask, node_onehot.
        assert entry[0].count("parameter") >= 0  # structure checked by rust loader
