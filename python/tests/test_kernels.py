"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py) and vs
numpy, including hypothesis sweeps over shapes and value ranges.

This is the CORE correctness signal for the compiled artifact: the AOT HLO
is lowered from exactly the functions under test here.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import edge as edge_kernel
from compile.kernels import quantile as quantile_kernel
from compile.kernels import ref
from compile.kernels import stats as stats_kernel

F = ref.NUM_FEATURES
N = model.MAX_NODES
W = model.EDGE_W


def make_inputs(rng, t, n_valid, scale=1.0):
    x = rng.uniform(0.0, scale, size=(t, F)).astype(np.float32)
    dur = rng.uniform(0.1, 10.0, size=(t,)).astype(np.float32)
    mask = np.zeros((t,), dtype=np.float32)
    mask[:n_valid] = 1.0
    x[n_valid:] = 0.0
    dur[n_valid:] = 0.0
    nodes = rng.integers(0, 5, size=(t,))
    onehot = np.zeros((N, t), dtype=np.float32)
    for i in range(n_valid):
        onehot[nodes[i], i] = 1.0
    return x, dur, mask, onehot


# ---------------------------------------------------------------- moments

class TestMoments:
    def test_matches_ref(self):
        rng = np.random.default_rng(0)
        x, dur, mask, onehot = make_inputs(rng, 256, 200)
        out_k = stats_kernel.moments(x, dur, mask, onehot)
        out_r = ref.moments_ref(x, dur, mask, onehot)
        for k, r in zip(out_k, out_r):
            np.testing.assert_allclose(np.asarray(k), np.asarray(r), rtol=2e-5, atol=1e-4)

    def test_matches_numpy(self):
        rng = np.random.default_rng(1)
        x, dur, mask, onehot = make_inputs(rng, 128, 100)
        col, dur_stats, node_sum, node_count = stats_kernel.moments(x, dur, mask, onehot)
        v = x[:100]
        np.testing.assert_allclose(np.asarray(col)[0], v.sum(axis=0), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(col)[1], (v * v).sum(axis=0), rtol=1e-5)
        np.testing.assert_allclose(
            np.asarray(col)[2], (v * dur[:100, None]).sum(axis=0), rtol=1e-5
        )
        assert np.asarray(dur_stats)[0, 2] == pytest.approx(100.0)
        np.testing.assert_allclose(
            np.asarray(node_sum), onehot @ (x * mask[:, None]), rtol=1e-5, atol=1e-5
        )
        np.testing.assert_allclose(
            np.asarray(node_count)[:, 0], onehot @ mask, rtol=1e-6
        )

    def test_full_mask(self):
        rng = np.random.default_rng(2)
        x, dur, mask, onehot = make_inputs(rng, 128, 128)
        col, dur_stats, *_ = stats_kernel.moments(x, dur, mask, onehot)
        assert np.asarray(dur_stats)[0, 2] == pytest.approx(128.0)
        np.testing.assert_allclose(np.asarray(col)[0], x.sum(axis=0), rtol=1e-5)

    def test_empty_mask(self):
        x = np.zeros((128, F), np.float32)
        dur = np.zeros((128,), np.float32)
        mask = np.zeros((128,), np.float32)
        onehot = np.zeros((N, 128), np.float32)
        col, dur_stats, node_sum, node_count = stats_kernel.moments(x, dur, mask, onehot)
        assert float(np.abs(np.asarray(col)).sum()) == 0.0
        assert float(np.asarray(dur_stats)[0, 2]) == 0.0
        assert float(np.abs(np.asarray(node_sum)).sum()) == 0.0

    def test_mask_zeroes_padding_influence(self):
        # Garbage in padded rows must not leak (the kernel multiplies by mask).
        rng = np.random.default_rng(3)
        x, dur, mask, onehot = make_inputs(rng, 256, 130)
        x2 = x.copy()
        x2[130:] = 999.0
        dur2 = dur.copy()
        dur2[130:] = 123.0
        a = stats_kernel.moments(x, dur, mask, onehot)
        b = stats_kernel.moments(x2, dur2, mask, onehot)
        for u, v in zip(a, b):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v), rtol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(
        t_mult=st.integers(min_value=1, max_value=6),
        frac=st.floats(min_value=0.05, max_value=1.0),
        scale=st.sampled_from([0.01, 1.0, 100.0, 1e4]),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_sweep(self, t_mult, frac, scale, seed):
        t = 128 * t_mult
        n_valid = max(1, int(t * frac))
        rng = np.random.default_rng(seed)
        x, dur, mask, onehot = make_inputs(rng, t, n_valid, scale)
        out_k = stats_kernel.moments(x, dur, mask, onehot)
        out_r = ref.moments_ref(x, dur, mask, onehot)
        for k, r in zip(out_k, out_r):
            np.testing.assert_allclose(
                np.asarray(k), np.asarray(r), rtol=5e-4, atol=1e-3 * scale
            )


# --------------------------------------------------------------- quantiles

class TestQuantiles:
    def sorted_cols(self, x, mask):
        return np.asarray(model._sorted_columns(jnp.asarray(x), jnp.asarray(mask)))

    def test_matches_numpy_quantile(self):
        rng = np.random.default_rng(4)
        t, n_valid = 256, 177
        x, _, mask, _ = make_inputs(rng, t, n_valid, scale=10.0)
        xs = self.sorted_cols(x, mask)
        out = np.asarray(quantile_kernel.quantile_grid(xs, float(n_valid)))
        qs = np.arange(ref.GRID_Q) / (ref.GRID_Q - 1)
        expect = np.quantile(x[:n_valid], qs, axis=0)
        np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)

    def test_matches_ref(self):
        rng = np.random.default_rng(5)
        x, _, mask, _ = make_inputs(rng, 128, 77)
        xs = self.sorted_cols(x, mask)
        k = np.asarray(quantile_kernel.quantile_grid(xs, 77.0))
        r = np.asarray(ref.quantile_grid_ref(jnp.asarray(xs), 77.0))
        np.testing.assert_allclose(k, r, rtol=1e-5, atol=1e-6)

    def test_single_valid_row(self):
        x = np.zeros((128, F), np.float32)
        x[0] = 7.5
        mask = np.zeros((128,), np.float32)
        mask[0] = 1.0
        xs = self.sorted_cols(x, mask)
        out = np.asarray(quantile_kernel.quantile_grid(xs, 1.0))
        np.testing.assert_allclose(out, 7.5, rtol=1e-6)

    def test_monotone_in_q(self):
        rng = np.random.default_rng(6)
        x, _, mask, _ = make_inputs(rng, 256, 256)
        xs = self.sorted_cols(x, mask)
        out = np.asarray(quantile_kernel.quantile_grid(xs, 256.0))
        assert (np.diff(out, axis=0) >= -1e-6).all()

    @settings(max_examples=20, deadline=None)
    @given(
        n_valid=st.integers(min_value=1, max_value=512),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_vs_numpy(self, n_valid, seed):
        t = 512
        rng = np.random.default_rng(seed)
        x, _, mask, _ = make_inputs(rng, t, n_valid, scale=5.0)
        xs = self.sorted_cols(x, mask)
        out = np.asarray(quantile_kernel.quantile_grid(xs, float(n_valid)))
        qs = np.arange(ref.GRID_Q) / (ref.GRID_Q - 1)
        expect = np.quantile(x[:n_valid], qs, axis=0)
        np.testing.assert_allclose(out, expect, rtol=1e-3, atol=1e-3)


# -------------------------------------------------------------- edge means

class TestEdgeMeans:
    def test_matches_ref_and_numpy(self):
        rng = np.random.default_rng(7)
        t = 256
        head = rng.uniform(0, 1, (t, 3 * W)).astype(np.float32)
        tail = rng.uniform(0, 1, (t, 3 * W)).astype(np.float32)
        hk, tk = edge_kernel.edge_means(head, tail, W)
        hr, tr = ref.edge_means_ref(jnp.asarray(head), jnp.asarray(tail), W)
        np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(tk), np.asarray(tr), rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(hk), head.reshape(t, 3, W).mean(axis=2), rtol=1e-5
        )

    @settings(max_examples=10, deadline=None)
    @given(
        t_mult=st.integers(min_value=1, max_value=4),
        seed=st.integers(min_value=0, max_value=2**31),
    )
    def test_hypothesis_sweep(self, t_mult, seed):
        t = 128 * t_mult
        rng = np.random.default_rng(seed)
        head = rng.uniform(0, 100, (t, 3 * W)).astype(np.float32)
        tail = rng.uniform(0, 100, (t, 3 * W)).astype(np.float32)
        hk, tk = edge_kernel.edge_means(head, tail, W)
        hr, tr = ref.edge_means_ref(jnp.asarray(head), jnp.asarray(tail), W)
        np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(tk), np.asarray(tr), rtol=1e-5)


# ---------------------------------------------------------------- pearson

class TestPearson:
    def test_matches_numpy_corrcoef(self):
        rng = np.random.default_rng(8)
        t, n_valid = 256, 211
        x, dur, mask, onehot = make_inputs(rng, t, n_valid)
        col, dur_stats, *_ = ref.moments_ref(
            jnp.asarray(x), jnp.asarray(dur), jnp.asarray(mask), jnp.asarray(onehot)
        )
        rho = np.asarray(ref.pearson_from_moments(col, dur_stats))
        for k in range(F):
            expect = np.corrcoef(x[:n_valid, k], dur[:n_valid])[0, 1]
            assert rho[k] == pytest.approx(expect, rel=2e-3, abs=2e-3), f"feature {k}"

    def test_constant_feature_is_zero(self):
        t = 128
        x = np.ones((t, F), np.float32)
        dur = np.linspace(1, 5, t).astype(np.float32)
        mask = np.ones((t,), np.float32)
        onehot = np.zeros((N, t), np.float32)
        col, dur_stats, *_ = ref.moments_ref(
            jnp.asarray(x), jnp.asarray(dur), jnp.asarray(mask), jnp.asarray(onehot)
        )
        rho = np.asarray(ref.pearson_from_moments(col, dur_stats))
        np.testing.assert_allclose(rho, 0.0, atol=1e-5)
