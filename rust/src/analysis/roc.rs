//! Scoring against injected ground truth: confusion matrices, FPR/TPR/ACC
//! (Eq. 9 — with the paper's obvious typos fixed: FPR = FP/(FP+TN),
//! TPR = TP/(TP+FN); see DESIGN.md §Errata), ROC threshold sweeps and AUC
//! (Fig. 8), and the edge-detection ablation metrics (Fig. 9).
//!
//! Ground truth: for each straggler and each feature, the feature is
//! *affected* iff an injection of the matching anomaly kind
//! (CPU↔CPU, disk↔IO, network↔NET) overlapped the task on its node with at
//! least `min_coverage` of the task's duration. Injection experiments are
//! scored over the *resource* features only ([`resource_features`]):
//! framework features have no injection ground truth — a genuine
//! shuffle-skew finding during an AG run is not a false positive of the
//! injected anomaly (this reproduces Table III's BigRoots FP ≈ 0).

use super::bigroots::{BigRootsConfig, StageAnalysis};
use super::features::{FeatureKind, StageFeatures};
use super::pcc::PccConfig;
use super::stats::StageStats;
use crate::trace::JobTrace;
use crate::util::stats::auc;

/// Confusion counts over (straggler, feature) pairs.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Confusion {
    pub tp: usize,
    pub fp: usize,
    pub tn: usize,
    pub fn_: usize,
}

impl Confusion {
    pub fn add(&mut self, other: Confusion) {
        self.tp += other.tp;
        self.fp += other.fp;
        self.tn += other.tn;
        self.fn_ += other.fn_;
    }

    /// FPR = FP / (FP + TN); 0 when undefined.
    pub fn fpr(&self) -> f64 {
        let d = self.fp + self.tn;
        if d == 0 {
            0.0
        } else {
            self.fp as f64 / d as f64
        }
    }

    /// TPR (recall) = TP / (TP + FN); 0 when undefined.
    pub fn tpr(&self) -> f64 {
        let d = self.tp + self.fn_;
        if d == 0 {
            0.0
        } else {
            self.tp as f64 / d as f64
        }
    }

    /// ACC = (TP + TN) / total; 0 when empty.
    pub fn acc(&self) -> f64 {
        let total = self.tp + self.tn + self.fp + self.fn_;
        if total == 0 {
            0.0
        } else {
            (self.tp + self.tn) as f64 / total as f64
        }
    }
}

/// Ground-truth labels for one stage: `labels[row][feature] = affected`.
#[derive(Debug, Clone)]
pub struct GroundTruth {
    pub labels: Vec<[bool; FeatureKind::COUNT]>,
}

/// Build ground truth for a stage from the trace's injection records.
pub fn ground_truth(trace: &JobTrace, sf: &StageFeatures, min_coverage: f64) -> GroundTruth {
    let labels = (0..sf.num_tasks())
        .map(|row| {
            let task = trace
                .tasks
                .iter()
                .find(|t| t.task_id == sf.task_ids[row])
                .expect("stage feature row references unknown task");
            let mut l = [false; FeatureKind::COUNT];
            for inj in &trace.injections {
                let cov = inj.coverage(task);
                if cov >= min_coverage {
                    for &k in &FeatureKind::ALL {
                        if k.matching_anomaly() == Some(inj.kind) {
                            l[k.index()] = true;
                        }
                    }
                }
            }
            l
        })
        .collect();
    GroundTruth { labels }
}

/// The resource features — the population the anomaly-injection
/// experiments score over (Tables III/V, Figures 8/9). Framework features
/// (data skew, GC, …) are excluded from injection scoring: a genuine
/// shuffle-skew root cause found during an AG run is a correct
/// identification, not a false positive of the injected anomaly.
pub fn resource_features() -> [FeatureKind; 3] {
    [FeatureKind::Cpu, FeatureKind::Disk, FeatureKind::Network]
}

/// Score one stage's analysis against ground truth over all features.
pub fn score(analysis: &StageAnalysis, truth: &GroundTruth) -> Confusion {
    score_filtered(analysis, truth, &FeatureKind::ALL)
}

/// Score over a feature subset; the population is (straggler row, feature)
/// pairs restricted to `features`.
pub fn score_filtered(
    analysis: &StageAnalysis,
    truth: &GroundTruth,
    features: &[FeatureKind],
) -> Confusion {
    let mut c = Confusion::default();
    for &row in &analysis.stragglers.rows {
        for &k in features {
            let actual = truth.labels[row][k.index()];
            let predicted = analysis.causes.iter().any(|x| x.row == row && x.kind == k);
            match (predicted, actual) {
                (true, true) => c.tp += 1,
                (true, false) => c.fp += 1,
                (false, true) => c.fn_ += 1,
                (false, false) => c.tn += 1,
            }
        }
    }
    c
}

/// TP/FP per *injected-feature* only (Table III reports the injected kind's
/// hits; other *resource* features flagged without ground truth count as
/// FP). `kind_feature` is the feature matching the injected AG kind.
pub fn score_injected_kind(
    analysis: &StageAnalysis,
    truth: &GroundTruth,
    kind_feature: FeatureKind,
) -> (usize, usize) {
    let mut tp = 0;
    let mut fp = 0;
    let resource = resource_features();
    for c in &analysis.causes {
        if !resource.contains(&c.kind) {
            continue;
        }
        let actual = truth.labels[c.row][c.kind.index()];
        if actual && c.kind == kind_feature {
            tp += 1;
        } else if !actual {
            fp += 1;
        }
    }
    (tp, fp)
}

/// One point of a ROC sweep.
#[derive(Debug, Clone, Copy)]
pub struct RocPoint {
    pub fpr: f64,
    pub tpr: f64,
    pub acc: f64,
    /// The two thresholds that produced this point.
    pub t1: f64,
    pub t2: f64,
}

/// Sweep BigRoots over a (λ_q, λ_p) grid. `stages` pairs each stage's
/// features with its precomputed stats (one stats pass amortized over the
/// whole grid) and ground truth.
pub fn sweep_bigroots(
    stages: &[(&StageFeatures, &StageStats, &GroundTruth)],
    base: &BigRootsConfig,
    lambda_q_grid: &[f64],
    lambda_p_grid: &[f64],
) -> Vec<RocPoint> {
    let mut points = Vec::new();
    for &lq in lambda_q_grid {
        for &lp in lambda_p_grid {
            let cfg = BigRootsConfig { lambda_q: lq, lambda_p: lp, ..*base };
            let mut c = Confusion::default();
            let feats = resource_features();
            for (sf, stats, truth) in stages {
                let a = super::bigroots::analyze_stage_with_stats(sf, stats, &cfg);
                c.add(score_filtered(&a, truth, &feats));
            }
            points.push(RocPoint { fpr: c.fpr(), tpr: c.tpr(), acc: c.acc(), t1: lq, t2: lp });
        }
    }
    points
}

/// Sweep PCC over a (pearson, max-quantile) grid.
pub fn sweep_pcc(
    stages: &[(&StageFeatures, &StageStats, &GroundTruth)],
    base: &PccConfig,
    pearson_grid: &[f64],
    quantile_grid: &[f64],
) -> Vec<RocPoint> {
    let mut points = Vec::new();
    for &pt in pearson_grid {
        for &qt in quantile_grid {
            let cfg = PccConfig { pearson_threshold: pt, max_quantile: qt, ..*base };
            let mut c = Confusion::default();
            let feats = resource_features();
            for (sf, stats, truth) in stages {
                let a = super::pcc::analyze_stage_with_stats(sf, stats, &cfg);
                c.add(score_filtered(&a, truth, &feats));
            }
            points.push(RocPoint { fpr: c.fpr(), tpr: c.tpr(), acc: c.acc(), t1: pt, t2: qt });
        }
    }
    points
}

/// AUC of a sweep's (FPR, TPR) cloud.
pub fn sweep_auc(points: &[RocPoint]) -> f64 {
    auc(&points.iter().map(|p| (p.fpr, p.tpr)).collect::<Vec<_>>())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::bigroots::{analyze_stage, BigRootsConfig};
    use crate::analysis::features::{extract_stage, FeatureKind as F};
    use crate::analysis::stats::{compute_native, NativeBackend};
    use crate::sim::{Engine, InjectionPlan, SimConfig, StageSpec};
    use crate::trace::AnomalyKind;

    fn injected_trace(kind: AnomalyKind) -> crate::trace::JobTrace {
        // A NaiveBayes-like stage: ~60-70% CPU duty cycle (so node CPU is
        // not saturated at baseline and the AG's utilization is visible)
        // plus natural duration variance (so the AG's dilation pushes tail
        // tasks over the 1.5× straggler threshold).
        let mut stage = StageSpec::base("s", 400);
        stage.compute_base = if kind == AnomalyKind::Cpu { 1.5 } else { 0.4 };
        stage.compute_per_byte = 0.0;
        stage.compute_dist = crate::sim::SizeDist::LogNormal { sigma: 0.35 };
        stage.input_mean_bytes = if kind == AnomalyKind::Io { 50e6 } else { 25e6 };
        let mut eng = Engine::new(SimConfig { seed: 21, ..Default::default() });
        let plan = InjectionPlan::intermittent(kind, 1, 15.0, 10.0, 200.0);
        eng.run("j", "t", &[stage], &plan)
    }

    #[test]
    fn confusion_metrics() {
        let c = Confusion { tp: 8, fp: 2, tn: 88, fn_: 2 };
        assert!((c.fpr() - 2.0 / 90.0).abs() < 1e-12);
        assert!((c.tpr() - 0.8).abs() < 1e-12);
        assert!((c.acc() - 0.96).abs() < 1e-12);
        let z = Confusion::default();
        assert_eq!(z.fpr(), 0.0);
        assert_eq!(z.tpr(), 0.0);
        assert_eq!(z.acc(), 0.0);
    }

    #[test]
    fn ground_truth_labels_match_injections() {
        let trace = injected_trace(AnomalyKind::Cpu);
        let sf = extract_stage(&trace, 0, 3.0);
        let gt = ground_truth(&trace, &sf, 0.3);
        // Some task on node 1 overlapping an injection must be labeled CPU.
        let any_cpu = (0..sf.num_tasks())
            .any(|r| sf.nodes[r] == 1 && gt.labels[r][F::Cpu.index()]);
        assert!(any_cpu);
        // No task is labeled for a kind that was never injected.
        for l in &gt.labels {
            assert!(!l[F::Disk.index()]);
            assert!(!l[F::Network.index()]);
            assert!(!l[F::BytesRead.index()]);
        }
        // Tasks on other nodes are never labeled.
        for r in 0..sf.num_tasks() {
            if sf.nodes[r] != 1 {
                assert!(!gt.labels[r][F::Cpu.index()]);
            }
        }
    }

    #[test]
    fn end_to_end_cpu_injection_scores_tp() {
        let trace = injected_trace(AnomalyKind::Cpu);
        let sf = extract_stage(&trace, 0, 3.0);
        let gt = ground_truth(&trace, &sf, 0.3);
        let a = analyze_stage(&sf, &mut NativeBackend::new(), &BigRootsConfig::default());
        assert!(!a.stragglers.rows.is_empty(), "CPU AG must create stragglers");
        let c = score(&a, &gt);
        assert!(c.tp > 0, "BigRoots must find injected CPU causes: {c:?}");
        // BigRoots' design goal: few false positives.
        assert!(c.fp <= c.tp.max(2) * 3, "too many FPs: {c:?}");
    }

    #[test]
    fn sweep_produces_monotone_extremes() {
        let trace = injected_trace(AnomalyKind::Io);
        let sf = extract_stage(&trace, 0, 3.0);
        let stats = compute_native(&sf);
        let gt = ground_truth(&trace, &sf, 0.3);
        let stages = [(&sf, &stats, &gt)];
        let pts = sweep_bigroots(
            &stages,
            &BigRootsConfig::default(),
            &[0.0, 0.5, 0.99],
            &[0.5, 1.5, 10.0],
        );
        assert_eq!(pts.len(), 9);
        // The loosest corner has TPR ≥ the strictest corner.
        let loose = pts.iter().find(|p| p.t1 == 0.0 && p.t2 == 0.5).unwrap();
        let strict = pts.iter().find(|p| p.t1 == 0.99 && p.t2 == 10.0).unwrap();
        assert!(loose.tpr >= strict.tpr);
        assert!(loose.fpr >= strict.fpr);
    }

    #[test]
    fn auc_of_sweep_in_unit_range() {
        let trace = injected_trace(AnomalyKind::Cpu);
        let sf = extract_stage(&trace, 0, 3.0);
        let stats = compute_native(&sf);
        let gt = ground_truth(&trace, &sf, 0.3);
        let stages = [(&sf, &stats, &gt)];
        let grid: Vec<f64> = (0..6).map(|i| i as f64 / 5.0).collect();
        let pts = sweep_bigroots(&stages, &BigRootsConfig::default(), &grid, &[1.0, 1.5, 2.0]);
        let a = sweep_auc(&pts);
        assert!((0.0..=1.0).contains(&a));
        let pcc_pts = sweep_pcc(&stages, &PccConfig::default(), &grid, &grid);
        let a2 = sweep_auc(&pcc_pts);
        assert!((0.0..=1.0).contains(&a2));
    }

    #[test]
    fn score_injected_kind_counts() {
        let trace = injected_trace(AnomalyKind::Cpu);
        let sf = extract_stage(&trace, 0, 3.0);
        let gt = ground_truth(&trace, &sf, 0.3);
        let a = analyze_stage(&sf, &mut NativeBackend::new(), &BigRootsConfig::default());
        let (tp, fp) = score_injected_kind(&a, &gt, F::Cpu);
        let full = score_filtered(&a, &gt, &resource_features());
        assert!(tp <= full.tp);
        assert!(fp == full.fp, "kind-scoped FP equals resource-scoped FP by construction");
    }
}
