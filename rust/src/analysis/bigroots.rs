//! The BigRoots root-cause identification rules — Section III-B.
//!
//! For each straggler and each feature, the feature is a root cause when:
//!
//! - **numerical / resource / time** (Eq. 5):
//!   `F > global_quantile(λ_q)` AND `F > mean(F_peer) · λ_p`, where the
//!   peer test passes against *either* the inter-node or the intra-node
//!   peer group (the paper's two observations are alternatives — intra-node
//!   evidence would be drowned out if the groups were pooled);
//! - **time**: additionally `F > 0.2` (the empirical lower bound — a
//!   blocking time far below task duration cannot explain the straggler);
//! - **resource**: *edge detection* (Eq. 6) — if utilization in the window
//!   before the task starts AND after it finishes stays below
//!   `λ_e · F`, the utilization edge coincides with the task itself, so
//!   the task (not an external hog) caused it → filtered out.
//!   NOTE: the paper's printed Eq. 6 has the inequality pointing the other
//!   way, which contradicts its own prose ("if system resource utilization
//!   raises after task begins and drops after task ends, we will attribute
//!   the resource utilization to the job itself"); we implement the prose
//!   (see DESIGN.md §Errata).
//! - **discrete / locality** (Eq. 7): `F_locality = 2` AND
//!   `sum(F_locality over normal tasks) < num(normal)/2` — the straggler
//!   read remotely while its peers read locally.

use super::features::{FeatureCategory, FeatureKind, StageFeatures};
use super::stats::{StageStats, StatsBackend};
use super::straggler::{detect, StragglerSet};

/// All thresholds of the method (paper defaults; the ROC benches sweep
/// `lambda_q` and `lambda_p`). `PartialEq` lets the flight-recorder replay
/// ([`crate::analysis::explain`]) assert the dumped config round-trips
/// bit-exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BigRootsConfig {
    /// Straggler definition: duration > ratio × stage median.
    pub straggler_ratio: f64,
    /// λ_q — global quantile the feature must exceed (Eq. 5, first line).
    pub lambda_q: f64,
    /// λ_p — peer-mean multiplier (Eq. 5, second line).
    pub lambda_p: f64,
    /// Absolute lower bound for time features (paper: 0.2).
    pub time_lower_bound: f64,
    /// Edge-detection window width t (s).
    pub edge_width: f64,
    /// λ_e — edge filter threshold (Eq. 6).
    pub lambda_e: f64,
    /// Ablation switch (Fig. 9 compares with/without).
    pub use_edge_detection: bool,
    /// Absolute utilization floor for CPU/disk resource features — the
    /// empirical lower bound of Section III applied to resources: an
    /// almost-idle resource (noise blips over near-zero peers) cannot
    /// explain a straggler. Prior straggler studies use 80% [11]; we
    /// default to 0.5 to keep recall under partial overlap.
    pub min_resource_util: f64,
    /// Same floor for the network feature, in bytes per sampling interval
    /// (Eq. 3 is absolute traffic, not a ratio).
    pub min_net_bytes: f64,
}

impl Default for BigRootsConfig {
    fn default() -> Self {
        BigRootsConfig {
            straggler_ratio: 1.5,
            lambda_q: 0.8,
            lambda_p: 1.5,
            time_lower_bound: 0.2,
            edge_width: 3.0,
            lambda_e: 0.6,
            use_edge_detection: true,
            min_resource_util: 0.5,
            min_net_bytes: 20e6,
        }
    }
}

/// Which peer group produced the supporting evidence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeerEvidence {
    InterNode,
    IntraNode,
    Both,
    /// Locality rule (Eq. 7) — no peer-mean comparison involved.
    LocalityVote,
}

impl PeerEvidence {
    /// Stable wire name, used by the verdict provenance traces.
    pub fn as_str(self) -> &'static str {
        match self {
            PeerEvidence::InterNode => "inter_node",
            PeerEvidence::IntraNode => "intra_node",
            PeerEvidence::Both => "both",
            PeerEvidence::LocalityVote => "locality_vote",
        }
    }
}

/// One identified root cause: feature `kind` explains straggler `row`.
#[derive(Debug, Clone, PartialEq)]
pub struct RootCause {
    pub row: usize,
    pub task_id: u64,
    pub kind: FeatureKind,
    /// The feature value of the straggler.
    pub value: f64,
    /// The global quantile threshold it exceeded.
    pub global_threshold: f64,
    pub peer: PeerEvidence,
}

/// Analysis result of one stage. `PartialEq` supports the streaming-vs-
/// batch parity tests: two analyses are equal only when every straggler
/// row, cause, threshold and evidence value matches bit-for-bit.
#[derive(Debug, Clone, PartialEq)]
pub struct StageAnalysis {
    pub stage_id: u64,
    pub stragglers: StragglerSet,
    pub causes: Vec<RootCause>,
}

impl StageAnalysis {
    /// Root causes of a specific straggler row.
    pub fn causes_of(&self, row: usize) -> Vec<&RootCause> {
        self.causes.iter().filter(|c| c.row == row).collect()
    }

    /// Count of identified causes per feature kind.
    pub fn cause_histogram(&self) -> Vec<(FeatureKind, usize)> {
        FeatureKind::ALL
            .iter()
            .map(|&k| (k, self.causes.iter().filter(|c| c.kind == k).count()))
            .filter(|&(_, n)| n > 0)
            .collect()
    }
}

/// Evaluate the peer-deviation test (Eq. 5 second line) for one straggler
/// feature; returns the supporting evidence if it passes.
fn peer_test(
    stats: &StageStats,
    node: usize,
    k: FeatureKind,
    v: f64,
    lambda_p: f64,
) -> Option<PeerEvidence> {
    let inter = stats
        .inter_node_mean(node, k)
        .map(|m| v > m * lambda_p)
        .unwrap_or(false);
    let intra = stats
        .intra_node_mean(node, k, v)
        .map(|m| v > m * lambda_p)
        .unwrap_or(false);
    match (inter, intra) {
        (true, true) => Some(PeerEvidence::Both),
        (true, false) => Some(PeerEvidence::InterNode),
        (false, true) => Some(PeerEvidence::IntraNode),
        (false, false) => None,
    }
}

/// Run the full BigRoots identification on one stage.
pub fn analyze_stage(
    sf: &StageFeatures,
    backend: &mut dyn StatsBackend,
    cfg: &BigRootsConfig,
) -> StageAnalysis {
    let stats = backend.stage_stats(sf);
    analyze_stage_with_stats(sf, &stats, cfg)
}

/// Identification given precomputed stats (lets callers reuse one stats
/// pass for BigRoots + PCC + threshold sweeps).
pub fn analyze_stage_with_stats(
    sf: &StageFeatures,
    stats: &StageStats,
    cfg: &BigRootsConfig,
) -> StageAnalysis {
    let stragglers = detect(sf, cfg.straggler_ratio);
    let mut causes = Vec::new();

    // Eq. 7 precomputation: locality sum over *normal* tasks.
    let loc_col = sf.column(FeatureKind::Locality);
    let normal_count = sf.num_tasks() - stragglers.rows.len();
    let normal_loc_sum: f64 = (0..sf.num_tasks())
        .filter(|r| !stragglers.is_straggler(*r))
        .map(|r| loc_col[r])
        .sum();
    let locality_vote = normal_loc_sum < normal_count as f64 / 2.0;

    for &row in &stragglers.rows {
        let node = sf.nodes[row];
        for &k in &FeatureKind::ALL {
            let v = sf.get(row, k);
            match k.category() {
                FeatureCategory::Discrete => {
                    // Eq. 7: straggler read remotely, peers read locally.
                    if v >= 2.0 && locality_vote && normal_count > 0 {
                        causes.push(RootCause {
                            row,
                            task_id: sf.task_ids[row],
                            kind: k,
                            value: v,
                            global_threshold: 2.0,
                            peer: PeerEvidence::LocalityVote,
                        });
                    }
                }
                cat => {
                    // Eq. 5, first line: global quantile bound.
                    let gq = stats.quantile(k, cfg.lambda_q);
                    if !(v > gq) || v <= 0.0 {
                        continue;
                    }
                    // Time features: absolute lower bound.
                    if cat == FeatureCategory::Time && v <= cfg.time_lower_bound {
                        continue;
                    }
                    // Resource features: absolute utilization floor (see
                    // config docs) — relative tests alone misfire when the
                    // whole stage sits near zero utilization.
                    if cat == FeatureCategory::Resource {
                        let floor = if k == FeatureKind::Network {
                            cfg.min_net_bytes
                        } else {
                            cfg.min_resource_util
                        };
                        if v < floor {
                            continue;
                        }
                    }
                    // Eq. 5, second line: peer deviation (either group).
                    let Some(peer) = peer_test(stats, node, k, v, cfg.lambda_p) else {
                        continue;
                    };
                    // Resource features: edge detection (Eq. 6, prose
                    // semantics — see module docs).
                    if cat == FeatureCategory::Resource && cfg.use_edge_detection {
                        let (head, tail) = sf.edge_means(row, k);
                        let self_inflicted =
                            head < cfg.lambda_e * v && tail < cfg.lambda_e * v;
                        if self_inflicted {
                            continue;
                        }
                    }
                    causes.push(RootCause {
                        row,
                        task_id: sf.task_ids[row],
                        kind: k,
                        value: v,
                        global_threshold: gq,
                        peer,
                    });
                }
            }
        }
    }
    StageAnalysis { stage_id: sf.stage_id, stragglers, causes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::features::FeatureKind as F;
    use crate::analysis::stats::NativeBackend;

    /// Build a stage where row `hot` is a straggler with an elevated value
    /// in column `k`, and everything else is flat.
    fn stage_with_hot(k: F, hot_value: f64, n: usize, hot: usize) -> StageFeatures {
        let f = F::COUNT;
        let mut matrix = vec![0.0; n * f];
        let mut durations = vec![1.0; n];
        durations[hot] = 3.0;
        for r in 0..n {
            matrix[r * f + k.index()] = if r == hot { hot_value } else { 0.1 };
        }
        StageFeatures {
            stage_id: 0,
            task_ids: (0..n as u64).collect(),
            nodes: (0..n).map(|r| r % 4).collect(),
            durations,
            matrix,
            // Head/tail resource means default HIGH so edge detection does
            // NOT filter (external contention persisted around the task).
            head_means: vec![1.0; n * 3],
            tail_means: vec![1.0; n * 3],
        }
    }

    fn run(sf: &StageFeatures, cfg: &BigRootsConfig) -> StageAnalysis {
        analyze_stage(sf, &mut NativeBackend::new(), cfg)
    }

    #[test]
    fn numerical_outlier_identified() {
        let sf = stage_with_hot(F::ShuffleReadBytes, 5.0, 20, 7);
        let a = run(&sf, &BigRootsConfig::default());
        assert_eq!(a.stragglers.rows, vec![7]);
        let causes = a.causes_of(7);
        assert!(causes.iter().any(|c| c.kind == F::ShuffleReadBytes), "{causes:?}");
    }

    #[test]
    fn flat_feature_not_identified() {
        // Straggler exists but no feature deviates → no causes.
        let f = F::COUNT;
        let n = 20;
        let mut durations = vec![1.0; n];
        durations[3] = 3.0;
        let sf = StageFeatures {
            stage_id: 0,
            task_ids: (0..n as u64).collect(),
            nodes: (0..n).map(|r| r % 4).collect(),
            durations,
            matrix: vec![0.5; n * f],
            head_means: vec![1.0; n * 3],
            tail_means: vec![1.0; n * 3],
        };
        let a = run(&sf, &BigRootsConfig::default());
        assert_eq!(a.stragglers.rows, vec![3]);
        assert!(a.causes.is_empty(), "{:?}", a.causes);
    }

    #[test]
    fn time_feature_lower_bound_filters_small_values() {
        // GC elevated relative to peers but below the 0.2 absolute bound.
        let sf = stage_with_hot(F::JvmGcTime, 0.15, 20, 5);
        let a = run(&sf, &BigRootsConfig::default());
        assert!(a.causes_of(5).iter().all(|c| c.kind != F::JvmGcTime));
        // Above the bound it is identified.
        let sf2 = stage_with_hot(F::JvmGcTime, 0.5, 20, 5);
        let a2 = run(&sf2, &BigRootsConfig::default());
        assert!(a2.causes_of(5).iter().any(|c| c.kind == F::JvmGcTime));
    }

    #[test]
    fn edge_detection_filters_self_inflicted_resource() {
        let mut sf = stage_with_hot(F::Cpu, 0.9, 20, 5);
        // Head/tail low → the task itself caused the utilization.
        for v in sf.head_means.iter_mut().chain(sf.tail_means.iter_mut()) {
            *v = 0.05;
        }
        let with_edge = run(&sf, &BigRootsConfig::default());
        assert!(with_edge.causes_of(5).iter().all(|c| c.kind != F::Cpu));
        // Without edge detection the same feature IS flagged (Fig. 9's FP).
        let cfg = BigRootsConfig { use_edge_detection: false, ..Default::default() };
        let no_edge = run(&sf, &cfg);
        assert!(no_edge.causes_of(5).iter().any(|c| c.kind == F::Cpu));
    }

    #[test]
    fn edge_detection_keeps_external_resource() {
        // Head/tail high → contention existed before/after → external.
        let sf = stage_with_hot(F::Cpu, 0.9, 20, 5);
        let a = run(&sf, &BigRootsConfig::default());
        assert!(a.causes_of(5).iter().any(|c| c.kind == F::Cpu));
    }

    #[test]
    fn locality_rule_eq7() {
        let f = F::COUNT;
        let n = 12;
        let mut matrix = vec![0.0; n * f];
        let mut durations = vec![1.0; n];
        durations[2] = 3.0;
        // Straggler reads remotely (2.0), peers locally (0.0).
        matrix[2 * f + F::Locality.index()] = 2.0;
        let sf = StageFeatures {
            stage_id: 0,
            task_ids: (0..n as u64).collect(),
            nodes: (0..n).map(|r| r % 3).collect(),
            durations: durations.clone(),
            matrix: matrix.clone(),
            head_means: vec![1.0; n * 3],
            tail_means: vec![1.0; n * 3],
        };
        let a = run(&sf, &BigRootsConfig::default());
        assert!(a.causes_of(2).iter().any(|c| c.kind == F::Locality));

        // If peers ALSO read remotely, the vote fails (Eq. 7).
        let mut m2 = matrix;
        for r in 0..n {
            m2[r * f + F::Locality.index()] = 2.0;
        }
        let sf2 = StageFeatures {
            stage_id: 0,
            task_ids: (0..n as u64).collect(),
            nodes: (0..n).map(|r| r % 3).collect(),
            durations,
            matrix: m2,
            head_means: vec![1.0; n * 3],
            tail_means: vec![1.0; n * 3],
        };
        let a2 = run(&sf2, &BigRootsConfig::default());
        assert!(a2.causes_of(2).iter().all(|c| c.kind != F::Locality));
    }

    #[test]
    fn lambda_p_monotone() {
        // Raising λ_p can only remove causes.
        let sf = stage_with_hot(F::BytesRead, 3.0, 30, 11);
        let lo = run(&sf, &BigRootsConfig { lambda_p: 1.2, ..Default::default() });
        let hi = run(&sf, &BigRootsConfig { lambda_p: 4.0, ..Default::default() });
        assert!(hi.causes.len() <= lo.causes.len());
    }

    #[test]
    fn lambda_q_monotone() {
        let sf = stage_with_hot(F::BytesRead, 3.0, 30, 11);
        let lo = run(&sf, &BigRootsConfig { lambda_q: 0.2, ..Default::default() });
        let hi = run(&sf, &BigRootsConfig { lambda_q: 0.99, ..Default::default() });
        assert!(hi.causes.len() <= lo.causes.len());
    }

    #[test]
    fn non_stragglers_never_get_causes() {
        let sf = stage_with_hot(F::BytesRead, 5.0, 20, 7);
        let a = run(&sf, &BigRootsConfig::default());
        for c in &a.causes {
            assert!(a.stragglers.is_straggler(c.row));
        }
    }

    #[test]
    fn histogram_counts() {
        let sf = stage_with_hot(F::ShuffleReadBytes, 5.0, 20, 7);
        let a = run(&sf, &BigRootsConfig::default());
        let h = a.cause_histogram();
        assert!(h.iter().any(|&(k, n)| k == F::ShuffleReadBytes && n >= 1));
    }

    #[test]
    fn intra_node_evidence_detected() {
        // Straggler's value deviates from intra-node peers only: all tasks on
        // node 0; other nodes' tasks have elevated values too, so inter-node
        // mean is high, but intra-node mean is low.
        let f = F::COUNT;
        let n = 16;
        let k = F::DiskBytesSpilled;
        let mut matrix = vec![0.0; n * f];
        let mut durations = vec![1.0; n];
        let nodes: Vec<usize> = (0..n).map(|r| r % 4).collect();
        durations[0] = 3.0; // straggler, node 0
        for r in 0..n {
            let v = if r == 0 {
                4.0 // straggler value
            } else if nodes[r] == 0 {
                0.2 // intra-node peers: low
            } else {
                3.0 // inter-node peers: high → inter test fails at λ_p=1.5
            };
            matrix[r * f + k.index()] = v;
        }
        let sf = StageFeatures {
            stage_id: 0,
            task_ids: (0..n as u64).collect(),
            nodes,
            durations,
            matrix,
            head_means: vec![1.0; n * 3],
            tail_means: vec![1.0; n * 3],
        };
        let a = run(&sf, &BigRootsConfig::default());
        let c = a
            .causes_of(0)
            .into_iter()
            .find(|c| c.kind == k)
            .expect("intra-node deviation must be found");
        assert_eq!(c.peer, PeerEvidence::IntraNode);
    }
}
