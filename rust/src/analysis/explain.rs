//! Verdict provenance — every straggler/cause verdict explains itself.
//!
//! The identification rules ([`super::bigroots`]) answer *which* feature
//! caused a straggler; this module records *why the analyzer believes it*:
//! per flagged task and cause, the observed feature value, the threshold it
//! crossed, the stage baseline it was measured against (median/MAD of the
//! feature column), where the value sits in the fleet-wide distribution
//! ([`FeatureSnapshot`] percentile), and an effect-size-derived confidence
//! in `[0, 1]`. Causes whose flagged-task sets overlap within a stage are
//! grouped as co-occurring (HybridTune-style aligned evidence, arxiv
//! 1711.07639) so a GC spike and the shuffle surge that provoked it read
//! as one incident, not two.
//!
//! ## Confidence semantics
//!
//! The score is a closed-form map of two robust effect sizes, computed in
//! a fixed f64 evaluation order so it is **bit-reproducible** offline:
//!
//! 1. *stage effect* — `z = (value − median) / MAD` over the stage's
//!    feature column, mapped through `z / (z + 2)` (0 at the median, 0.5
//!    at two MADs out, → 1 as the deviation grows). A degenerate column
//!    (MAD = 0) scores 1 when the value clears the median, else 0.
//! 2. *fleet percentile* — the value's position in the fleet baseline,
//!    interpolated from the [`FeatureSnapshot`] p50/p95 markers; skipped
//!    while the baseline is colder than [`FLEET_MIN_COUNT`] observations.
//!
//! `confidence = (stage + fleet) / 2` when the fleet is warm, else the
//! stage effect alone. Both components are monotone in the deviation, so
//! ranking causes by confidence never contradicts ranking by effect size.
//!
//! ## Replay
//!
//! [`FlightDump`] is the NDJSON container the flight recorder
//! ([`crate::obs::flight`]) writes: one header line freezing the verdict,
//! the analyzer config and the fleet baselines in effect (floats as bit
//! patterns), then the job's raw event window. [`FlightDump::replay`]
//! re-runs the full pipeline — events → trace → features → rules →
//! provenance — against the frozen baselines and must reproduce the
//! recorded verdict **bit-identically** ([`FlightDump::verify`]); the
//! fleet baselines travel in the dump because the live registry keeps
//! evolving after the verdict fires.

use super::bigroots::{analyze_stage_with_stats, BigRootsConfig, StageAnalysis};
use super::features::{extract_all, FeatureKind, StageFeatures};
use super::stats::{NativeBackend, StatsBackend};
use crate::live::registry::FeatureSnapshot;
use crate::trace::eventlog::{events_to_trace, parse_tagged_events, TaggedEvent};
use crate::trace::wire;
use crate::util::json::Json;
use crate::util::stats::{mad, median};

/// A fleet baseline below this many observations is too cold to contribute
/// a percentile (matches [`crate::analysis::whatif::FLEET_MIN_COUNT`]).
pub const FLEET_MIN_COUNT: usize = 64;

/// Provenance of one identified cause: everything that went into the call.
#[derive(Debug, Clone, PartialEq)]
pub struct CauseTrace {
    /// Row into the stage's feature matrix.
    pub row: usize,
    pub task_id: u64,
    pub kind: FeatureKind,
    /// The observed feature value.
    pub value: f64,
    /// The threshold the rule applied (global quantile for Eq. 5 causes,
    /// the locality code 2.0 for Eq. 7).
    pub threshold: f64,
    /// Which peer group supplied the supporting evidence.
    pub peer: &'static str,
    /// Median of the stage's feature column — the local baseline.
    pub stage_median: f64,
    /// Median absolute deviation of the column — the local spread.
    pub stage_mad: f64,
    /// Estimated fleet-wide percentile of the value in `[0, 1]`, `None`
    /// while the fleet baseline is colder than [`FLEET_MIN_COUNT`].
    pub fleet_percentile: Option<f64>,
    /// Effect-size-derived confidence in `[0, 1]` (module docs).
    pub confidence: f64,
    /// Index into [`VerdictTrace::groups`] of this cause's co-occurrence
    /// group.
    pub group: usize,
}

/// Structured provenance of one stage's verdict.
#[derive(Debug, Clone, PartialEq)]
pub struct VerdictTrace {
    pub stage_id: u64,
    /// Median task duration the straggler threshold was derived from.
    pub duration_median: f64,
    /// The straggler duration threshold (ratio × median).
    pub duration_threshold: f64,
    /// Task ids flagged as stragglers, in row order.
    pub flagged: Vec<u64>,
    pub causes: Vec<CauseTrace>,
    /// Co-occurrence groups: cause kinds whose flagged-task sets overlap,
    /// each group sorted by feature index, groups sorted by first member.
    pub groups: Vec<Vec<FeatureKind>>,
}

impl VerdictTrace {
    /// Highest cause confidence in this stage (0.0 with no causes).
    pub fn max_confidence(&self) -> f64 {
        self.causes.iter().fold(0.0, |m, c| m.max(c.confidence))
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("stage", self.stage_id.into()),
            ("duration_median", self.duration_median.into()),
            ("duration_threshold", self.duration_threshold.into()),
            (
                "flagged",
                Json::Arr(self.flagged.iter().map(|&t| t.into()).collect()),
            ),
            (
                "causes",
                Json::Arr(self.causes.iter().map(|c| c.to_json()).collect()),
            ),
            (
                "groups",
                Json::Arr(
                    self.groups
                        .iter()
                        .map(|g| {
                            Json::Arr(g.iter().map(|k| k.name().into()).collect())
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

impl CauseTrace {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("task", self.task_id.into()),
            ("row", self.row.into()),
            ("cause", self.kind.name().into()),
            ("value", self.value.into()),
            ("threshold", self.threshold.into()),
            ("peer", self.peer.into()),
            ("stage_median", self.stage_median.into()),
            ("stage_mad", self.stage_mad.into()),
            (
                "fleet_percentile",
                match self.fleet_percentile {
                    Some(p) => p.into(),
                    None => Json::Null,
                },
            ),
            ("confidence", self.confidence.into()),
            ("group", self.group.into()),
        ])
    }
}

/// Map a robust z-score to `[0, 1)`: 0 at the baseline, 0.5 at two MADs
/// out, asymptotically 1. Infinite z (degenerate spread, cleared median)
/// scores exactly 1.
fn confidence_of_z(z: f64) -> f64 {
    if z.is_infinite() {
        1.0
    } else {
        z / (z + 2.0)
    }
}

/// Estimated fleet percentile of `v` from the p50/p95 markers of a warm
/// baseline: linear below the median (0 → 0.5), linear between the markers
/// (0.5 → 0.95), and a hyperbolic tail above p95 approaching 1.
fn fleet_percentile(v: f64, snap: &FeatureSnapshot) -> Option<f64> {
    if snap.count < FLEET_MIN_COUNT {
        return None;
    }
    let (p50, p95) = (snap.p50, snap.p95);
    let p = if v <= p50 {
        if p50 > 0.0 {
            0.5 * (v / p50).max(0.0)
        } else {
            0.5
        }
    } else if v <= p95 {
        if p95 > p50 {
            0.5 + 0.45 * ((v - p50) / (p95 - p50))
        } else {
            0.95
        }
    } else {
        // v > p95: tail share shrinks as the value pulls away.
        1.0 - 0.05 * (p95.max(0.0) / v)
    };
    Some(p.clamp(0.0, 1.0))
}

/// Derive the provenance trace for one analyzed stage. `baselines` is the
/// fleet report's per-feature snapshot at derivation time (empty when no
/// fleet context exists — offline single-job analysis).
pub fn explain_stage(
    sf: &StageFeatures,
    analysis: &StageAnalysis,
    baselines: &[FeatureSnapshot],
) -> VerdictTrace {
    // Per-kind column baselines, computed once per kind actually implicated.
    let mut col_stats: Vec<Option<(f64, f64)>> = vec![None; FeatureKind::COUNT];
    let mut causes: Vec<CauseTrace> = Vec::with_capacity(analysis.causes.len());
    for c in &analysis.causes {
        let (stage_median, stage_mad) = *col_stats[c.kind.index()].get_or_insert_with(|| {
            let col = sf.column(c.kind);
            (median(&col), mad(&col))
        });
        let z = if stage_mad > 0.0 {
            ((c.value - stage_median) / stage_mad).max(0.0)
        } else if c.value > stage_median {
            f64::INFINITY
        } else {
            0.0
        };
        let stage_conf = confidence_of_z(z);
        let fp = baselines
            .iter()
            .find(|b| b.kind == c.kind)
            .and_then(|b| fleet_percentile(c.value, b));
        let confidence = match fp {
            Some(p) => (stage_conf + p) / 2.0,
            None => stage_conf,
        };
        causes.push(CauseTrace {
            row: c.row,
            task_id: c.task_id,
            kind: c.kind,
            value: c.value,
            threshold: c.global_threshold,
            peer: c.peer.as_str(),
            stage_median,
            stage_mad,
            fleet_percentile: fp,
            confidence,
            group: 0, // assigned below
        });
    }

    // Co-occurrence: union-find over the implicated kinds; two kinds join
    // when any straggler row is flagged by both.
    let mut parent: Vec<usize> = (0..FeatureKind::COUNT).collect();
    fn find(parent: &mut Vec<usize>, i: usize) -> usize {
        let mut i = i;
        while parent[i] != i {
            parent[i] = parent[parent[i]];
            i = parent[i];
        }
        i
    }
    let kinds: Vec<FeatureKind> = FeatureKind::ALL
        .iter()
        .copied()
        .filter(|k| causes.iter().any(|c| c.kind == *k))
        .collect();
    for (i, &a) in kinds.iter().enumerate() {
        for &b in &kinds[i + 1..] {
            let overlap = causes.iter().any(|ca| {
                ca.kind == a && causes.iter().any(|cb| cb.kind == b && cb.row == ca.row)
            });
            if overlap {
                let (ra, rb) = (find(&mut parent, a.index()), find(&mut parent, b.index()));
                // Union toward the smaller feature index for determinism.
                if ra < rb {
                    parent[rb] = ra;
                } else {
                    parent[ra] = rb;
                }
            }
        }
    }
    let mut groups: Vec<Vec<FeatureKind>> = Vec::new();
    let mut group_of_root: Vec<Option<usize>> = vec![None; FeatureKind::COUNT];
    for &k in &kinds {
        let root = find(&mut parent, k.index());
        let g = *group_of_root[root].get_or_insert_with(|| {
            groups.push(Vec::new());
            groups.len() - 1
        });
        groups[g].push(k);
    }
    for c in &mut causes {
        let root = find(&mut parent, c.kind.index());
        c.group = group_of_root[root].expect("implicated kind has a group");
    }

    VerdictTrace {
        stage_id: analysis.stage_id,
        duration_median: analysis.stragglers.median,
        duration_threshold: analysis.stragglers.threshold,
        flagged: analysis.stragglers.flagged_task_ids(sf),
        causes,
        groups,
    }
}

/// Highest cause confidence across a job's stage traces.
pub fn max_confidence(traces: &[VerdictTrace]) -> f64 {
    traces.iter().fold(0.0, |m, t| m.max(t.max_confidence()))
}

/// Distinct cause kinds across a job's stage traces, by feature index.
pub fn cause_kinds(traces: &[VerdictTrace]) -> Vec<FeatureKind> {
    FeatureKind::ALL
        .iter()
        .copied()
        .filter(|k| traces.iter().any(|t| t.causes.iter().any(|c| c.kind == *k)))
        .collect()
}

/// The job-level verdict document: stage traces sorted by stage id, so the
/// encoding is independent of stage *emission* order (live completion
/// order vs. batch submission order).
pub fn job_verdict_json(job_id: u64, incarnation: u32, traces: &[VerdictTrace]) -> Json {
    let mut sorted: Vec<&VerdictTrace> = traces.iter().collect();
    sorted.sort_by_key(|t| t.stage_id);
    Json::from_pairs(vec![
        ("job_id", format!("{job_id}").as_str().into()),
        ("incarnation", incarnation.into()),
        ("max_confidence", max_confidence(traces).into()),
        (
            "cause_kinds",
            Json::Arr(cause_kinds(traces).iter().map(|k| k.name().into()).collect()),
        ),
        ("stages", Json::Arr(sorted.iter().map(|t| t.to_json()).collect())),
    ])
}

// ---------------------------------------------------------------------------
// Flight dump: NDJSON container for verdict + frozen context + raw events.
// ---------------------------------------------------------------------------

const DUMP_KIND: &str = "bigroots-flight-dump";
const DUMP_VERSION: u64 = 1;
/// Magic prefix of the *binary* dump container (`.bew` dumps): the JSON
/// header travels length-prefixed, the event window as wire frames.
const DUMP_MAGIC: [u8; 4] = *b"BGRD";

/// f64 → bit-exact hex string (same codec as [`crate::live::persist`]).
fn fbits(x: f64) -> Json {
    Json::Str(format!("{:016x}", x.to_bits()))
}

fn read_fbits(j: &Json, what: &str) -> Result<f64, String> {
    let s = j.as_str().ok_or_else(|| format!("{what}: expected hex f64 string"))?;
    if s.len() != 16 {
        return Err(format!("{what}: expected 16 hex chars, got {}", s.len()));
    }
    u64::from_str_radix(s, 16)
        .map(f64::from_bits)
        .map_err(|e| format!("{what}: {e}"))
}

fn encode_config(cfg: &BigRootsConfig) -> Json {
    Json::from_pairs(vec![
        ("straggler_ratio", fbits(cfg.straggler_ratio)),
        ("lambda_q", fbits(cfg.lambda_q)),
        ("lambda_p", fbits(cfg.lambda_p)),
        ("time_lower_bound", fbits(cfg.time_lower_bound)),
        ("edge_width", fbits(cfg.edge_width)),
        ("lambda_e", fbits(cfg.lambda_e)),
        ("use_edge_detection", cfg.use_edge_detection.into()),
        ("min_resource_util", fbits(cfg.min_resource_util)),
        ("min_net_bytes", fbits(cfg.min_net_bytes)),
    ])
}

fn decode_config(j: &Json) -> Result<BigRootsConfig, String> {
    Ok(BigRootsConfig {
        straggler_ratio: read_fbits(j.get("straggler_ratio"), "straggler_ratio")?,
        lambda_q: read_fbits(j.get("lambda_q"), "lambda_q")?,
        lambda_p: read_fbits(j.get("lambda_p"), "lambda_p")?,
        time_lower_bound: read_fbits(j.get("time_lower_bound"), "time_lower_bound")?,
        edge_width: read_fbits(j.get("edge_width"), "edge_width")?,
        lambda_e: read_fbits(j.get("lambda_e"), "lambda_e")?,
        use_edge_detection: j
            .get("use_edge_detection")
            .as_bool()
            .ok_or("use_edge_detection: expected bool")?,
        min_resource_util: read_fbits(j.get("min_resource_util"), "min_resource_util")?,
        min_net_bytes: read_fbits(j.get("min_net_bytes"), "min_net_bytes")?,
    })
}

fn encode_baseline(b: &FeatureSnapshot) -> Json {
    Json::from_pairs(vec![
        ("feature", b.kind.name().into()),
        ("count", b.count.into()),
        ("p50", fbits(b.p50)),
        ("p95", fbits(b.p95)),
    ])
}

fn decode_baseline(j: &Json) -> Result<FeatureSnapshot, String> {
    let name = j.get("feature").as_str().ok_or("baseline: missing feature name")?;
    let kind = FeatureKind::from_name(name)
        .ok_or_else(|| format!("baseline: unknown feature '{name}'"))?;
    Ok(FeatureSnapshot {
        kind,
        count: j.get("count").as_usize().ok_or("baseline: missing count")?,
        p50: read_fbits(j.get("p50"), "baseline p50")?,
        p95: read_fbits(j.get("p95"), "baseline p95")?,
        // Not consulted by replay — the trace derivation reads count/p50/p95.
        straggler_p50: 0.0,
        cause_count: 0,
        mean_confidence: 0.0,
        verdicts: 0,
    })
}

/// One flight-recorder dump: the recorded verdict, the exact analyzer
/// config and fleet baselines it was derived under, and the raw event
/// window. Everything [`FlightDump::replay`] needs to reproduce the
/// verdict bit-identically travels inside the file.
#[derive(Debug, Clone, PartialEq)]
pub struct FlightDump {
    pub job_id: u64,
    pub incarnation: u32,
    /// Whether the recorder held the job's complete event window (no ring
    /// evictions, job start observed). Replay of an incomplete window may
    /// legitimately diverge.
    pub complete: bool,
    pub config: BigRootsConfig,
    /// Fleet baselines in effect when the verdict was derived (only
    /// `kind`/`count`/`p50`/`p95` round-trip; the rest is not consulted).
    pub baselines: Vec<FeatureSnapshot>,
    /// The recorded verdict document ([`job_verdict_json`]).
    pub verdict: Json,
    pub events: Vec<TaggedEvent>,
}

impl FlightDump {
    fn header_json(&self) -> Json {
        Json::from_pairs(vec![
            ("kind", DUMP_KIND.into()),
            ("version", DUMP_VERSION.into()),
            ("job", self.job_id.into()),
            ("incarnation", self.incarnation.into()),
            ("complete", self.complete.into()),
            ("config", encode_config(&self.config)),
            (
                "baselines",
                Json::Arr(self.baselines.iter().map(encode_baseline).collect()),
            ),
            ("verdict", self.verdict.clone()),
        ])
    }

    fn from_header(header: &Json, events: Vec<TaggedEvent>) -> Result<FlightDump, String> {
        if header.get("kind").as_str() != Some(DUMP_KIND) {
            return Err(format!("not a flight dump (kind != {DUMP_KIND})"));
        }
        let version = header.get("version").as_u64().unwrap_or(0);
        if version != DUMP_VERSION {
            return Err(format!("unsupported dump version {version}"));
        }
        let baselines = match header.get("baselines") {
            Json::Arr(items) => items
                .iter()
                .map(decode_baseline)
                .collect::<Result<Vec<_>, _>>()?,
            _ => return Err("dump header: baselines must be an array".to_string()),
        };
        Ok(FlightDump {
            job_id: header.get("job").as_u64().ok_or("dump header: missing job")?,
            incarnation: header
                .get("incarnation")
                .as_u64()
                .ok_or("dump header: missing incarnation")? as u32,
            complete: header.get("complete").as_bool().unwrap_or(false),
            config: decode_config(header.get("config"))?,
            baselines,
            verdict: header.get("verdict").clone(),
            events,
        })
    }

    /// Serialize: one header line, then one NDJSON line per event.
    pub fn encode_ndjson(&self) -> String {
        let mut out = self.header_json().to_string();
        out.push('\n');
        for e in &self.events {
            out.push_str(&e.encode().to_string());
            out.push('\n');
        }
        out
    }

    /// Serialize the binary container: `BGRD` magic, u32 LE length of the
    /// JSON header, the header bytes, then the event window as a wire
    /// stream (`trace/wire.rs` frames). Same information as
    /// [`FlightDump::encode_ndjson`], parser-free event decode.
    pub fn encode_binary(&self) -> Vec<u8> {
        let header = self.header_json().to_string();
        let stream = wire::encode_stream(&self.events);
        let mut out = Vec::with_capacity(8 + header.len() + stream.len());
        out.extend_from_slice(&DUMP_MAGIC);
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        out.extend_from_slice(&stream);
        out
    }

    /// Parse a dump file's text back into its parts.
    pub fn parse(text: &str) -> Result<FlightDump, String> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header_line = lines.next().ok_or("empty flight dump")?;
        let header = Json::parse(header_line).map_err(|e| format!("dump header: {e}"))?;
        let body: String = lines.fold(String::new(), |mut acc, l| {
            acc.push_str(l);
            acc.push('\n');
            acc
        });
        let events = parse_tagged_events(&body).map_err(|e| format!("dump events: {e}"))?;
        Self::from_header(&header, events)
    }

    /// Does this buffer hold a binary flight dump?
    pub fn is_binary(bytes: &[u8]) -> bool {
        bytes.len() >= 4 && bytes[..4] == DUMP_MAGIC
    }

    /// Parse a binary dump produced by [`FlightDump::encode_binary`].
    pub fn parse_binary(bytes: &[u8]) -> Result<FlightDump, String> {
        if !Self::is_binary(bytes) {
            return Err("not a binary flight dump (bad magic)".to_string());
        }
        let len_bytes = bytes
            .get(4..8)
            .ok_or("binary dump truncated before header length")?;
        let header_len = u32::from_le_bytes(len_bytes.try_into().expect("4-byte slice")) as usize;
        let header_bytes = bytes
            .get(8..8 + header_len)
            .ok_or("binary dump truncated inside header")?;
        let header_text = std::str::from_utf8(header_bytes)
            .map_err(|e| format!("dump header not UTF-8: {e}"))?;
        let header = Json::parse(header_text).map_err(|e| format!("dump header: {e}"))?;
        let events = wire::decode_stream(&bytes[8 + header_len..])
            .map_err(|e| format!("dump events: {e}"))?;
        Self::from_header(&header, events)
    }

    /// Parse either container, sniffing the magic.
    pub fn parse_any(bytes: &[u8]) -> Result<FlightDump, String> {
        if Self::is_binary(bytes) {
            Self::parse_binary(bytes)
        } else {
            let text =
                std::str::from_utf8(bytes).map_err(|e| format!("dump not UTF-8: {e}"))?;
            Self::parse(text)
        }
    }

    /// Re-run the full pipeline over the dumped event window — rebuild the
    /// trace, extract features, apply the identification rules under the
    /// dumped config, derive provenance against the frozen fleet baselines
    /// — and return the reproduced verdict document.
    pub fn replay(&self) -> Result<Json, String> {
        let events: Vec<_> = self
            .events
            .iter()
            .filter(|e| e.job_id == self.job_id)
            .map(|e| e.event.clone())
            .collect();
        let trace = events_to_trace(&events)?;
        let features = extract_all(&trace, self.config.edge_width);
        let mut backend = NativeBackend::new();
        let refs: Vec<&StageFeatures> = features.iter().collect();
        let stats = backend.stage_stats_batch(&refs);
        if stats.len() != features.len() {
            return Err("backend returned wrong batch size".to_string());
        }
        let traces: Vec<VerdictTrace> = features
            .iter()
            .zip(&stats)
            .map(|(sf, st)| {
                let a = analyze_stage_with_stats(sf, st, &self.config);
                explain_stage(sf, &a, &self.baselines)
            })
            .collect();
        Ok(job_verdict_json(self.job_id, self.incarnation, &traces))
    }

    /// Replay and require the reproduced verdict to match the recorded one
    /// bit-identically (compared as canonical compact JSON). Returns the
    /// replayed verdict on success.
    pub fn verify(&self) -> Result<Json, String> {
        let replayed = self.replay()?;
        let want = self.verdict.to_string();
        let got = replayed.to_string();
        if want != got {
            return Err(format!(
                "replay diverged from recorded verdict\nrecorded: {want}\nreplayed: {got}"
            ));
        }
        Ok(replayed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::bigroots::analyze_stage;
    use crate::analysis::features::FeatureKind as F;
    use crate::sim::{workloads, Engine, InjectionPlan, SimConfig};
    use crate::trace::AnomalyKind;

    fn analyzed_stages() -> Vec<(StageFeatures, StageAnalysis)> {
        let w = workloads::wordcount(0.25);
        let mut eng = Engine::new(SimConfig { seed: 17, ..Default::default() });
        let t = eng.run(
            "explain-test",
            w.name,
            &w.stages,
            &InjectionPlan::intermittent(AnomalyKind::Cpu, 1, 15.0, 10.0, 300.0),
        );
        let cfg = BigRootsConfig::default();
        extract_all(&t, cfg.edge_width)
            .into_iter()
            .map(|sf| {
                let a = analyze_stage(&sf, &mut NativeBackend::new(), &cfg);
                (sf, a)
            })
            .collect()
    }

    #[test]
    fn traces_cover_every_cause_with_bounded_confidence() {
        let mut saw_cause = false;
        for (sf, a) in analyzed_stages() {
            let tr = explain_stage(&sf, &a, &[]);
            assert_eq!(tr.stage_id, a.stage_id);
            assert_eq!(tr.causes.len(), a.causes.len());
            assert_eq!(tr.flagged.len(), a.stragglers.rows.len());
            for (c, rc) in tr.causes.iter().zip(&a.causes) {
                saw_cause = true;
                assert_eq!(c.task_id, rc.task_id);
                assert_eq!(c.kind, rc.kind);
                assert_eq!(c.value, rc.value);
                assert_eq!(c.threshold, rc.global_threshold);
                assert!(
                    (0.0..=1.0).contains(&c.confidence),
                    "confidence {} out of range",
                    c.confidence
                );
                assert!(c.group < tr.groups.len());
                assert!(tr.groups[c.group].contains(&c.kind));
                // No fleet context → stage-only confidence, no percentile.
                assert_eq!(c.fleet_percentile, None);
            }
        }
        assert!(saw_cause, "workload produced no causes to trace");
    }

    #[test]
    fn cooccurring_kinds_group_when_rows_overlap() {
        // Hand-build an analysis where two kinds flag the same row and a
        // third flags a different row.
        let n = 8;
        let f = F::COUNT;
        let sf = StageFeatures {
            stage_id: 3,
            task_ids: (0..n as u64).collect(),
            nodes: vec![0; n],
            durations: vec![1.0; n],
            matrix: vec![0.0; n * f],
            head_means: vec![0.0; n * 3],
            tail_means: vec![0.0; n * 3],
        };
        let mk = |row: usize, kind: F| crate::analysis::bigroots::RootCause {
            row,
            task_id: row as u64,
            kind,
            value: 2.0,
            global_threshold: 1.0,
            peer: crate::analysis::bigroots::PeerEvidence::Both,
        };
        let a = StageAnalysis {
            stage_id: 3,
            stragglers: crate::analysis::straggler::StragglerSet {
                median: 1.0,
                threshold: 1.5,
                rows: vec![2, 5],
            },
            causes: vec![mk(2, F::JvmGcTime), mk(2, F::ShuffleReadBytes), mk(5, F::Cpu)],
        };
        let tr = explain_stage(&sf, &a, &[]);
        assert_eq!(tr.groups.len(), 2);
        let joint: &Vec<F> = tr
            .groups
            .iter()
            .find(|g| g.len() == 2)
            .expect("overlapping kinds must share a group");
        assert!(joint.contains(&F::ShuffleReadBytes) && joint.contains(&F::JvmGcTime));
        let gc = tr.causes.iter().find(|c| c.kind == F::JvmGcTime).unwrap();
        let sh = tr.causes.iter().find(|c| c.kind == F::ShuffleReadBytes).unwrap();
        let cpu = tr.causes.iter().find(|c| c.kind == F::Cpu).unwrap();
        assert_eq!(gc.group, sh.group);
        assert_ne!(gc.group, cpu.group);
    }

    #[test]
    fn fleet_percentile_is_monotone_and_gated_on_warmth() {
        let warm = FeatureSnapshot {
            kind: F::Cpu,
            count: 1000,
            p50: 0.4,
            p95: 0.8,
            straggler_p50: 0.0,
            cause_count: 0,
            mean_confidence: 0.0,
            verdicts: 0,
        };
        let cold = FeatureSnapshot { count: 3, ..warm.clone() };
        assert_eq!(fleet_percentile(0.5, &cold), None);
        let samples = [0.0, 0.2, 0.4, 0.6, 0.8, 1.0, 2.0, 10.0];
        let mut prev = -1.0;
        for v in samples {
            let p = fleet_percentile(v, &warm).unwrap();
            assert!((0.0..=1.0).contains(&p), "percentile {p}");
            assert!(p >= prev, "not monotone at {v}");
            prev = p;
        }
        assert_eq!(fleet_percentile(0.4, &warm), Some(0.5));
        assert_eq!(fleet_percentile(0.8, &warm), Some(0.95));
    }

    #[test]
    fn confidence_blends_fleet_when_warm() {
        for (sf, a) in analyzed_stages() {
            if a.causes.is_empty() {
                continue;
            }
            let warm: Vec<FeatureSnapshot> = FeatureKind::ALL
                .iter()
                .map(|&kind| FeatureSnapshot {
                    kind,
                    count: 1000,
                    p50: 0.1,
                    p95: 0.2,
                    straggler_p50: 0.0,
                    cause_count: 0,
                    mean_confidence: 0.0,
                    verdicts: 0,
                })
                .collect();
            let tr = explain_stage(&sf, &a, &warm);
            for c in &tr.causes {
                assert!(c.fleet_percentile.is_some());
                assert!((0.0..=1.0).contains(&c.confidence));
            }
            return;
        }
        panic!("no causes to test");
    }

    #[test]
    fn dump_roundtrips_and_replays_bit_identically() {
        let w = workloads::wordcount(0.25);
        let mut eng = Engine::new(SimConfig { seed: 23, ..Default::default() });
        let t = eng.run(
            "dump-test",
            w.name,
            &w.stages,
            &InjectionPlan::intermittent(AnomalyKind::Cpu, 1, 15.0, 10.0, 300.0),
        );
        let cfg = BigRootsConfig::default();
        let events: Vec<TaggedEvent> = crate::trace::eventlog::trace_to_events(&t)
            .into_iter()
            .map(|event| TaggedEvent { job_id: 9, event })
            .collect();
        // Derive the "live" verdict exactly as replay will, so the test
        // asserts the codec (not the pipeline) is lossless.
        let dump0 = FlightDump {
            job_id: 9,
            incarnation: 1,
            complete: true,
            config: cfg,
            baselines: Vec::new(),
            verdict: Json::Null,
            events,
        };
        let verdict = dump0.replay().expect("replay");
        let dump = FlightDump { verdict, ..dump0 };
        let text = dump.encode_ndjson();
        let back = FlightDump::parse(&text).expect("parse");
        assert_eq!(back.config, dump.config);
        assert_eq!(back.events, dump.events);
        assert_eq!(back.verdict.to_string(), dump.verdict.to_string());
        back.verify().expect("bit-identical replay");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FlightDump::parse("").is_err());
        assert!(FlightDump::parse("{\"kind\":\"nope\"}\n").is_err());
        assert!(FlightDump::parse("not json\n").is_err());
    }

    #[test]
    fn binary_dump_roundtrips_and_sniffs() {
        let w = workloads::wordcount(0.25);
        let mut eng = Engine::new(SimConfig { seed: 29, ..Default::default() });
        let t = eng.run(
            "bindump-test",
            w.name,
            &w.stages,
            &InjectionPlan::intermittent(AnomalyKind::Cpu, 1, 15.0, 10.0, 300.0),
        );
        let events: Vec<TaggedEvent> = crate::trace::eventlog::trace_to_events(&t)
            .into_iter()
            .map(|event| TaggedEvent { job_id: 4, event })
            .collect();
        let dump0 = FlightDump {
            job_id: 4,
            incarnation: 2,
            complete: true,
            config: BigRootsConfig::default(),
            baselines: Vec::new(),
            verdict: Json::Null,
            events,
        };
        let verdict = dump0.replay().expect("replay");
        let dump = FlightDump { verdict, ..dump0 };

        let bytes = dump.encode_binary();
        assert!(FlightDump::is_binary(&bytes));
        assert!(!FlightDump::is_binary(dump.encode_ndjson().as_bytes()));
        let back = FlightDump::parse_binary(&bytes).expect("parse_binary");
        assert_eq!(back, dump);
        back.verify().expect("bit-identical replay from binary dump");

        // parse_any picks the right container for both encodings.
        assert_eq!(FlightDump::parse_any(&bytes).unwrap(), dump);
        assert_eq!(
            FlightDump::parse_any(dump.encode_ndjson().as_bytes()).unwrap(),
            dump
        );
        // Re-encode is byte-identical: the container is canonical.
        assert_eq!(back.encode_binary(), bytes);

        // Truncations error, never panic.
        for cut in [0, 3, 6, 9, bytes.len() - 1] {
            assert!(FlightDump::parse_binary(&bytes[..cut]).is_err());
        }
    }

    #[test]
    fn job_verdict_sorts_stages_by_id() {
        let mk = |stage_id: u64| VerdictTrace {
            stage_id,
            duration_median: 1.0,
            duration_threshold: 1.5,
            flagged: vec![],
            causes: vec![],
            groups: vec![],
        };
        let j = job_verdict_json(4, 1, &[mk(7), mk(2), mk(5)]);
        let ids: Vec<u64> = j
            .get("stages")
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| s.get("stage").as_u64().unwrap())
            .collect();
        assert_eq!(ids, vec![2, 5, 7]);
    }
}
