//! Human-readable reports and figure data: per-straggler annotations
//! (Figures 3–6 timelines), Table VI-style workload summaries, and CSV
//! emission for external plotting.

use super::bigroots::StageAnalysis;
use super::features::{FeatureKind, StageFeatures};
use crate::trace::JobTrace;
use crate::util::table::{fnum, Align, Table};

/// A straggler annotation: the black lines of Figures 3–6.
#[derive(Debug, Clone)]
pub struct StragglerAnnotation {
    pub task_id: u64,
    pub stage_id: u64,
    pub node: usize,
    pub start: f64,
    pub finish: f64,
    /// duration / stage median (right y-axis of Figures 3–6).
    pub scale: f64,
    /// Identified root-cause features (may be empty — unexplained).
    pub causes: Vec<FeatureKind>,
}

/// Collect annotations from per-stage analyses.
pub fn annotations(
    trace: &JobTrace,
    per_stage: &[(StageFeatures, StageAnalysis)],
) -> Vec<StragglerAnnotation> {
    let mut out = Vec::new();
    for (sf, a) in per_stage {
        for &row in &a.stragglers.rows {
            let task = trace
                .tasks
                .iter()
                .find(|t| t.task_id == sf.task_ids[row])
                .expect("annotation for unknown task");
            out.push(StragglerAnnotation {
                task_id: task.task_id,
                stage_id: sf.stage_id,
                node: task.node,
                start: task.start,
                finish: task.finish,
                scale: a.stragglers.scale(task.duration()),
                causes: a.causes_of(row).iter().map(|c| c.kind).collect(),
            });
        }
    }
    out.sort_by(|a, b| a.start.total_cmp(&b.start));
    out
}

/// Figure 3–6 data: per-second resource utilization of one node plus the
/// straggler annotations, as CSV ("time,cpu,disk,net_bytes" then a second
/// section "task_id,start,finish,scale,causes").
pub fn timeline_csv(trace: &JobTrace, node: usize, anns: &[StragglerAnnotation]) -> String {
    let s = trace.series(node);
    let mut out = String::from("time,cpu,disk,net_bytes\n");
    for i in 0..s.len() {
        out.push_str(&format!(
            "{},{},{},{}\n",
            i as f64 * s.period,
            fnum(s.cpu[i], 4),
            fnum(s.disk[i], 4),
            fnum(s.net_bytes[i], 0)
        ));
    }
    out.push_str("\ntask_id,node,start,finish,scale,causes\n");
    for a in anns.iter().filter(|a| a.node == node) {
        let causes: Vec<&str> = a.causes.iter().map(|k| k.name()).collect();
        out.push_str(&format!(
            "{},{},{},{},{},{}\n",
            a.task_id,
            a.node,
            fnum(a.start, 2),
            fnum(a.finish, 2),
            fnum(a.scale, 2),
            causes.join("|")
        ));
    }
    out
}

/// Table VI-style row for one workload: the identified root causes
/// histogram and the straggler count.
#[derive(Debug, Clone)]
pub struct WorkloadSummary {
    pub domain: String,
    pub workload: String,
    pub stragglers: usize,
    /// (feature, count) of identified causes, sorted descending.
    pub causes: Vec<(FeatureKind, usize)>,
}

/// Summarize a full job analysis.
pub fn summarize_workload(
    domain: &str,
    workload: &str,
    per_stage: &[(StageFeatures, StageAnalysis)],
) -> WorkloadSummary {
    let stragglers = per_stage.iter().map(|(_, a)| a.stragglers.rows.len()).sum();
    let mut hist: Vec<(FeatureKind, usize)> = FeatureKind::ALL
        .iter()
        .map(|&k| {
            (
                k,
                per_stage
                    .iter()
                    .map(|(_, a)| a.causes.iter().filter(|c| c.kind == k).count())
                    .sum(),
            )
        })
        .filter(|&(_, n)| n > 0)
        .collect();
    hist.sort_by(|a, b| b.1.cmp(&a.1));
    WorkloadSummary {
        domain: domain.to_string(),
        workload: workload.to_string(),
        stragglers,
        causes: hist,
    }
}

/// Render Table VI from workload summaries.
pub fn render_table6(rows: &[WorkloadSummary]) -> String {
    let mut t = Table::new("Table VI: Root cause analysis on Hibench workloads")
        .header(&["Domain", "Workload", "BigRoots Result", "# Stragglers"])
        .aligns(&[Align::Left, Align::Left, Align::Left, Align::Right]);
    for r in rows {
        let result = if r.causes.is_empty() {
            "-".to_string()
        } else {
            r.causes
                .iter()
                .map(|(k, n)| format!("{} ({})", k.name(), n))
                .collect::<Vec<_>>()
                .join(", ")
        };
        t.row(vec![
            r.domain.clone(),
            r.workload.clone(),
            result,
            r.stragglers.to_string(),
        ]);
    }
    t.render()
}

/// Render a verdict provenance document ([`super::explain::job_verdict_json`])
/// as a human-readable table — one row per flagged task/cause pair, with
/// the threshold, baselines and confidence that convicted it. Takes the
/// JSON form so the CLI can render replayed dumps and control-socket
/// responses alike.
pub fn render_explain(doc: &crate::util::json::Json) -> String {
    use crate::util::json::Json;
    let job = doc.get("job").as_str().unwrap_or("?");
    let conf = doc.get("max_confidence").as_f64().unwrap_or(0.0);
    let mut t = Table::new(&format!(
        "Verdict provenance: job {job} (max confidence {})",
        fnum(conf, 3)
    ))
    .header(&[
        "stage", "task", "cause", "value", "threshold", "peer", "stage med", "MAD",
        "fleet pct", "conf", "grp",
    ])
    .aligns(&[
        Align::Right,
        Align::Right,
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    let empty: [Json; 0] = [];
    for stage in doc.get("stages").as_arr().unwrap_or(&empty) {
        let sid = stage.get("stage").as_usize().unwrap_or(0);
        for c in stage.get("causes").as_arr().unwrap_or(&empty) {
            t.row(vec![
                sid.to_string(),
                c.get("task").as_usize().unwrap_or(0).to_string(),
                c.get("cause").as_str().unwrap_or("?").to_string(),
                fnum(c.get("value").as_f64().unwrap_or(0.0), 3),
                fnum(c.get("threshold").as_f64().unwrap_or(0.0), 3),
                c.get("peer").as_str().unwrap_or("?").to_string(),
                fnum(c.get("stage_median").as_f64().unwrap_or(0.0), 3),
                fnum(c.get("stage_mad").as_f64().unwrap_or(0.0), 3),
                match c.get("fleet_percentile").as_f64() {
                    Some(p) => fnum(p, 3),
                    None => "-".to_string(),
                },
                fnum(c.get("confidence").as_f64().unwrap_or(0.0), 3),
                c.get("group").as_usize().unwrap_or(0).to_string(),
            ]);
        }
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::bigroots::{analyze_stage, BigRootsConfig};
    use crate::analysis::features::extract_all;
    use crate::analysis::stats::NativeBackend;
    use crate::sim::{workloads, Engine, InjectionPlan, SimConfig};

    fn analyzed() -> (JobTrace, Vec<(StageFeatures, StageAnalysis)>) {
        let w = workloads::kmeans(0.2);
        let mut eng = Engine::new(SimConfig { seed: 31, ..Default::default() });
        let trace = eng.run("j", w.name, &w.stages, &InjectionPlan::none());
        let per_stage: Vec<_> = extract_all(&trace, 3.0)
            .into_iter()
            .map(|sf| {
                let a = analyze_stage(&sf, &mut NativeBackend::new(), &BigRootsConfig::default());
                (sf, a)
            })
            .collect();
        (trace, per_stage)
    }

    #[test]
    fn render_explain_tables_every_cause_row() {
        use crate::analysis::explain::{explain_stage, job_verdict_json};
        let w = workloads::wordcount(0.25);
        let mut eng = Engine::new(SimConfig { seed: 17, ..Default::default() });
        let plan = crate::sim::InjectionPlan::intermittent(
            crate::trace::AnomalyKind::Cpu,
            1,
            15.0,
            10.0,
            300.0,
        );
        let trace = eng.run("j", w.name, &w.stages, &plan);
        let cfg = BigRootsConfig::default();
        let traces: Vec<_> = extract_all(&trace, cfg.edge_width)
            .into_iter()
            .map(|sf| {
                let a = analyze_stage(&sf, &mut NativeBackend::new(), &cfg);
                explain_stage(&sf, &a, &[])
            })
            .collect();
        let total: usize = traces.iter().map(|t| t.causes.len()).sum();
        assert!(total > 0, "injected run should convict at least one cause");
        let doc = job_verdict_json(7, 0, &traces);
        let text = render_explain(&doc);
        assert!(text.contains("Verdict provenance: job 7"));
        for tr in &traces {
            for c in &tr.causes {
                assert!(text.contains(c.kind.name()));
            }
        }
    }

    #[test]
    fn annotations_are_time_sorted_stragglers() {
        let (trace, per_stage) = analyzed();
        let anns = annotations(&trace, &per_stage);
        for w in anns.windows(2) {
            assert!(w[0].start <= w[1].start);
        }
        for a in &anns {
            assert!(a.scale > 1.5, "annotation scale {}", a.scale);
            assert!(a.finish > a.start);
        }
    }

    #[test]
    fn timeline_csv_has_both_sections() {
        let (trace, per_stage) = analyzed();
        let anns = annotations(&trace, &per_stage);
        let csv = timeline_csv(&trace, 0, &anns);
        assert!(csv.starts_with("time,cpu,disk,net_bytes\n"));
        assert!(csv.contains("task_id,node,start,finish,scale,causes"));
        let lines = csv.lines().count();
        assert!(lines > trace.series(0).len(), "one line per sample plus annotations");
    }

    #[test]
    fn workload_summary_counts() {
        let (_, per_stage) = analyzed();
        let s = summarize_workload("Machine Learning", "Kmeans", &per_stage);
        assert_eq!(s.workload, "Kmeans");
        let total: usize = per_stage.iter().map(|(_, a)| a.stragglers.rows.len()).sum();
        assert_eq!(s.stragglers, total);
        // Histogram sorted descending.
        for w in s.causes.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
    }

    #[test]
    fn table6_renders_dash_for_no_causes() {
        let rows = vec![WorkloadSummary {
            domain: "Micro".into(),
            workload: "Terasort".into(),
            stragglers: 2,
            causes: vec![],
        }];
        let s = render_table6(&rows);
        assert!(s.contains("Terasort"));
        assert!(s.contains(" - "));
    }
}
