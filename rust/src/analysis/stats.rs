//! Per-stage batched feature statistics — the numeric hot path.
//!
//! Everything the identification rules (Eq. 5–8) need is reduced here from
//! the `tasks × features` matrix in one pass:
//!
//! - per-feature mean / std / Pearson correlation with duration,
//! - a quantile grid (λ_q is swept over this grid during ROC experiments),
//! - per-node sums and counts (peer means for inter-/intra-node groups are
//!   derived by exclusion, so no per-straggler recomputation is needed).
//!
//! Two interchangeable backends produce [`StageStats`]:
//! [`NativeBackend`] (pure rust, below) and the PJRT-executed AOT kernel
//! (`crate::runtime::XlaBackend`) compiled from the L1 Pallas kernels.
//! Parity between them is tested in `rust/tests/`.

use std::collections::HashMap;

use super::cache::CacheCounters;
use super::features::{FeatureKind, StageFeatures};

/// Number of quantile grid points: q = i / (GRID_Q - 1), i ∈ 0..GRID_Q.
pub const GRID_Q: usize = 21;

/// The quantile grid values (0.00, 0.05, …, 1.00).
pub fn quantile_grid() -> Vec<f64> {
    (0..GRID_Q).map(|i| i as f64 / (GRID_Q - 1) as f64).collect()
}

/// Batched statistics of one stage's feature matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct StageStats {
    pub count: usize,
    /// Per-feature sum, `[F]`.
    pub col_sum: Vec<f64>,
    /// Per-feature mean, `[F]`.
    pub col_mean: Vec<f64>,
    /// Per-feature population std, `[F]`.
    pub col_std: Vec<f64>,
    /// Pearson correlation of each feature with task duration, `[F]`.
    pub pearson: Vec<f64>,
    /// Quantile values, row-major `[GRID_Q × F]`.
    pub quantiles: Vec<f64>,
    /// Distinct node ids present in the stage.
    pub nodes: Vec<usize>,
    /// Per-node feature sums, row-major `[nodes.len() × F]`.
    pub node_sum: Vec<f64>,
    /// Per-node task counts, `[nodes.len()]`.
    pub node_count: Vec<usize>,
}

impl StageStats {
    /// Quantile of feature `k` at probability `q`, linearly interpolated on
    /// the grid (grid resolution 1/(GRID_Q-1) = 0.05).
    pub fn quantile(&self, k: FeatureKind, q: f64) -> f64 {
        let f = FeatureKind::COUNT;
        let q = q.clamp(0.0, 1.0);
        let pos = q * (GRID_Q - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let v_lo = self.quantiles[lo * f + k.index()];
        if lo == hi {
            return v_lo;
        }
        let v_hi = self.quantiles[hi * f + k.index()];
        let frac = pos - lo as f64;
        v_lo * (1.0 - frac) + v_hi * frac
    }

    fn node_slot(&self, node: usize) -> Option<usize> {
        self.nodes.iter().position(|&n| n == node)
    }

    /// Mean of feature `k` over *inter-node peers* of a task on `node`
    /// (all stage tasks on other nodes). None if the stage has no tasks on
    /// other nodes.
    pub fn inter_node_mean(&self, node: usize, k: FeatureKind) -> Option<f64> {
        let f = FeatureKind::COUNT;
        let slot = self.node_slot(node)?;
        let n_other = self.count - self.node_count[slot];
        if n_other == 0 {
            return None;
        }
        let sum_other = self.col_sum[k.index()] - self.node_sum[slot * f + k.index()];
        Some(sum_other / n_other as f64)
    }

    /// Mean of feature `k` over *intra-node peers* of a task on `node` with
    /// feature value `own` (other stage tasks on the same node). None if the
    /// task is alone on its node.
    pub fn intra_node_mean(&self, node: usize, k: FeatureKind, own: f64) -> Option<f64> {
        let f = FeatureKind::COUNT;
        let slot = self.node_slot(node)?;
        let n_here = self.node_count[slot];
        if n_here <= 1 {
            return None;
        }
        let sum_here = self.node_sum[slot * f + k.index()] - own;
        Some(sum_here / (n_here - 1) as f64)
    }
}

/// Backend interface: compute [`StageStats`] from a stage feature matrix.
/// Implemented natively below and by the XLA runtime.
pub trait StatsBackend {
    fn stage_stats(&mut self, sf: &StageFeatures) -> StageStats;

    /// Compute stats for a batch of ready stages in one dispatch. Backends
    /// with per-call overhead (device transfer, artifact selection) can
    /// override this to amortize it; the default just loops. The streaming
    /// [`crate::coordinator::service::AnalysisService`] and the offline
    /// pipeline both route through this entry point.
    fn stage_stats_batch(&mut self, sfs: &[&StageFeatures]) -> Vec<StageStats> {
        sfs.iter().map(|sf| self.stage_stats(sf)).collect()
    }

    /// Human-readable backend name (for reports / perf logs).
    fn name(&self) -> &'static str;

    /// Memoization hit/miss counters, for backends that cache
    /// ([`crate::analysis::cache::CachedBackend`]). None for backends that
    /// recompute every call.
    fn cache_counters(&self) -> Option<CacheCounters> {
        None
    }
}

// Boxed backends forward the whole contract, so wrappers like
// `CachedBackend<Box<dyn StatsBackend>>` compose with dynamic dispatch.
impl<T: StatsBackend + ?Sized> StatsBackend for Box<T> {
    fn stage_stats(&mut self, sf: &StageFeatures) -> StageStats {
        (**self).stage_stats(sf)
    }

    fn stage_stats_batch(&mut self, sfs: &[&StageFeatures]) -> Vec<StageStats> {
        (**self).stage_stats_batch(sfs)
    }

    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn cache_counters(&self) -> Option<CacheCounters> {
        (**self).cache_counters()
    }
}

/// Reusable working memory for [`compute_native_with`]: everything the
/// kernel needs beyond the output [`StageStats`] itself. One scratch lives
/// inside each [`NativeBackend`] (one backend per service worker / shard
/// thread), so the per-stage intermediate buffers are allocated once per
/// worker instead of ~10 fresh vectors per stage analysis.
#[derive(Debug, Default, Clone)]
pub struct StatsScratch {
    /// Per-feature Σv² (intermediate — only mean/std are returned).
    col_sumsq: Vec<f64>,
    /// Per-feature Σv·duration (intermediate for Pearson).
    col_dot_dur: Vec<f64>,
    /// node id → slot, O(1) instead of the former `Vec::position` scan.
    node_slots: HashMap<usize, usize>,
    /// Slot of each row, so the accumulation loop does no lookups.
    node_of_row: Vec<usize>,
    /// One feature column, reused for the quantile selection.
    col_buf: Vec<f64>,
    /// Order-statistic indices needed by the quantile grid (depends only
    /// on the row count, so it is computed once per stage, not per column).
    order_idxs: Vec<usize>,
}

/// Pure-rust reference backend (also the fallback when `artifacts/` is
/// absent). Single-threaded; reuses a [`StatsScratch`] across calls, so
/// steady-state cost is the arithmetic plus the output allocations only.
#[derive(Debug, Default, Clone)]
pub struct NativeBackend {
    scratch: StatsScratch,
}

impl NativeBackend {
    pub fn new() -> Self {
        Self::default()
    }
}

impl StatsBackend for NativeBackend {
    fn stage_stats(&mut self, sf: &StageFeatures) -> StageStats {
        compute_native_with(sf, &mut self.scratch)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// The native computation with a throwaway scratch — convenience for tests
/// and one-shot callers. Hot paths go through [`NativeBackend`] /
/// [`compute_native_with`] to reuse buffers.
pub fn compute_native(sf: &StageFeatures) -> StageStats {
    compute_native_with(sf, &mut StatsScratch::default())
}

/// The native computation. Bit-identical to the historical sort-based
/// kernel: accumulation order is unchanged, and the quantile grid reads
/// the same order statistics (selected, not obtained via a full sort).
pub fn compute_native_with(sf: &StageFeatures, scratch: &mut StatsScratch) -> StageStats {
    let f = FeatureKind::COUNT;
    let n = sf.num_tasks();
    let mut col_sum = vec![0.0f64; f];
    scratch.col_sumsq.clear();
    scratch.col_sumsq.resize(f, 0.0);
    scratch.col_dot_dur.clear();
    scratch.col_dot_dur.resize(f, 0.0);
    let col_sumsq = &mut scratch.col_sumsq;
    let col_dot_dur = &mut scratch.col_dot_dur;
    let mut dur_sum = 0.0f64;
    let mut dur_sumsq = 0.0f64;

    // Node slots in first-appearance order (hash-mapped: O(rows), not
    // O(rows × nodes)).
    let mut nodes: Vec<usize> = Vec::new();
    scratch.node_slots.clear();
    scratch.node_of_row.clear();
    scratch.node_of_row.reserve(n);
    for &nd in &sf.nodes {
        let slot = *scratch.node_slots.entry(nd).or_insert_with(|| {
            nodes.push(nd);
            nodes.len() - 1
        });
        scratch.node_of_row.push(slot);
    }
    let mut node_sum = vec![0.0f64; nodes.len() * f];
    let mut node_count = vec![0usize; nodes.len()];

    for row in 0..n {
        let d = sf.durations[row];
        dur_sum += d;
        dur_sumsq += d * d;
        let slot = scratch.node_of_row[row];
        node_count[slot] += 1;
        let base = row * f;
        for k in 0..f {
            let v = sf.matrix[base + k];
            col_sum[k] += v;
            col_sumsq[k] += v * v;
            col_dot_dur[k] += v * d;
            node_sum[slot * f + k] += v;
        }
    }

    let nf = n as f64;
    let col_mean: Vec<f64> = col_sum.iter().map(|s| if n > 0 { s / nf } else { 0.0 }).collect();
    let col_var: Vec<f64> = (0..f)
        .map(|k| if n > 0 { (col_sumsq[k] / nf - col_mean[k] * col_mean[k]).max(0.0) } else { 0.0 })
        .collect();
    let col_std: Vec<f64> = col_var.iter().map(|v| v.sqrt()).collect();
    let dur_mean = if n > 0 { dur_sum / nf } else { 0.0 };
    let dur_var = if n > 0 { (dur_sumsq / nf - dur_mean * dur_mean).max(0.0) } else { 0.0 };

    let pearson: Vec<f64> = (0..f)
        .map(|k| {
            if n < 2 {
                return 0.0;
            }
            let cov = col_dot_dur[k] / nf - col_mean[k] * dur_mean;
            let denom = (col_var[k] * dur_var).sqrt();
            if denom <= 1e-30 {
                0.0
            } else {
                (cov / denom).clamp(-1.0, 1.0)
            }
        })
        .collect();

    // Quantile grid: the grid needs at most 2·GRID_Q order statistics per
    // column, so select exactly those instead of fully sorting. `total_cmp`
    // keeps NaN feature values (degenerate input) from panicking — they
    // sort to the top like an ordinary largest value.
    let mut quantiles = vec![0.0f64; GRID_Q * f];
    if n > 0 {
        let idxs = &mut scratch.order_idxs;
        idxs.clear();
        for qi in 0..GRID_Q {
            let q = qi as f64 / (GRID_Q - 1) as f64;
            let pos = q * (n - 1) as f64;
            idxs.push(pos.floor() as usize);
            idxs.push(pos.ceil() as usize);
        }
        idxs.sort_unstable();
        idxs.dedup();
        let col_buf = &mut scratch.col_buf;
        for k in 0..f {
            col_buf.clear();
            col_buf.extend((0..n).map(|r| sf.matrix[r * f + k]));
            select_order_stats(col_buf, idxs, 0);
            for qi in 0..GRID_Q {
                let q = qi as f64 / (GRID_Q - 1) as f64;
                let pos = q * (n - 1) as f64;
                let lo = pos.floor() as usize;
                let hi = pos.ceil() as usize;
                quantiles[qi * f + k] = if lo == hi {
                    col_buf[lo]
                } else {
                    let frac = pos - lo as f64;
                    col_buf[lo] * (1.0 - frac) + col_buf[hi] * frac
                };
            }
        }
    }

    StageStats {
        count: n,
        col_sum,
        col_mean,
        col_std,
        pearson,
        quantiles,
        nodes,
        node_sum,
        node_count,
    }
}

/// Place every order statistic in `idxs` (sorted, deduped, indices into the
/// *whole* column; `base` is the offset of `data` within it) at its sorted
/// position, by divide-and-conquer `select_nth_unstable_by`: one selection
/// per grid point on an ever-shrinking slice — O(n log grid) instead of the
/// full O(n log n) sort.
fn select_order_stats(data: &mut [f64], idxs: &[usize], base: usize) {
    if idxs.is_empty() || data.is_empty() {
        return;
    }
    let mid = idxs.len() / 2;
    let k = idxs[mid] - base;
    let (lo, _, hi) = data.select_nth_unstable_by(k, |a, b| a.total_cmp(b));
    select_order_stats(lo, &idxs[..mid], base);
    select_order_stats(hi, &idxs[mid + 1..], base + k + 1);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::features::FeatureKind as F;

    /// Hand-built StageFeatures: 4 tasks, 2 nodes.
    fn sf() -> StageFeatures {
        let f = F::COUNT;
        let mut matrix = vec![0.0; 4 * f];
        // bytes_read column: 1, 2, 3, 10 ; cpu column: .1 .2 .3 .4
        let br = F::BytesRead.index();
        let cpu = F::Cpu.index();
        for (r, (b, c)) in [(1.0, 0.1), (2.0, 0.2), (3.0, 0.3), (10.0, 0.4)].iter().enumerate() {
            matrix[r * f + br] = *b;
            matrix[r * f + cpu] = *c;
        }
        StageFeatures {
            stage_id: 0,
            task_ids: vec![0, 1, 2, 3],
            nodes: vec![0, 0, 1, 1],
            durations: vec![1.0, 2.0, 3.0, 10.0],
            matrix,
            head_means: vec![0.0; 12],
            tail_means: vec![0.0; 12],
        }
    }

    #[test]
    fn means_and_sums() {
        let s = compute_native(&sf());
        assert_eq!(s.count, 4);
        assert!((s.col_mean[F::BytesRead.index()] - 4.0).abs() < 1e-12);
        assert!((s.col_sum[F::Cpu.index()] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_matches_scalar_impl() {
        let s = compute_native(&sf());
        let expect = crate::util::stats::pearson(&[1.0, 2.0, 3.0, 10.0], &[1.0, 2.0, 3.0, 10.0]);
        assert!((s.pearson[F::BytesRead.index()] - expect).abs() < 1e-12);
        assert!((s.pearson[F::BytesRead.index()] - 1.0).abs() < 1e-12); // identical vectors
        let e2 = crate::util::stats::pearson(&[0.1, 0.2, 0.3, 0.4], &[1.0, 2.0, 3.0, 10.0]);
        assert!((s.pearson[F::Cpu.index()] - e2).abs() < 1e-12);
        // Constant column → 0 correlation.
        assert_eq!(s.pearson[F::Locality.index()], 0.0);
    }

    #[test]
    fn quantile_grid_interpolates() {
        let s = compute_native(&sf());
        assert!((s.quantile(F::BytesRead, 0.0) - 1.0).abs() < 1e-12);
        assert!((s.quantile(F::BytesRead, 1.0) - 10.0).abs() < 1e-12);
        assert!((s.quantile(F::BytesRead, 0.5) - 2.5).abs() < 1e-12);
        // Off-grid q interpolates smoothly and monotonically.
        let q1 = s.quantile(F::BytesRead, 0.62);
        let q2 = s.quantile(F::BytesRead, 0.63);
        assert!(q2 >= q1);
    }

    #[test]
    fn peer_means_by_exclusion() {
        let s = compute_native(&sf());
        // Task on node 0: inter-node peers are rows 2,3 → bytes mean 6.5.
        assert!((s.inter_node_mean(0, F::BytesRead).unwrap() - 6.5).abs() < 1e-12);
        // Row 0 (value 1.0) on node 0: intra peer is row 1 → mean 2.0.
        assert!((s.intra_node_mean(0, F::BytesRead, 1.0).unwrap() - 2.0).abs() < 1e-12);
        // Unknown node → None.
        assert!(s.inter_node_mean(9, F::BytesRead).is_none());
    }

    #[test]
    fn intra_none_when_alone() {
        let mut x = sf();
        x.nodes = vec![0, 1, 2, 3]; // every task alone on its node
        let s = compute_native(&x);
        assert!(s.intra_node_mean(0, F::BytesRead, 1.0).is_none());
        // All inter-node means exist.
        assert!(s.inter_node_mean(0, F::BytesRead).is_some());
    }

    #[test]
    fn inter_none_when_single_node() {
        let mut x = sf();
        x.nodes = vec![5, 5, 5, 5];
        let s = compute_native(&x);
        assert!(s.inter_node_mean(5, F::BytesRead).is_none());
        assert!(s.intra_node_mean(5, F::BytesRead, 1.0).is_some());
    }

    #[test]
    fn empty_stage_is_safe() {
        let x = StageFeatures {
            stage_id: 0,
            task_ids: vec![],
            nodes: vec![],
            durations: vec![],
            matrix: vec![],
            head_means: vec![],
            tail_means: vec![],
        };
        let s = compute_native(&x);
        assert_eq!(s.count, 0);
        assert_eq!(s.col_mean[0], 0.0);
        assert_eq!(s.pearson[0], 0.0);
    }

    #[test]
    fn backend_trait_dispatch() {
        let mut b = NativeBackend::new();
        let s = b.stage_stats(&sf());
        assert_eq!(s, compute_native(&sf()));
        assert_eq!(b.name(), "native");
        assert!(b.cache_counters().is_none());
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        // The same backend (warm scratch) must produce identical results
        // across differently-shaped stages, including after shrinking.
        let mut b = NativeBackend::new();
        let big = sf();
        let mut small = sf();
        small.task_ids.truncate(2);
        small.nodes.truncate(2);
        small.durations.truncate(2);
        small.matrix.truncate(2 * F::COUNT);
        for stage in [&big, &small, &big, &small] {
            assert_eq!(b.stage_stats(stage), compute_native(stage));
        }
    }

    #[test]
    fn selected_quantiles_match_full_sort() {
        // The multi-select kernel must read the exact same order statistics
        // a full sort would produce, on adversarial value patterns.
        let mut rng = crate::util::rng::Pcg64::seeded(31);
        for n in [1usize, 2, 3, 7, 50, 257] {
            let f = F::COUNT;
            let mut matrix = vec![0.0; n * f];
            for v in matrix.iter_mut() {
                // Mix of duplicates and spread values.
                *v = (rng.below(7) as f64) * rng.range_f64(0.0, 10.0);
            }
            let x = StageFeatures {
                stage_id: 0,
                task_ids: (0..n as u64).collect(),
                nodes: (0..n).map(|r| r % 3).collect(),
                durations: (0..n).map(|r| 1.0 + r as f64).collect(),
                matrix,
                head_means: vec![0.0; n * 3],
                tail_means: vec![0.0; n * 3],
            };
            let s = compute_native(&x);
            for k in 0..f {
                let mut col: Vec<f64> = (0..n).map(|r| x.matrix[r * f + k]).collect();
                col.sort_by(|a, b| a.total_cmp(b));
                for (qi, &q) in quantile_grid().iter().enumerate() {
                    let want = crate::util::stats::quantile_sorted(&col, q);
                    assert_eq!(s.quantiles[qi * f + k], want, "n={n} k={k} q={q}");
                }
            }
        }
    }

    #[test]
    fn nan_feature_value_does_not_panic() {
        // Regression: the old kernel sorted with partial_cmp().unwrap(),
        // which panics on NaN. NaN now sorts like a largest value.
        let mut x = sf();
        x.matrix[F::BytesRead.index()] = f64::NAN;
        let s = compute_native(&x);
        assert_eq!(s.count, 4);
        // The max quantile of the poisoned column is NaN; others are sane.
        assert!(s.quantile(F::BytesRead, 1.0).is_nan());
        assert!(s.quantile(F::Cpu, 1.0).is_finite());
    }
}
