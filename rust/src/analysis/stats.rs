//! Per-stage batched feature statistics — the numeric hot path.
//!
//! Everything the identification rules (Eq. 5–8) need is reduced here from
//! the `tasks × features` matrix in one pass:
//!
//! - per-feature mean / std / Pearson correlation with duration,
//! - a quantile grid (λ_q is swept over this grid during ROC experiments),
//! - per-node sums and counts (peer means for inter-/intra-node groups are
//!   derived by exclusion, so no per-straggler recomputation is needed).
//!
//! Two interchangeable backends produce [`StageStats`]:
//! [`NativeBackend`] (pure rust, below) and the PJRT-executed AOT kernel
//! (`crate::runtime::XlaBackend`) compiled from the L1 Pallas kernels.
//! Parity between them is tested in `rust/tests/`.

use super::features::{FeatureKind, StageFeatures};

/// Number of quantile grid points: q = i / (GRID_Q - 1), i ∈ 0..GRID_Q.
pub const GRID_Q: usize = 21;

/// The quantile grid values (0.00, 0.05, …, 1.00).
pub fn quantile_grid() -> Vec<f64> {
    (0..GRID_Q).map(|i| i as f64 / (GRID_Q - 1) as f64).collect()
}

/// Batched statistics of one stage's feature matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct StageStats {
    pub count: usize,
    /// Per-feature sum, `[F]`.
    pub col_sum: Vec<f64>,
    /// Per-feature mean, `[F]`.
    pub col_mean: Vec<f64>,
    /// Per-feature population std, `[F]`.
    pub col_std: Vec<f64>,
    /// Pearson correlation of each feature with task duration, `[F]`.
    pub pearson: Vec<f64>,
    /// Quantile values, row-major `[GRID_Q × F]`.
    pub quantiles: Vec<f64>,
    /// Distinct node ids present in the stage.
    pub nodes: Vec<usize>,
    /// Per-node feature sums, row-major `[nodes.len() × F]`.
    pub node_sum: Vec<f64>,
    /// Per-node task counts, `[nodes.len()]`.
    pub node_count: Vec<usize>,
}

impl StageStats {
    /// Quantile of feature `k` at probability `q`, linearly interpolated on
    /// the grid (grid resolution 1/(GRID_Q-1) = 0.05).
    pub fn quantile(&self, k: FeatureKind, q: f64) -> f64 {
        let f = FeatureKind::COUNT;
        let q = q.clamp(0.0, 1.0);
        let pos = q * (GRID_Q - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let v_lo = self.quantiles[lo * f + k.index()];
        if lo == hi {
            return v_lo;
        }
        let v_hi = self.quantiles[hi * f + k.index()];
        let frac = pos - lo as f64;
        v_lo * (1.0 - frac) + v_hi * frac
    }

    fn node_slot(&self, node: usize) -> Option<usize> {
        self.nodes.iter().position(|&n| n == node)
    }

    /// Mean of feature `k` over *inter-node peers* of a task on `node`
    /// (all stage tasks on other nodes). None if the stage has no tasks on
    /// other nodes.
    pub fn inter_node_mean(&self, node: usize, k: FeatureKind) -> Option<f64> {
        let f = FeatureKind::COUNT;
        let slot = self.node_slot(node)?;
        let n_other = self.count - self.node_count[slot];
        if n_other == 0 {
            return None;
        }
        let sum_other = self.col_sum[k.index()] - self.node_sum[slot * f + k.index()];
        Some(sum_other / n_other as f64)
    }

    /// Mean of feature `k` over *intra-node peers* of a task on `node` with
    /// feature value `own` (other stage tasks on the same node). None if the
    /// task is alone on its node.
    pub fn intra_node_mean(&self, node: usize, k: FeatureKind, own: f64) -> Option<f64> {
        let f = FeatureKind::COUNT;
        let slot = self.node_slot(node)?;
        let n_here = self.node_count[slot];
        if n_here <= 1 {
            return None;
        }
        let sum_here = self.node_sum[slot * f + k.index()] - own;
        Some(sum_here / (n_here - 1) as f64)
    }
}

/// Backend interface: compute [`StageStats`] from a stage feature matrix.
/// Implemented natively below and by the XLA runtime.
pub trait StatsBackend {
    fn stage_stats(&mut self, sf: &StageFeatures) -> StageStats;

    /// Compute stats for a batch of ready stages in one dispatch. Backends
    /// with per-call overhead (device transfer, artifact selection) can
    /// override this to amortize it; the default just loops. The streaming
    /// [`crate::coordinator::service::AnalysisService`] and the offline
    /// pipeline both route through this entry point.
    fn stage_stats_batch(&mut self, sfs: &[&StageFeatures]) -> Vec<StageStats> {
        sfs.iter().map(|sf| self.stage_stats(sf)).collect()
    }

    /// Human-readable backend name (for reports / perf logs).
    fn name(&self) -> &'static str;
}

/// Pure-rust reference backend (also the fallback when `artifacts/` is
/// absent). Single-threaded, allocation-light.
#[derive(Debug, Default, Clone)]
pub struct NativeBackend;

impl StatsBackend for NativeBackend {
    fn stage_stats(&mut self, sf: &StageFeatures) -> StageStats {
        compute_native(sf)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// The native computation, shared with tests.
pub fn compute_native(sf: &StageFeatures) -> StageStats {
    let f = FeatureKind::COUNT;
    let n = sf.num_tasks();
    let mut col_sum = vec![0.0f64; f];
    let mut col_sumsq = vec![0.0f64; f];
    let mut col_dot_dur = vec![0.0f64; f];
    let mut dur_sum = 0.0f64;
    let mut dur_sumsq = 0.0f64;

    // Node slots in first-appearance order.
    let mut nodes: Vec<usize> = Vec::new();
    let mut node_of_row: Vec<usize> = Vec::with_capacity(n);
    for &nd in &sf.nodes {
        let slot = match nodes.iter().position(|&x| x == nd) {
            Some(s) => s,
            None => {
                nodes.push(nd);
                nodes.len() - 1
            }
        };
        node_of_row.push(slot);
    }
    let mut node_sum = vec![0.0f64; nodes.len() * f];
    let mut node_count = vec![0usize; nodes.len()];

    for row in 0..n {
        let d = sf.durations[row];
        dur_sum += d;
        dur_sumsq += d * d;
        let slot = node_of_row[row];
        node_count[slot] += 1;
        let base = row * f;
        for k in 0..f {
            let v = sf.matrix[base + k];
            col_sum[k] += v;
            col_sumsq[k] += v * v;
            col_dot_dur[k] += v * d;
            node_sum[slot * f + k] += v;
        }
    }

    let nf = n as f64;
    let col_mean: Vec<f64> = col_sum.iter().map(|s| if n > 0 { s / nf } else { 0.0 }).collect();
    let col_var: Vec<f64> = (0..f)
        .map(|k| if n > 0 { (col_sumsq[k] / nf - col_mean[k] * col_mean[k]).max(0.0) } else { 0.0 })
        .collect();
    let col_std: Vec<f64> = col_var.iter().map(|v| v.sqrt()).collect();
    let dur_mean = if n > 0 { dur_sum / nf } else { 0.0 };
    let dur_var = if n > 0 { (dur_sumsq / nf - dur_mean * dur_mean).max(0.0) } else { 0.0 };

    let pearson: Vec<f64> = (0..f)
        .map(|k| {
            if n < 2 {
                return 0.0;
            }
            let cov = col_dot_dur[k] / nf - col_mean[k] * dur_mean;
            let denom = (col_var[k] * dur_var).sqrt();
            if denom <= 1e-30 {
                0.0
            } else {
                (cov / denom).clamp(-1.0, 1.0)
            }
        })
        .collect();

    // Quantile grid: sort each column once.
    let mut quantiles = vec![0.0f64; GRID_Q * f];
    let grid = quantile_grid();
    let mut col_buf: Vec<f64> = Vec::with_capacity(n);
    for k in 0..f {
        col_buf.clear();
        col_buf.extend((0..n).map(|r| sf.matrix[r * f + k]));
        col_buf.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (qi, &q) in grid.iter().enumerate() {
            quantiles[qi * f + k] = crate::util::stats::quantile_sorted(&col_buf, q);
        }
    }

    StageStats {
        count: n,
        col_sum,
        col_mean,
        col_std,
        pearson,
        quantiles,
        nodes,
        node_sum,
        node_count,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::features::FeatureKind as F;

    /// Hand-built StageFeatures: 4 tasks, 2 nodes.
    fn sf() -> StageFeatures {
        let f = F::COUNT;
        let mut matrix = vec![0.0; 4 * f];
        // bytes_read column: 1, 2, 3, 10 ; cpu column: .1 .2 .3 .4
        let br = F::BytesRead.index();
        let cpu = F::Cpu.index();
        for (r, (b, c)) in [(1.0, 0.1), (2.0, 0.2), (3.0, 0.3), (10.0, 0.4)].iter().enumerate() {
            matrix[r * f + br] = *b;
            matrix[r * f + cpu] = *c;
        }
        StageFeatures {
            stage_id: 0,
            task_ids: vec![0, 1, 2, 3],
            nodes: vec![0, 0, 1, 1],
            durations: vec![1.0, 2.0, 3.0, 10.0],
            matrix,
            head_means: vec![0.0; 12],
            tail_means: vec![0.0; 12],
        }
    }

    #[test]
    fn means_and_sums() {
        let s = compute_native(&sf());
        assert_eq!(s.count, 4);
        assert!((s.col_mean[F::BytesRead.index()] - 4.0).abs() < 1e-12);
        assert!((s.col_sum[F::Cpu.index()] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_matches_scalar_impl() {
        let s = compute_native(&sf());
        let expect = crate::util::stats::pearson(&[1.0, 2.0, 3.0, 10.0], &[1.0, 2.0, 3.0, 10.0]);
        assert!((s.pearson[F::BytesRead.index()] - expect).abs() < 1e-12);
        assert!((s.pearson[F::BytesRead.index()] - 1.0).abs() < 1e-12); // identical vectors
        let e2 = crate::util::stats::pearson(&[0.1, 0.2, 0.3, 0.4], &[1.0, 2.0, 3.0, 10.0]);
        assert!((s.pearson[F::Cpu.index()] - e2).abs() < 1e-12);
        // Constant column → 0 correlation.
        assert_eq!(s.pearson[F::Locality.index()], 0.0);
    }

    #[test]
    fn quantile_grid_interpolates() {
        let s = compute_native(&sf());
        assert!((s.quantile(F::BytesRead, 0.0) - 1.0).abs() < 1e-12);
        assert!((s.quantile(F::BytesRead, 1.0) - 10.0).abs() < 1e-12);
        assert!((s.quantile(F::BytesRead, 0.5) - 2.5).abs() < 1e-12);
        // Off-grid q interpolates smoothly and monotonically.
        let q1 = s.quantile(F::BytesRead, 0.62);
        let q2 = s.quantile(F::BytesRead, 0.63);
        assert!(q2 >= q1);
    }

    #[test]
    fn peer_means_by_exclusion() {
        let s = compute_native(&sf());
        // Task on node 0: inter-node peers are rows 2,3 → bytes mean 6.5.
        assert!((s.inter_node_mean(0, F::BytesRead).unwrap() - 6.5).abs() < 1e-12);
        // Row 0 (value 1.0) on node 0: intra peer is row 1 → mean 2.0.
        assert!((s.intra_node_mean(0, F::BytesRead, 1.0).unwrap() - 2.0).abs() < 1e-12);
        // Unknown node → None.
        assert!(s.inter_node_mean(9, F::BytesRead).is_none());
    }

    #[test]
    fn intra_none_when_alone() {
        let mut x = sf();
        x.nodes = vec![0, 1, 2, 3]; // every task alone on its node
        let s = compute_native(&x);
        assert!(s.intra_node_mean(0, F::BytesRead, 1.0).is_none());
        // All inter-node means exist.
        assert!(s.inter_node_mean(0, F::BytesRead).is_some());
    }

    #[test]
    fn inter_none_when_single_node() {
        let mut x = sf();
        x.nodes = vec![5, 5, 5, 5];
        let s = compute_native(&x);
        assert!(s.inter_node_mean(5, F::BytesRead).is_none());
        assert!(s.intra_node_mean(5, F::BytesRead, 1.0).is_some());
    }

    #[test]
    fn empty_stage_is_safe() {
        let x = StageFeatures {
            stage_id: 0,
            task_ids: vec![],
            nodes: vec![],
            durations: vec![],
            matrix: vec![],
            head_means: vec![],
            tail_means: vec![],
        };
        let s = compute_native(&x);
        assert_eq!(s.count, 0);
        assert_eq!(s.col_mean[0], 0.0);
        assert_eq!(s.pearson[0], 0.0);
    }

    #[test]
    fn backend_trait_dispatch() {
        let mut b = NativeBackend;
        let s = b.stage_stats(&sf());
        assert_eq!(s, compute_native(&sf()));
        assert_eq!(b.name(), "native");
    }
}
