//! Counterfactual what-if engine: rank root causes by **estimated job
//! completion time saved**, not by incidence.
//!
//! The source paper stops at naming a straggler's cause; "Understanding
//! Stragglers in Large Model Training Using What-if Analysis" (arxiv
//! 2505.05713) closes the gap by asking, per cause: *how much faster would
//! this job have finished if that cause were removed?* This module answers
//! that question from the two things we already own — the observed
//! per-stage features/verdicts and the deterministic replay scheduler
//! ([`crate::sim::replay`]):
//!
//! 1. replay the observed per-task durations through the slot scheduler →
//!    the **baseline** completion time;
//! 2. for each detected cause kind, rebuild the durations with that cause
//!    **neutralized** on exactly the tasks where BigRoots detected it, and
//!    replay again → the **counterfactual** completion time;
//! 3. report `saved = baseline − counterfactual` per cause, ranked.
//!
//! Neutralization semantics per feature category (see `docs/WHATIF.md`):
//!
//! | category | neutralizer |
//! |----------|-------------|
//! | time (`jvm_gc_time`) | GC time zeroed: `dur ← dur·(1 − gc_frac)` |
//! | time (ser/deser) | excess over the benign target removed |
//! | numerical (shuffle-read, bytes-read, spills) | bytes normalized to the benign target; the duration credit is the stage's fitted seconds-per-ratio slope × the excess |
//! | resource (cpu/disk/network) | slow node swapped to fleet-median speed: the node's slowdown factor versus the reference median is divided out |
//! | discrete (locality) | remote read replaced by a median local task |
//!
//! The *benign target* is the within-stage median of the feature column —
//! or, when a warm [`FleetReport`] baseline is supplied, the fleet-wide
//! p50 of that (already peer-normalized) feature. The slow-node reference
//! likewise tightens to the fleet median of stage medians when available.
//! A neutralized duration never increases and never drops below
//! `min_duration_frac` of the original.
//!
//! **Determinism:** every step is closed-form `f64` arithmetic in a fixed
//! order over `(trace, seed)` — same inputs, bit-identical ranking
//! (`rust/tests/whatif_integration.rs` asserts it). The seed is carried in
//! the report so future stochastic replay extensions stay keyed.

use crate::analysis::bigroots::StageAnalysis;
use crate::analysis::features::{FeatureCategory, FeatureKind, StageFeatures};
use crate::live::registry::FleetReport;
use crate::sim::replay::{job_completion, ReplayStage, ReplayTask};
use crate::util::json::Json;
use crate::util::stats::median;
use crate::util::table::{fnum, pct, Align, Table};

/// A fleet feature baseline below this many observations is too cold to
/// override the within-stage target (matches the registry's default
/// cold-start guard).
pub const FLEET_MIN_COUNT: usize = 64;

/// What-if replay knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WhatIfConfig {
    /// Carried into the report; the current neutralizers are closed-form,
    /// so the seed namespaces determinism rather than driving sampling.
    pub seed: u64,
    /// Task slots per node for the replay scheduler. The offline path
    /// infers this from the trace ([`crate::sim::replay::infer_slots_per_node`]);
    /// the live path uses this configured value.
    pub slots_per_node: usize,
    /// Floor on a neutralized duration, as a fraction of the original.
    pub min_duration_frac: f64,
}

impl Default for WhatIfConfig {
    fn default() -> Self {
        // slots_per_node matches SimConfig::default().slots.
        WhatIfConfig { seed: 42, slots_per_node: 12, min_duration_frac: 0.05 }
    }
}

/// Estimated effect of removing one cause.
#[derive(Debug, Clone, PartialEq)]
pub struct CauseSavings {
    pub kind: FeatureKind,
    /// Tasks whose duration the neutralizer adjusted.
    pub tasks_affected: usize,
    /// Stages containing at least one adjusted task.
    pub stages_affected: usize,
    /// Replayed completion time with this cause neutralized (s).
    pub counterfactual_secs: f64,
    /// `baseline − counterfactual` (s).
    pub saved_secs: f64,
    /// `saved / baseline` (0 when the baseline is 0).
    pub saved_frac: f64,
}

/// Ranked what-if verdict for one job.
#[derive(Debug, Clone, PartialEq)]
pub struct WhatIfReport {
    pub job: String,
    pub seed: u64,
    pub slots_per_node: usize,
    /// Replayed completion time of the observed durations (s).
    pub baseline_secs: f64,
    /// One row per detected cause kind, largest saving first.
    pub rows: Vec<CauseSavings>,
}

impl WhatIfReport {
    /// The cause whose removal saves the most time, if any.
    pub fn top(&self) -> Option<&CauseSavings> {
        self.rows.first()
    }

    /// `(kind, saved_secs)` pairs in rank order — the shape the fleet
    /// registry accumulates.
    pub fn ranked(&self) -> Vec<(FeatureKind, f64)> {
        self.rows.iter().map(|r| (r.kind, r.saved_secs)).collect()
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "what-if {}: replay baseline {} s ({} slots/node, seed {})\n",
            self.job,
            fnum(self.baseline_secs, 2),
            self.slots_per_node,
            self.seed,
        );
        if self.rows.is_empty() {
            out.push_str("no causes detected — nothing to neutralize\n");
            return out;
        }
        let mut t = Table::new("Estimated completion-time saved per cause")
            .header(&["rank", "cause", "tasks", "stages", "counterfactual s", "saved s", "saved"])
            .aligns(&[
                Align::Right,
                Align::Left,
                Align::Right,
                Align::Right,
                Align::Right,
                Align::Right,
                Align::Right,
            ]);
        for (i, r) in self.rows.iter().enumerate() {
            t.row(vec![
                (i + 1).to_string(),
                r.kind.name().to_string(),
                r.tasks_affected.to_string(),
                r.stages_affected.to_string(),
                fnum(r.counterfactual_secs, 2),
                fnum(r.saved_secs, 2),
                pct(r.saved_frac),
            ]);
        }
        out.push_str(&t.render());
        out
    }

    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                Json::from_pairs(vec![
                    ("cause", r.kind.name().into()),
                    ("tasks_affected", r.tasks_affected.into()),
                    ("stages_affected", r.stages_affected.into()),
                    ("counterfactual_secs", r.counterfactual_secs.into()),
                    ("saved_secs", r.saved_secs.into()),
                    ("saved_frac", r.saved_frac.into()),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("job", self.job.as_str().into()),
            ("seed", self.seed.into()),
            ("slots_per_node", self.slots_per_node.into()),
            ("baseline_secs", self.baseline_secs.into()),
            ("rows", Json::Arr(rows)),
        ])
    }
}

/// Benign target value for a feature: the fleet-wide p50 when the supplied
/// baseline is warm enough, else the within-stage median of the column.
fn benign_target(
    fleet: Option<&FleetReport>,
    kind: FeatureKind,
    stage_median: f64,
) -> f64 {
    if let Some(f) = fleet {
        if let Some(b) = f.baselines.iter().find(|b| b.kind == kind) {
            if b.count >= FLEET_MIN_COUNT {
                return b.p50;
            }
        }
    }
    stage_median
}

/// Least-squares slope of `durations` on `values`, clamped non-negative.
/// The "seconds of duration per unit of feature ratio" the numerical
/// neutralizer credits back.
fn duration_slope(values: &[f64], durations: &[f64]) -> f64 {
    let n = values.len();
    if n < 2 {
        return 0.0;
    }
    let nf = n as f64;
    let mean_v = values.iter().sum::<f64>() / nf;
    let mean_d = durations.iter().sum::<f64>() / nf;
    let mut cov = 0.0;
    let mut var = 0.0;
    for i in 0..n {
        let dv = values[i] - mean_v;
        cov += dv * (durations[i] - mean_d);
        var += dv * dv;
    }
    if var <= 0.0 {
        0.0
    } else {
        (cov / var).max(0.0)
    }
}

/// Median duration of the tasks that ran on `node` in this stage.
fn node_median_duration(sf: &StageFeatures, node: usize) -> f64 {
    let durs: Vec<f64> = (0..sf.num_tasks())
        .filter(|&r| sf.nodes[r] == node)
        .map(|r| sf.durations[r])
        .collect();
    median(&durs)
}

/// Neutralized durations for one stage: rows where `kind` was detected as
/// a cause get their duration credit; everything else is untouched.
/// Returns `(durations, adjusted_rows)`.
fn neutralize_stage(
    sf: &StageFeatures,
    analysis: &StageAnalysis,
    kind: FeatureKind,
    fleet: Option<&FleetReport>,
    cfg: &WhatIfConfig,
) -> (Vec<f64>, usize) {
    let mut durs = sf.durations.clone();
    let mut rows: Vec<usize> = analysis
        .causes
        .iter()
        .filter(|c| c.kind == kind)
        .map(|c| c.row)
        .collect();
    rows.sort_unstable();
    rows.dedup();
    if rows.is_empty() {
        return (durs, 0);
    }
    let col = sf.column(kind);
    let stage_col_median = median(&col);
    let stage_dur_median = median(&sf.durations);
    let target = benign_target(fleet, kind, stage_col_median);
    let slope = match kind.category() {
        FeatureCategory::Numerical => duration_slope(&col, &sf.durations),
        _ => 0.0,
    };
    // Slow-node reference: the stage's own median, tightened to the fleet
    // median of stage medians when a warm baseline says this whole stage
    // ran degraded.
    let node_reference = match fleet {
        Some(f) if f.stages >= FLEET_MIN_COUNT && f.stage_median_p50 > 0.0 => {
            stage_dur_median.min(f.stage_median_p50)
        }
        _ => stage_dur_median,
    };
    for &row in &rows {
        let dur = durs[row];
        if dur <= 0.0 {
            continue;
        }
        let v = col[row];
        let neutralized = match kind.category() {
            FeatureCategory::Time => {
                // v is the phase's fraction of the task duration. GC is
                // zeroed outright; ser/deser shrink to the benign target.
                let tgt = if kind == FeatureKind::JvmGcTime { 0.0 } else { target.min(v) };
                dur - dur * (v - tgt).max(0.0)
            }
            FeatureCategory::Numerical => {
                // v is the task's bytes ratio versus the stage mean;
                // normalize to the benign target and credit the fitted
                // seconds-per-ratio slope for the excess.
                let tgt = target.min(v);
                dur - slope * (v - tgt).max(0.0)
            }
            FeatureCategory::Resource => {
                // Swap the slow node for a fleet-median-speed one: divide
                // out the node's slowdown factor versus the reference.
                let node_med = node_median_duration(sf, sf.nodes[row]);
                let factor = if node_reference > 0.0 && node_med > 0.0 {
                    (node_med / node_reference).max(1.0)
                } else {
                    1.0
                };
                dur / factor
            }
            FeatureCategory::Discrete => {
                // Remote read → a typical local task.
                dur.min(stage_dur_median)
            }
        };
        durs[row] = neutralized.clamp(dur * cfg.min_duration_frac, dur);
    }
    (durs, rows.len())
}

fn replay_stages(
    per_stage: &[(StageFeatures, StageAnalysis)],
    durations: impl Fn(usize) -> Vec<f64>,
) -> Vec<ReplayStage> {
    let mut order: Vec<usize> = (0..per_stage.len()).collect();
    order.sort_by_key(|&i| per_stage[i].0.stage_id);
    order
        .into_iter()
        .map(|i| {
            let sf = &per_stage[i].0;
            let durs = durations(i);
            ReplayStage {
                stage_id: sf.stage_id,
                tasks: (0..sf.num_tasks())
                    .map(|r| ReplayTask { node: sf.nodes[r], duration: durs[r] })
                    .collect(),
            }
        })
        .collect()
}

/// Savings estimate for one specific cause kind — 0 saved (and 0 tasks
/// affected) when the analyses never implicated it.
pub fn estimate_for_kind(
    per_stage: &[(StageFeatures, StageAnalysis)],
    kind: FeatureKind,
    fleet: Option<&FleetReport>,
    cfg: &WhatIfConfig,
) -> CauseSavings {
    let baseline_stages = replay_stages(per_stage, |i| per_stage[i].0.durations.clone());
    let baseline = job_completion(&baseline_stages, cfg.slots_per_node);
    estimate_against_baseline(per_stage, kind, fleet, cfg, baseline)
}

fn estimate_against_baseline(
    per_stage: &[(StageFeatures, StageAnalysis)],
    kind: FeatureKind,
    fleet: Option<&FleetReport>,
    cfg: &WhatIfConfig,
    baseline: f64,
) -> CauseSavings {
    let mut tasks_affected = 0usize;
    let mut stages_affected = 0usize;
    let neutralized: Vec<Vec<f64>> = per_stage
        .iter()
        .map(|(sf, a)| {
            let (durs, adjusted) = neutralize_stage(sf, a, kind, fleet, cfg);
            tasks_affected += adjusted;
            if adjusted > 0 {
                stages_affected += 1;
            }
            durs
        })
        .collect();
    let stages = replay_stages(per_stage, |i| neutralized[i].clone());
    let counterfactual = job_completion(&stages, cfg.slots_per_node);
    let saved = (baseline - counterfactual).max(0.0);
    CauseSavings {
        kind,
        tasks_affected,
        stages_affected,
        counterfactual_secs: counterfactual,
        saved_secs: saved,
        saved_frac: if baseline > 0.0 { saved / baseline } else { 0.0 },
    }
}

/// The what-if verdict for one analyzed job: replay the observed durations
/// once, then once per detected cause kind with that cause neutralized.
/// Rows are ranked by time saved (ties broken by feature order), so
/// `rows[0]` is the mitigation with the largest estimated payoff.
pub fn analyze_job(
    job: &str,
    per_stage: &[(StageFeatures, StageAnalysis)],
    fleet: Option<&FleetReport>,
    cfg: &WhatIfConfig,
) -> WhatIfReport {
    let baseline_stages = replay_stages(per_stage, |i| per_stage[i].0.durations.clone());
    let baseline = job_completion(&baseline_stages, cfg.slots_per_node);

    let mut seen = [false; FeatureKind::COUNT];
    for (_, a) in per_stage {
        for c in &a.causes {
            seen[c.kind.index()] = true;
        }
    }
    let mut rows: Vec<CauseSavings> = FeatureKind::ALL
        .iter()
        .filter(|k| seen[k.index()])
        .map(|&k| estimate_against_baseline(per_stage, k, fleet, cfg, baseline))
        .collect();
    rows.sort_by(|a, b| {
        b.saved_secs
            .total_cmp(&a.saved_secs)
            .then_with(|| a.kind.index().cmp(&b.kind.index()))
    });
    WhatIfReport {
        job: job.to_string(),
        seed: cfg.seed,
        slots_per_node: cfg.slots_per_node,
        baseline_secs: baseline,
        rows,
    }
}

/// Offline entry point: what-if over a full trace, slots inferred from the
/// observed per-node concurrency.
pub fn analyze_trace(
    trace: &crate::trace::JobTrace,
    per_stage: &[(StageFeatures, StageAnalysis)],
    fleet: Option<&FleetReport>,
    cfg: &WhatIfConfig,
) -> WhatIfReport {
    let mut cfg = *cfg;
    cfg.slots_per_node = crate::sim::replay::infer_slots_per_node(trace);
    analyze_job(&trace.job_name, per_stage, fleet, &cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::bigroots::{analyze_stage, BigRootsConfig};
    use crate::analysis::features::extract_all;
    use crate::analysis::stats::NativeBackend;
    use crate::sim::{workloads, Engine, InjectionPlan, SimConfig};
    use crate::trace::{AnomalyKind, JobTrace};

    fn analyzed(
        seed: u64,
        plan: &InjectionPlan,
    ) -> (JobTrace, Vec<(StageFeatures, StageAnalysis)>) {
        let w = workloads::wordcount(0.25);
        let mut eng = Engine::new(SimConfig { seed, ..Default::default() });
        let t = eng.run("whatif-test", w.name, &w.stages, plan);
        let cfg = BigRootsConfig::default();
        let mut backend = NativeBackend::new();
        let per_stage: Vec<_> = extract_all(&t, cfg.edge_width)
            .into_iter()
            .map(|sf| {
                let a = analyze_stage(&sf, &mut backend, &cfg);
                (sf, a)
            })
            .collect();
        (t, per_stage)
    }

    #[test]
    fn clean_job_has_bounded_report() {
        let (t, per_stage) = analyzed(5, &InjectionPlan::none());
        let r = analyze_trace(&t, &per_stage, None, &WhatIfConfig::default());
        assert!(r.baseline_secs > 0.0);
        for row in &r.rows {
            assert!(row.saved_secs >= 0.0);
            assert!(row.counterfactual_secs <= r.baseline_secs);
            assert!(row.saved_frac <= 1.0);
        }
        // Ranked descending.
        for w in r.rows.windows(2) {
            assert!(w[0].saved_secs >= w[1].saved_secs);
        }
    }

    #[test]
    fn report_is_bit_identical_across_runs() {
        let plan = InjectionPlan::intermittent(AnomalyKind::Cpu, 1, 15.0, 10.0, 300.0);
        let (t, per_stage) = analyzed(7, &plan);
        let cfg = WhatIfConfig::default();
        let a = analyze_trace(&t, &per_stage, None, &cfg);
        let b = analyze_trace(&t, &per_stage, None, &cfg);
        assert_eq!(a, b);
        assert_eq!(
            a.baseline_secs.to_bits(),
            b.baseline_secs.to_bits(),
            "baseline replay must be bit-identical"
        );
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert_eq!(x.saved_secs.to_bits(), y.saved_secs.to_bits());
        }
    }

    #[test]
    fn undetected_kind_saves_nothing() {
        let (t, per_stage) = analyzed(9, &InjectionPlan::none());
        let mut cfg = WhatIfConfig::default();
        cfg.slots_per_node = crate::sim::replay::infer_slots_per_node(&t);
        // Find a kind no analysis implicated.
        let mut seen = [false; FeatureKind::COUNT];
        for (_, a) in &per_stage {
            for c in &a.causes {
                seen[c.kind.index()] = true;
            }
        }
        let quiet = FeatureKind::ALL.iter().copied().find(|k| !seen[k.index()]);
        if let Some(kind) = quiet {
            let est = estimate_for_kind(&per_stage, kind, None, &cfg);
            assert_eq!(est.tasks_affected, 0);
            assert_eq!(est.saved_secs, 0.0);
        }
    }

    #[test]
    fn neutralizing_never_inflates_durations() {
        let plan = InjectionPlan::intermittent(AnomalyKind::Cpu, 2, 15.0, 10.0, 300.0);
        let (_, per_stage) = analyzed(11, &plan);
        let cfg = WhatIfConfig::default();
        for (sf, a) in &per_stage {
            for &kind in FeatureKind::ALL.iter() {
                let (durs, _) = neutralize_stage(sf, a, kind, None, &cfg);
                for (new, old) in durs.iter().zip(&sf.durations) {
                    assert!(new <= old, "{} inflated {old} -> {new}", kind.name());
                    assert!(*new >= old * cfg.min_duration_frac - 1e-12);
                }
            }
        }
    }

    #[test]
    fn slope_fit_is_sane() {
        // duration = 2·v + 1 exactly.
        let v = vec![1.0, 2.0, 3.0, 4.0];
        let d = vec![3.0, 5.0, 7.0, 9.0];
        assert!((duration_slope(&v, &d) - 2.0).abs() < 1e-12);
        // Anti-correlated clamps to zero.
        let d2 = vec![9.0, 7.0, 5.0, 3.0];
        assert_eq!(duration_slope(&v, &d2), 0.0);
        assert_eq!(duration_slope(&[1.0], &[1.0]), 0.0);
        assert_eq!(duration_slope(&[2.0, 2.0], &[1.0, 5.0]), 0.0);
    }

    #[test]
    fn render_and_json_carry_the_ranking() {
        let plan = InjectionPlan::intermittent(AnomalyKind::Cpu, 1, 15.0, 10.0, 300.0);
        let (t, per_stage) = analyzed(13, &plan);
        let r = analyze_trace(&t, &per_stage, None, &WhatIfConfig::default());
        let text = r.render();
        assert!(text.contains("what-if whatif-test"));
        let j = r.to_json();
        assert_eq!(j.get("job").as_str(), Some("whatif-test"));
        let rows = j.get("rows").as_arr().expect("rows array");
        assert_eq!(rows.len(), r.rows.len());
        if let Some(top) = r.top() {
            assert_eq!(rows[0].get("cause").as_str(), Some(top.kind.name()));
        }
    }
}
