//! Feature-correlation analysis — the paper's stated future work
//! (Section VI): *"we would like to consider the correlation between
//! different features, which helps us to identify the complicated root
//! cause where features are not independent of each other. For instance,
//! poor locality may be correlated with high network utilization."*
//!
//! Per stage we compute the full feature×feature Pearson matrix and use it
//! to (a) surface strongly-coupled feature pairs, and (b) merge a
//! straggler's root causes into *joint causes*: groups of identified
//! features that are mutually correlated above a threshold, so the report
//! reads "locality + network (coupled)" instead of two independent causes.

use super::bigroots::StageAnalysis;
use super::features::{FeatureKind, StageFeatures};

/// Pairwise feature correlations of one stage, row-major `F × F`.
#[derive(Debug, Clone)]
pub struct FeatureCorrelations {
    pub matrix: Vec<f64>,
}

impl FeatureCorrelations {
    pub fn get(&self, a: FeatureKind, b: FeatureKind) -> f64 {
        self.matrix[a.index() * FeatureKind::COUNT + b.index()]
    }

    /// Feature pairs with |ρ| above `threshold`, strongest first.
    pub fn coupled_pairs(&self, threshold: f64) -> Vec<(FeatureKind, FeatureKind, f64)> {
        let mut out = Vec::new();
        for i in 0..FeatureKind::COUNT {
            for j in (i + 1)..FeatureKind::COUNT {
                let rho = self.matrix[i * FeatureKind::COUNT + j];
                if rho.abs() > threshold {
                    out.push((FeatureKind::ALL[i], FeatureKind::ALL[j], rho));
                }
            }
        }
        out.sort_by(|a, b| b.2.abs().total_cmp(&a.2.abs()));
        out
    }
}

/// Compute the feature×feature Pearson matrix over a stage (one pass:
/// sums, sums of squares and cross products).
pub fn feature_correlations(sf: &StageFeatures) -> FeatureCorrelations {
    let f = FeatureKind::COUNT;
    let n = sf.num_tasks();
    let mut sum = vec![0.0f64; f];
    let mut cross = vec![0.0f64; f * f];
    for row in 0..n {
        let base = row * f;
        let vals = &sf.matrix[base..base + f];
        for i in 0..f {
            sum[i] += vals[i];
            // Upper triangle incl. diagonal.
            for j in i..f {
                cross[i * f + j] += vals[i] * vals[j];
            }
        }
    }
    let nf = (n as f64).max(1.0);
    let mean: Vec<f64> = sum.iter().map(|s| s / nf).collect();
    let var: Vec<f64> =
        (0..f).map(|i| (cross[i * f + i] / nf - mean[i] * mean[i]).max(0.0)).collect();
    let mut matrix = vec![0.0f64; f * f];
    for i in 0..f {
        matrix[i * f + i] = if var[i] > 0.0 { 1.0 } else { 0.0 };
        for j in (i + 1)..f {
            let cov = cross[i * f + j] / nf - mean[i] * mean[j];
            let denom = (var[i] * var[j]).sqrt();
            let rho = if denom <= 1e-30 { 0.0 } else { (cov / denom).clamp(-1.0, 1.0) };
            matrix[i * f + j] = rho;
            matrix[j * f + i] = rho;
        }
    }
    FeatureCorrelations { matrix }
}

/// A joint root cause: features identified for the same straggler that are
/// mutually correlated across the stage — likely one underlying mechanism.
#[derive(Debug, Clone)]
pub struct JointCause {
    pub row: usize,
    pub task_id: u64,
    /// ≥ 2 mutually-correlated identified features.
    pub features: Vec<FeatureKind>,
    /// The weakest pairwise |ρ| within the group.
    pub min_abs_rho: f64,
}

/// Group each straggler's identified causes into correlated clusters
/// (single-linkage over |ρ| > threshold). Singleton causes are omitted —
/// they are already reported individually.
pub fn joint_causes(
    analysis: &StageAnalysis,
    corr: &FeatureCorrelations,
    threshold: f64,
) -> Vec<JointCause> {
    let mut out = Vec::new();
    for &row in &analysis.stragglers.rows {
        let feats: Vec<FeatureKind> =
            analysis.causes_of(row).iter().map(|c| c.kind).collect();
        if feats.len() < 2 {
            continue;
        }
        // Single-linkage clustering over the identified features.
        let mut cluster_of: Vec<usize> = (0..feats.len()).collect();
        for i in 0..feats.len() {
            for j in (i + 1)..feats.len() {
                if corr.get(feats[i], feats[j]).abs() > threshold {
                    let (a, b) = (cluster_of[i], cluster_of[j]);
                    if a != b {
                        for c in cluster_of.iter_mut() {
                            if *c == b {
                                *c = a;
                            }
                        }
                    }
                }
            }
        }
        let mut clusters: std::collections::BTreeMap<usize, Vec<FeatureKind>> =
            Default::default();
        for (i, &c) in cluster_of.iter().enumerate() {
            clusters.entry(c).or_default().push(feats[i]);
        }
        for (_, group) in clusters {
            if group.len() < 2 {
                continue;
            }
            let mut min_rho = f64::INFINITY;
            for i in 0..group.len() {
                for j in (i + 1)..group.len() {
                    min_rho = min_rho.min(corr.get(group[i], group[j]).abs());
                }
            }
            out.push(JointCause {
                row,
                task_id: analysis
                    .causes_of(row)
                    .first()
                    .map(|c| c.task_id)
                    .unwrap_or_default(),
                features: group,
                min_abs_rho: if min_rho.is_finite() { min_rho } else { 0.0 },
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::bigroots::{analyze_stage_with_stats, BigRootsConfig};
    use crate::analysis::features::FeatureKind as F;
    use crate::analysis::stats::compute_native;

    /// Stage where Locality and Network move together (the paper's §VI
    /// example) and BytesRead is independent.
    fn coupled_stage(n: usize) -> StageFeatures {
        let f = F::COUNT;
        let mut matrix = vec![0.0; n * f];
        let mut durations = vec![1.0; n];
        for r in 0..n {
            let remote = r % 4 == 0;
            matrix[r * f + F::Locality.index()] = if remote { 2.0 } else { 0.0 };
            matrix[r * f + F::Network.index()] = if remote { 90e6 } else { 5e6 };
            matrix[r * f + F::BytesRead.index()] = if r % 3 == 0 { 2.0 } else { 0.8 };
            if remote {
                durations[r] = 3.0;
            }
        }
        StageFeatures {
            stage_id: 0,
            task_ids: (0..n as u64).collect(),
            nodes: (0..n).map(|r| r % 4).collect(),
            durations,
            matrix,
            head_means: vec![1.0; n * 3],
            tail_means: vec![1.0; n * 3],
        }
    }

    #[test]
    fn correlation_matrix_detects_coupling() {
        let sf = coupled_stage(40);
        let corr = feature_correlations(&sf);
        assert!(corr.get(F::Locality, F::Network) > 0.95, "locality↔network coupled");
        assert!(corr.get(F::Locality, F::BytesRead).abs() < 0.4, "independent pair");
        // Symmetric with unit diagonal (for non-constant features).
        assert_eq!(corr.get(F::Network, F::Locality), corr.get(F::Locality, F::Network));
        assert_eq!(corr.get(F::Network, F::Network), 1.0);
        // Constant feature (never set) → zero correlation row.
        assert_eq!(corr.get(F::JvmGcTime, F::Network), 0.0);
    }

    #[test]
    fn coupled_pairs_sorted_by_strength() {
        let sf = coupled_stage(40);
        let corr = feature_correlations(&sf);
        let pairs = corr.coupled_pairs(0.8);
        assert!(!pairs.is_empty());
        assert!(pairs
            .iter()
            .any(|&(a, b, _)| (a == F::Locality && b == F::Network)
                || (a == F::Network && b == F::Locality)));
        for w in pairs.windows(2) {
            assert!(w[0].2.abs() >= w[1].2.abs());
        }
    }

    #[test]
    fn joint_causes_group_correlated_findings() {
        let sf = coupled_stage(40);
        let stats = compute_native(&sf);
        // Loose config so both locality and network get identified.
        let cfg = BigRootsConfig {
            lambda_q: 0.5,
            lambda_p: 1.2,
            min_net_bytes: 10e6,
            // The fixture has no meaningful head/tail windows.
            use_edge_detection: false,
            ..Default::default()
        };
        let a = analyze_stage_with_stats(&sf, &stats, &cfg);
        assert!(!a.stragglers.rows.is_empty());
        let corr = feature_correlations(&sf);
        let joints = joint_causes(&a, &corr, 0.8);
        // The locality+network pair must be merged for at least one straggler.
        assert!(
            joints.iter().any(|j| j.features.contains(&F::Locality)
                && j.features.contains(&F::Network)),
            "expected a joint locality+network cause, got {joints:?}"
        );
        for j in &joints {
            assert!(j.features.len() >= 2);
            assert!(j.min_abs_rho > 0.8);
        }
    }

    #[test]
    fn uncorrelated_causes_stay_separate() {
        let sf = coupled_stage(40);
        let corr = feature_correlations(&sf);
        // Fabricate an analysis where BytesRead and Network are both causes;
        // they are uncorrelated, so no joint cause should appear.
        let stats = compute_native(&sf);
        let cfg = BigRootsConfig { lambda_q: 0.5, lambda_p: 1.2, ..Default::default() };
        let mut a = analyze_stage_with_stats(&sf, &stats, &cfg);
        a.causes.retain(|c| c.kind == F::BytesRead || c.kind == F::Network);
        let joints = joint_causes(&a, &corr, 0.8);
        assert!(
            joints.iter().all(|j| !(j.features.contains(&F::BytesRead)
                && j.features.contains(&F::Network))),
            "uncorrelated features must not merge"
        );
    }

    #[test]
    fn empty_stage_safe() {
        let sf = StageFeatures {
            stage_id: 0,
            task_ids: vec![],
            nodes: vec![],
            durations: vec![],
            matrix: vec![],
            head_means: vec![],
            tail_means: vec![],
        };
        let corr = feature_correlations(&sf);
        assert_eq!(corr.matrix.len(), F::COUNT * F::COUNT);
        assert!(corr.coupled_pairs(0.5).is_empty());
    }
}
