//! Feature extraction — Section III-A of the paper.
//!
//! Twelve features per task, in four categories:
//!
//! | category  | features | definition |
//! |-----------|----------|------------|
//! | resource  | CPU, disk, network | Eq. 1–3: mean node utilization over the task's window |
//! | numerical | bytes_read, shuffle_read/write, memory/disk spilled | `B / B_avg` over the stage (Table II) |
//! | time      | JVM GC, serialize, deserialize | `T / T_task` (Table II) |
//! | discrete  | locality | Eq. 4: 0 / 1 / 2 |
//!
//! Extraction produces a dense `tasks × features` matrix per stage — the
//! input to both the native stats path and the AOT-compiled XLA kernel.

use crate::trace::{JobTrace, NodeSeries, TaskRecord};

/// Feature identity. Order defines the matrix column layout (keep in sync
/// with `python/compile/model.py::FEATURES`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FeatureKind {
    Cpu,
    Disk,
    Network,
    BytesRead,
    ShuffleReadBytes,
    ShuffleWriteBytes,
    MemoryBytesSpilled,
    DiskBytesSpilled,
    JvmGcTime,
    SerializeTime,
    DeserializeTime,
    Locality,
}

/// Statistical category determining which identification rule applies
/// (Section III-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeatureCategory {
    Resource,
    Numerical,
    Time,
    Discrete,
}

impl FeatureKind {
    pub const ALL: [FeatureKind; 12] = [
        FeatureKind::Cpu,
        FeatureKind::Disk,
        FeatureKind::Network,
        FeatureKind::BytesRead,
        FeatureKind::ShuffleReadBytes,
        FeatureKind::ShuffleWriteBytes,
        FeatureKind::MemoryBytesSpilled,
        FeatureKind::DiskBytesSpilled,
        FeatureKind::JvmGcTime,
        FeatureKind::SerializeTime,
        FeatureKind::DeserializeTime,
        FeatureKind::Locality,
    ];

    pub const COUNT: usize = 12;

    pub fn index(self) -> usize {
        Self::ALL.iter().position(|&k| k == self).unwrap()
    }

    pub fn category(self) -> FeatureCategory {
        match self {
            FeatureKind::Cpu | FeatureKind::Disk | FeatureKind::Network => {
                FeatureCategory::Resource
            }
            FeatureKind::BytesRead
            | FeatureKind::ShuffleReadBytes
            | FeatureKind::ShuffleWriteBytes
            | FeatureKind::MemoryBytesSpilled
            | FeatureKind::DiskBytesSpilled => FeatureCategory::Numerical,
            FeatureKind::JvmGcTime | FeatureKind::SerializeTime | FeatureKind::DeserializeTime => {
                FeatureCategory::Time
            }
            FeatureKind::Locality => FeatureCategory::Discrete,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FeatureKind::Cpu => "cpu",
            FeatureKind::Disk => "disk",
            FeatureKind::Network => "network",
            FeatureKind::BytesRead => "bytes_read",
            FeatureKind::ShuffleReadBytes => "shuffle_read_bytes",
            FeatureKind::ShuffleWriteBytes => "shuffle_write_bytes",
            FeatureKind::MemoryBytesSpilled => "memory_bytes_spilled",
            FeatureKind::DiskBytesSpilled => "disk_bytes_spilled",
            FeatureKind::JvmGcTime => "jvm_gc_time",
            FeatureKind::SerializeTime => "serialize_time",
            FeatureKind::DeserializeTime => "deserialize_time",
            FeatureKind::Locality => "locality",
        }
    }

    /// Inverse of [`FeatureKind::name`] — resolves user-supplied filter
    /// strings (control-socket `jobs cause=...`, CLI flags) back to a kind.
    pub fn from_name(s: &str) -> Option<FeatureKind> {
        Self::ALL.iter().copied().find(|k| k.name() == s)
    }

    /// The anomaly-generator kind whose injection this feature should flag
    /// (ground-truth mapping for TP/FP scoring); None for framework features.
    pub fn matching_anomaly(self) -> Option<crate::trace::AnomalyKind> {
        match self {
            FeatureKind::Cpu => Some(crate::trace::AnomalyKind::Cpu),
            FeatureKind::Disk => Some(crate::trace::AnomalyKind::Io),
            FeatureKind::Network => Some(crate::trace::AnomalyKind::Network),
            _ => None,
        }
    }
}

/// The per-stage feature matrix plus everything the rules need that is not
/// a plain matrix column: per-task node placement, durations, and the edge
/// detection head/tail resource means.
#[derive(Debug, Clone)]
pub struct StageFeatures {
    pub stage_id: u64,
    /// Task ids, row-aligned with `matrix`.
    pub task_ids: Vec<u64>,
    /// Node of each task.
    pub nodes: Vec<usize>,
    /// Duration of each task (s).
    pub durations: Vec<f64>,
    /// Row-major `tasks × FeatureKind::COUNT`.
    pub matrix: Vec<f64>,
    /// Head-window mean of each resource feature before task start:
    /// row-major `tasks × 3` (cpu, disk, network), for Eq. 6.
    pub head_means: Vec<f64>,
    /// Tail-window mean after task end, same layout.
    pub tail_means: Vec<f64>,
}

impl StageFeatures {
    pub fn num_tasks(&self) -> usize {
        self.task_ids.len()
    }

    /// Value of feature `k` for row `row`.
    pub fn get(&self, row: usize, k: FeatureKind) -> f64 {
        self.matrix[row * FeatureKind::COUNT + k.index()]
    }

    /// All values of feature `k` (column copy).
    pub fn column(&self, k: FeatureKind) -> Vec<f64> {
        (0..self.num_tasks()).map(|r| self.get(r, k)).collect()
    }

    /// Head/tail means of resource feature `k` (Cpu/Disk/Network) for `row`.
    pub fn edge_means(&self, row: usize, k: FeatureKind) -> (f64, f64) {
        let c = match k {
            FeatureKind::Cpu => 0,
            FeatureKind::Disk => 1,
            FeatureKind::Network => 2,
            _ => panic!("edge_means on non-resource feature"),
        };
        (self.head_means[row * 3 + c], self.tail_means[row * 3 + c])
    }
}

/// Resource features Eq. 1–3: average the node's sampled series over the
/// task's execution window. Network uses mean bytes per sampling interval.
fn resource_features(task: &TaskRecord, series: &NodeSeries) -> (f64, f64, f64) {
    let (t0, t1) = (task.start, task.finish);
    let p = series.period;
    (
        NodeSeries::window_mean(&series.cpu, p, t0, t1),
        NodeSeries::window_mean(&series.disk, p, t0, t1),
        NodeSeries::window_mean(&series.net_bytes, p, t0, t1),
    )
}

/// Extract the feature matrix for one stage of a trace. `edge_width` is the
/// duration (s) of the head/tail windows monitored for edge detection.
pub fn extract_stage(trace: &JobTrace, stage_id: u64, edge_width: f64) -> StageFeatures {
    let tasks = trace.stage_tasks(stage_id);
    let n = tasks.len();
    let f = FeatureKind::COUNT;

    // Stage averages for the numerical (B/B_avg) features.
    let avg = |get: &dyn Fn(&TaskRecord) -> f64| -> f64 {
        if n == 0 {
            return 0.0;
        }
        tasks.iter().map(|t| get(t)).sum::<f64>() / n as f64
    };
    let avg_bytes_read = avg(&|t| t.bytes_read);
    let avg_sh_read = avg(&|t| t.shuffle_read_bytes);
    let avg_sh_write = avg(&|t| t.shuffle_write_bytes);
    let avg_mem_spill = avg(&|t| t.memory_bytes_spilled);
    let avg_disk_spill = avg(&|t| t.disk_bytes_spilled);
    // A zero stage average makes B/B_avg degenerate; treat as "all zero"
    // (feature identically 0 — never a root cause, matching the paper's
    // stages that simply lack e.g. shuffle reads).
    let ratio = |b: f64, avg: f64| if avg > 0.0 { b / avg } else { 0.0 };

    let mut matrix = vec![0.0f64; n * f];
    let mut head_means = vec![0.0f64; n * 3];
    let mut tail_means = vec![0.0f64; n * 3];
    let mut task_ids = Vec::with_capacity(n);
    let mut nodes = Vec::with_capacity(n);
    let mut durations = Vec::with_capacity(n);

    for (row, t) in tasks.iter().enumerate() {
        let series = trace.series(t.node);
        let (f_cpu, f_disk, f_net) = resource_features(t, series);
        let dur = t.duration().max(1e-9);
        let vals: [f64; FeatureKind::COUNT] = [
            f_cpu,
            f_disk,
            f_net,
            ratio(t.bytes_read, avg_bytes_read),
            ratio(t.shuffle_read_bytes, avg_sh_read),
            ratio(t.shuffle_write_bytes, avg_sh_write),
            ratio(t.memory_bytes_spilled, avg_mem_spill),
            ratio(t.disk_bytes_spilled, avg_disk_spill),
            t.jvm_gc_time / dur,
            t.serialize_time / dur,
            t.deserialize_time / dur,
            t.locality.numeric(),
        ];
        matrix[row * f..(row + 1) * f].copy_from_slice(&vals);

        // Edge-detection windows: [start - w, start) and (finish, finish + w].
        let p = series.period;
        let hw = |s: &[f64]| NodeSeries::window_mean(s, p, t.start - edge_width, t.start);
        let tw = |s: &[f64]| NodeSeries::window_mean(s, p, t.finish, t.finish + edge_width);
        head_means[row * 3] = hw(&series.cpu);
        head_means[row * 3 + 1] = hw(&series.disk);
        head_means[row * 3 + 2] = hw(&series.net_bytes);
        tail_means[row * 3] = tw(&series.cpu);
        tail_means[row * 3 + 1] = tw(&series.disk);
        tail_means[row * 3 + 2] = tw(&series.net_bytes);

        task_ids.push(t.task_id);
        nodes.push(t.node);
        durations.push(t.duration());
    }

    StageFeatures { stage_id, task_ids, nodes, durations, matrix, head_means, tail_means }
}

/// Extract every stage of a trace.
pub fn extract_all(trace: &JobTrace, edge_width: f64) -> Vec<StageFeatures> {
    trace.stages.iter().map(|s| extract_stage(trace, s.stage_id, edge_width)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::*;

    fn trace() -> JobTrace {
        let mk = |task_id, node, start: f64, finish: f64, br: f64, gc: f64, loc| TaskRecord {
            task_id,
            stage_id: 0,
            node,
            executor: 0,
            start,
            finish,
            locality: loc,
            bytes_read: br,
            shuffle_read_bytes: 0.0,
            shuffle_write_bytes: 2.0 * br,
            memory_bytes_spilled: 0.0,
            disk_bytes_spilled: 0.0,
            jvm_gc_time: gc,
            serialize_time: 0.1,
            deserialize_time: 0.2,
        };
        JobTrace {
            job_name: "t".into(),
            workload: "u".into(),
            cluster: ClusterInfo { nodes: 2, cores_per_node: 4, executors_per_node: 1 },
            stages: vec![StageRecord { stage_id: 0, name: "s".into(), tasks: vec![0, 1, 2] }],
            tasks: vec![
                mk(0, 0, 0.0, 2.0, 100.0, 0.2, Locality::NodeLocal),
                mk(1, 0, 0.0, 4.0, 300.0, 0.4, Locality::ProcessLocal),
                mk(2, 1, 2.0, 6.0, 200.0, 1.0, Locality::Any),
            ],
            node_series: vec![
                NodeSeries {
                    node: 0,
                    period: 1.0,
                    cpu: vec![0.2, 0.4, 0.6, 0.8, 1.0, 1.0, 1.0, 1.0],
                    disk: vec![0.1; 8],
                    net_bytes: vec![10.0; 8],
                },
                NodeSeries {
                    node: 1,
                    period: 1.0,
                    cpu: vec![0.5; 8],
                    disk: vec![0.9; 8],
                    net_bytes: vec![100.0, 100.0, 200.0, 200.0, 200.0, 200.0, 0.0, 0.0],
                },
            ],
            injections: vec![],
        }
    }

    #[test]
    fn column_layout_is_stable() {
        assert_eq!(FeatureKind::COUNT, FeatureKind::ALL.len());
        for (i, k) in FeatureKind::ALL.iter().enumerate() {
            assert_eq!(k.index(), i);
        }
        assert_eq!(FeatureKind::Cpu.index(), 0);
        assert_eq!(FeatureKind::Locality.index(), 11);
    }

    #[test]
    fn categories_match_paper() {
        use FeatureCategory::*;
        assert_eq!(FeatureKind::Cpu.category(), Resource);
        assert_eq!(FeatureKind::Network.category(), Resource);
        assert_eq!(FeatureKind::BytesRead.category(), Numerical);
        assert_eq!(FeatureKind::DiskBytesSpilled.category(), Numerical);
        assert_eq!(FeatureKind::JvmGcTime.category(), Time);
        assert_eq!(FeatureKind::Locality.category(), Discrete);
    }

    #[test]
    fn numerical_features_are_b_over_bavg() {
        let sf = extract_stage(&trace(), 0, 3.0);
        // bytes_read: 100, 300, 200 → avg 200.
        assert!((sf.get(0, FeatureKind::BytesRead) - 0.5).abs() < 1e-12);
        assert!((sf.get(1, FeatureKind::BytesRead) - 1.5).abs() < 1e-12);
        assert!((sf.get(2, FeatureKind::BytesRead) - 1.0).abs() < 1e-12);
        // shuffle_read is identically zero → ratio 0, not NaN.
        assert_eq!(sf.get(0, FeatureKind::ShuffleReadBytes), 0.0);
    }

    #[test]
    fn time_features_are_t_over_task() {
        let sf = extract_stage(&trace(), 0, 3.0);
        // task 0: gc 0.2 over 2.0 s → 0.1
        assert!((sf.get(0, FeatureKind::JvmGcTime) - 0.1).abs() < 1e-12);
        // task 2: gc 1.0 over 4.0 s → 0.25
        assert!((sf.get(2, FeatureKind::JvmGcTime) - 0.25).abs() < 1e-12);
        assert!((sf.get(0, FeatureKind::SerializeTime) - 0.05).abs() < 1e-12);
    }

    #[test]
    fn resource_features_average_task_window() {
        let sf = extract_stage(&trace(), 0, 3.0);
        // task 0 on node 0, window [0,2): cpu mean (0.2+0.4)/2 = 0.3
        assert!((sf.get(0, FeatureKind::Cpu) - 0.3).abs() < 1e-12);
        // task 2 on node 1, window [2,6): net mean = 200
        assert!((sf.get(2, FeatureKind::Network) - 200.0).abs() < 1e-12);
        assert!((sf.get(2, FeatureKind::Disk) - 0.9).abs() < 1e-12);
    }

    #[test]
    fn locality_encoded_numerically() {
        let sf = extract_stage(&trace(), 0, 3.0);
        assert_eq!(sf.get(0, FeatureKind::Locality), 1.0);
        assert_eq!(sf.get(1, FeatureKind::Locality), 0.0);
        assert_eq!(sf.get(2, FeatureKind::Locality), 2.0);
    }

    #[test]
    fn edge_windows_cover_head_and_tail() {
        let sf = extract_stage(&trace(), 0, 2.0);
        // task 2 on node 1: head window [0,2): net mean 100; tail (6,8]: 0.
        let (head, tail) = sf.edge_means(2, FeatureKind::Network);
        assert!((head - 100.0).abs() < 1e-12);
        assert!((tail - 0.0).abs() < 1e-12);
        // task 0 head window [-2,0) clamps into the recorded series.
        let (h0, _) = sf.edge_means(0, FeatureKind::Cpu);
        assert!(h0 >= 0.0);
    }

    #[test]
    fn matching_anomaly_mapping() {
        assert_eq!(FeatureKind::Cpu.matching_anomaly(), Some(AnomalyKind::Cpu));
        assert_eq!(FeatureKind::Disk.matching_anomaly(), Some(AnomalyKind::Io));
        assert_eq!(FeatureKind::Network.matching_anomaly(), Some(AnomalyKind::Network));
        assert_eq!(FeatureKind::BytesRead.matching_anomaly(), None);
    }

    #[test]
    fn extract_all_covers_stages() {
        let all = extract_all(&trace(), 3.0);
        assert_eq!(all.len(), 1);
        assert_eq!(all[0].num_tasks(), 3);
        assert_eq!(all[0].column(FeatureKind::BytesRead).len(), 3);
    }
}
