//! Stage-stats memoization — the cache layer of the hot path.
//!
//! Fleets re-run the same jobs: a nightly ETL resubmitted per tenant, a
//! benchmark suite looping over the HiBench workloads, a scheduler
//! retrying a failed job. Their stages produce *identical* feature
//! matrices, and [`compute_native`](super::stats::compute_native) work on
//! an identical matrix is pure waste. [`CachedBackend`] wraps any
//! [`StatsBackend`] with an LRU-bounded memo table keyed on a structural
//! hash of the stats-relevant [`StageFeatures`] fields (`nodes`,
//! `durations`, `matrix` — ids and edge-window means do not influence
//! [`StageStats`]).
//!
//! Correctness contract: results are **bit-identical** to the wrapped
//! backend, always. A hash hit is verified against a stored copy of the
//! key fields before use, so a 64-bit collision degrades to a miss rather
//! than a wrong answer; `rust/tests/hotpath_parity.rs` asserts parity
//! (including under eviction pressure) property-style.
//!
//! Sizing: each resident entry holds the key fields plus the
//! [`StageStats`] (~`(14 × tasks + 300) × 8` bytes), so the default
//! capacity of a few hundred entries stays in the tens of megabytes even
//! for 2 000-task stages. Capacity 0 disables caching entirely (every
//! call forwards, counted as a miss).

use std::collections::{BTreeMap, HashMap};

use super::features::StageFeatures;
use super::stats::{StageStats, StatsBackend};

/// Hit/miss/eviction counters, surfaced through
/// [`StatsBackend::cache_counters`] into service metrics and fleet
/// snapshots.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheCounters {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CacheCounters {
    /// Hit fraction in [0, 1]; 0 before any lookup.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Owned copy of the fields that determine [`StageStats`], kept per entry
/// so hash collisions can be detected exactly.
#[derive(Debug, Clone)]
struct CacheKey {
    nodes: Vec<usize>,
    durations: Vec<f64>,
    matrix: Vec<f64>,
}

impl CacheKey {
    fn of(sf: &StageFeatures) -> CacheKey {
        CacheKey {
            nodes: sf.nodes.clone(),
            durations: sf.durations.clone(),
            matrix: sf.matrix.clone(),
        }
    }

    /// Exact (bitwise for floats) match — `f64::to_bits` so NaN keys
    /// compare like any other value instead of poisoning the table.
    fn matches(&self, sf: &StageFeatures) -> bool {
        self.nodes == sf.nodes
            && self.durations.len() == sf.durations.len()
            && self.matrix.len() == sf.matrix.len()
            && self
                .durations
                .iter()
                .zip(&sf.durations)
                .all(|(a, b)| a.to_bits() == b.to_bits())
            && self.matrix.iter().zip(&sf.matrix).all(|(a, b)| a.to_bits() == b.to_bits())
    }
}

/// FNV-1a over the stats-relevant bytes of a stage. 64-bit — collisions
/// are possible in principle, which is why entries verify the full key.
pub fn structural_hash(sf: &StageFeatures) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut eat = |x: u64| {
        for shift in [0u32, 8, 16, 24, 32, 40, 48, 56] {
            h ^= (x >> shift) & 0xff;
            h = h.wrapping_mul(PRIME);
        }
    };
    eat(sf.nodes.len() as u64);
    for &nd in &sf.nodes {
        eat(nd as u64);
    }
    eat(sf.durations.len() as u64);
    for &d in &sf.durations {
        eat(d.to_bits());
    }
    eat(sf.matrix.len() as u64);
    for &v in &sf.matrix {
        eat(v.to_bits());
    }
    h
}

struct Entry {
    key: CacheKey,
    value: StageStats,
    /// Monotone recency tick; the entry's position in `lru`.
    tick: u64,
}

/// A memoizing [`StatsBackend`] wrapper. See module docs.
pub struct CachedBackend<B> {
    inner: B,
    capacity: usize,
    /// structural hash → entry. One entry per hash: a colliding insert
    /// replaces (correct either way — the key check decides hit vs miss).
    map: HashMap<u64, Entry>,
    /// recency tick → hash, oldest first (BTreeMap keeps ticks ordered, so
    /// eviction is "remove the first key" without an intrusive list).
    lru: BTreeMap<u64, u64>,
    tick: u64,
    counters: CacheCounters,
}

impl<B: StatsBackend> CachedBackend<B> {
    pub fn new(inner: B, capacity: usize) -> Self {
        CachedBackend {
            inner,
            capacity,
            map: HashMap::new(),
            lru: BTreeMap::new(),
            tick: 0,
            counters: CacheCounters::default(),
        }
    }

    pub fn counters(&self) -> CacheCounters {
        self.counters
    }

    /// Resident entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// The wrapped backend (e.g. to read its own counters).
    pub fn inner(&self) -> &B {
        &self.inner
    }

    fn lookup(&mut self, hash: u64, sf: &StageFeatures) -> Option<StageStats> {
        self.tick += 1;
        let tick = self.tick;
        // One probe: verify the key, bump recency, clone the value.
        let (value, old_tick) = match self.map.get_mut(&hash) {
            Some(e) if e.key.matches(sf) => {
                let old = e.tick;
                e.tick = tick;
                (e.value.clone(), old)
            }
            _ => return None,
        };
        self.lru.remove(&old_tick);
        self.lru.insert(tick, hash);
        Some(value)
    }

    fn insert(&mut self, hash: u64, sf: &StageFeatures, value: StageStats) {
        // Replace a colliding (or stale same-hash) entry outright.
        if let Some(old) = self.map.remove(&hash) {
            self.lru.remove(&old.tick);
        }
        while self.map.len() >= self.capacity {
            let oldest = match self.lru.iter().next() {
                Some((&t, &h)) => (t, h),
                None => break,
            };
            self.lru.remove(&oldest.0);
            self.map.remove(&oldest.1);
            self.counters.evictions += 1;
        }
        self.tick += 1;
        self.lru.insert(self.tick, hash);
        self.map.insert(hash, Entry { key: CacheKey::of(sf), value, tick: self.tick });
    }
}

impl<B: StatsBackend> StatsBackend for CachedBackend<B> {
    fn stage_stats(&mut self, sf: &StageFeatures) -> StageStats {
        if self.capacity == 0 {
            self.counters.misses += 1;
            return self.inner.stage_stats(sf);
        }
        let hash = structural_hash(sf);
        if let Some(v) = self.lookup(hash, sf) {
            self.counters.hits += 1;
            return v;
        }
        self.counters.misses += 1;
        let v = self.inner.stage_stats(sf);
        self.insert(hash, sf, v.clone());
        v
    }

    // The default batch impl loops over `stage_stats`, which is exactly
    // right here: every element gets its own cache lookup.

    fn name(&self) -> &'static str {
        "cached"
    }

    fn cache_counters(&self) -> Option<CacheCounters> {
        Some(self.counters)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::features::FeatureKind as F;
    use crate::analysis::stats::{compute_native, NativeBackend};

    fn stage(seed: u64, n: usize) -> StageFeatures {
        let f = F::COUNT;
        let mut rng = crate::util::rng::Pcg64::seeded(seed);
        StageFeatures {
            stage_id: seed,
            task_ids: (0..n as u64).collect(),
            nodes: (0..n).map(|r| r % 3).collect(),
            durations: (0..n).map(|_| rng.range_f64(0.5, 5.0)).collect(),
            matrix: (0..n * f).map(|_| rng.range_f64(0.0, 4.0)).collect(),
            head_means: vec![0.0; n * 3],
            tail_means: vec![0.0; n * 3],
        }
    }

    #[test]
    fn hit_returns_identical_stats() {
        let mut c = CachedBackend::new(NativeBackend::new(), 8);
        let sf = stage(1, 20);
        let first = c.stage_stats(&sf);
        let second = c.stage_stats(&sf);
        assert_eq!(first, second);
        assert_eq!(first, compute_native(&sf));
        assert_eq!(c.counters(), CacheCounters { hits: 1, misses: 1, evictions: 0 });
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn ids_do_not_affect_the_key() {
        // stage_id / task_ids don't influence StageStats — same matrix
        // under different ids must hit.
        let mut c = CachedBackend::new(NativeBackend::new(), 8);
        let a = stage(2, 12);
        let mut b = a.clone();
        b.stage_id = 999;
        b.task_ids = (100..112).collect();
        let ra = c.stage_stats(&a);
        let rb = c.stage_stats(&b);
        assert_eq!(ra, rb);
        assert_eq!(c.counters().hits, 1);
    }

    #[test]
    fn different_matrices_miss() {
        let mut c = CachedBackend::new(NativeBackend::new(), 8);
        let a = stage(3, 10);
        let mut b = a.clone();
        b.matrix[0] += 1.0;
        c.stage_stats(&a);
        c.stage_stats(&b);
        assert_eq!(c.counters(), CacheCounters { hits: 0, misses: 2, evictions: 0 });
        assert_eq!(c.stage_stats(&b), compute_native(&b));
        assert_eq!(c.counters().hits, 1);
    }

    #[test]
    fn eviction_is_lru_and_results_stay_correct() {
        let mut c = CachedBackend::new(NativeBackend::new(), 2);
        let s1 = stage(10, 8);
        let s2 = stage(11, 8);
        let s3 = stage(12, 8);
        c.stage_stats(&s1);
        c.stage_stats(&s2);
        c.stage_stats(&s1); // s1 most recent; s2 is now LRU
        c.stage_stats(&s3); // evicts s2
        assert_eq!(c.counters().evictions, 1);
        assert_eq!(c.len(), 2);
        // s1 still resident → hit; s2 evicted → recomputed, still right.
        let hits_before = c.counters().hits;
        assert_eq!(c.stage_stats(&s1), compute_native(&s1));
        assert_eq!(c.counters().hits, hits_before + 1);
        assert_eq!(c.stage_stats(&s2), compute_native(&s2));
        assert_eq!(c.counters().misses, 4);
    }

    #[test]
    fn capacity_zero_disables_caching() {
        let mut c = CachedBackend::new(NativeBackend::new(), 0);
        let sf = stage(4, 6);
        assert_eq!(c.stage_stats(&sf), compute_native(&sf));
        assert_eq!(c.stage_stats(&sf), compute_native(&sf));
        assert_eq!(c.counters(), CacheCounters { hits: 0, misses: 2, evictions: 0 });
        assert!(c.is_empty());
    }

    #[test]
    fn batch_goes_through_the_cache() {
        let mut c = CachedBackend::new(NativeBackend::new(), 8);
        let a = stage(5, 10);
        let b = stage(6, 10);
        let refs = vec![&a, &b, &a];
        let out = c.stage_stats_batch(&refs);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0], out[2]);
        assert_eq!(out[1], compute_native(&b));
        assert_eq!(c.counters(), CacheCounters { hits: 1, misses: 2, evictions: 0 });
    }

    #[test]
    fn nan_keys_are_cacheable() {
        // NaN != NaN, but keys compare by bits — a NaN-bearing stage must
        // hit on resubmission rather than recompute forever.
        let mut c = CachedBackend::new(NativeBackend::new(), 4);
        let mut sf = stage(7, 6);
        sf.matrix[0] = f64::NAN;
        let a = c.stage_stats(&sf);
        let b = c.stage_stats(&sf);
        assert_eq!(c.counters().hits, 1);
        // Compare through Debug: StageStats PartialEq is false under NaN.
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn hit_rate() {
        let mut cc = CacheCounters::default();
        assert_eq!(cc.hit_rate(), 0.0);
        cc.hits = 3;
        cc.misses = 1;
        assert!((cc.hit_rate() - 0.75).abs() < 1e-12);
    }
}
