//! Stage-stats memoization — the cache layer of the hot path.
//!
//! Fleets re-run the same jobs: a nightly ETL resubmitted per tenant, a
//! benchmark suite looping over the HiBench workloads, a scheduler
//! retrying a failed job. Their stages produce *identical* feature
//! matrices, and [`compute_native`](super::stats::compute_native) work on
//! an identical matrix is pure waste. Two memo shapes share one engine:
//!
//! - [`CachedBackend`] — a single-owner LRU memo in front of one backend.
//!   No locks anywhere; the fast path for the offline
//!   [`crate::coordinator::Pipeline`], which owns its backend outright.
//! - [`SharedCachedBackend`] — the same memo semantics over a
//!   [`SharedStatsCache`]: a **lock-striped** table (N stripes selected by
//!   the structural hash, each its own mutex + LRU + eviction counter)
//!   shared by every service worker and live shard worker. A tenant's
//!   repeated stage shape hits *regardless of which shard rendezvous
//!   routing picked* — shard 1 computes, shard 0 hits.
//!
//! Both are the one generic [`Memoized`] wrapper over the
//! [`StageStatsCache`] storage trait — a single blanket `StatsBackend`
//! impl replaces the per-wrapper forwarding boilerplate.
//!
//! Correctness contract: results are **bit-identical** to the wrapped
//! backend, always. A hash hit is verified against a stored copy of the
//! key fields before use, so a 64-bit collision degrades to a miss rather
//! than a wrong answer; `rust/tests/hotpath_parity.rs` asserts parity
//! (including under eviction pressure) property-style.
//!
//! Sizing: each resident entry holds the key fields plus the
//! [`StageStats`] (~`(14 × tasks + 300) × 8` bytes), so the default
//! capacity of a few hundred entries stays in the tens of megabytes even
//! for 2 000-task stages. Capacity 0 disables caching entirely (every
//! call forwards, counted as a miss).

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

use super::features::StageFeatures;
use super::stats::{StageStats, StatsBackend};

/// Hit/miss/eviction counters, surfaced through
/// [`StatsBackend::cache_counters`] into service metrics and fleet
/// snapshots.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheCounters {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
}

impl CacheCounters {
    /// Hit fraction in [0, 1]; 0 before any lookup.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Owned copy of the fields that determine [`StageStats`], kept per entry
/// so hash collisions can be detected exactly.
#[derive(Debug, Clone)]
struct CacheKey {
    nodes: Vec<usize>,
    durations: Vec<f64>,
    matrix: Vec<f64>,
}

/// THE bitwise equality over the stats-relevant key fields — both the
/// stored-key hit verification ([`CacheKey::matches`]) and the
/// intra-batch duplicate check ([`same_stats_key`]) delegate here, so the
/// correctness-critical predicate cannot drift between them.
/// `f64::to_bits` comparison means NaN keys compare like any other value
/// instead of poisoning the table.
fn stats_key_eq(nodes: &[usize], durations: &[f64], matrix: &[f64], sf: &StageFeatures) -> bool {
    nodes == sf.nodes.as_slice()
        && durations.len() == sf.durations.len()
        && matrix.len() == sf.matrix.len()
        && durations.iter().zip(&sf.durations).all(|(a, b)| a.to_bits() == b.to_bits())
        && matrix.iter().zip(&sf.matrix).all(|(a, b)| a.to_bits() == b.to_bits())
}

/// [`stats_key_eq`] between two live stages (no owned key) — used to spot
/// intra-batch duplicates before dispatching misses.
fn same_stats_key(a: &StageFeatures, b: &StageFeatures) -> bool {
    stats_key_eq(&a.nodes, &a.durations, &a.matrix, b)
}

impl CacheKey {
    fn of(sf: &StageFeatures) -> CacheKey {
        CacheKey {
            nodes: sf.nodes.clone(),
            durations: sf.durations.clone(),
            matrix: sf.matrix.clone(),
        }
    }

    /// Exact (bitwise for floats) match against a stored key.
    fn matches(&self, sf: &StageFeatures) -> bool {
        stats_key_eq(&self.nodes, &self.durations, &self.matrix, sf)
    }
}

/// FNV-1a over the stats-relevant bytes of a stage. 64-bit — collisions
/// are possible in principle, which is why entries verify the full key.
pub fn structural_hash(sf: &StageFeatures) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut eat = |x: u64| {
        for shift in [0u32, 8, 16, 24, 32, 40, 48, 56] {
            h ^= (x >> shift) & 0xff;
            h = h.wrapping_mul(PRIME);
        }
    };
    eat(sf.nodes.len() as u64);
    for &nd in &sf.nodes {
        eat(nd as u64);
    }
    eat(sf.durations.len() as u64);
    for &d in &sf.durations {
        eat(d.to_bits());
    }
    eat(sf.matrix.len() as u64);
    for &v in &sf.matrix {
        eat(v.to_bits());
    }
    h
}

struct Entry {
    key: CacheKey,
    value: StageStats,
    /// Monotone recency tick; the entry's position in `lru`.
    tick: u64,
}

/// The memo engine: one verified-key LRU table. Used directly (single
/// owner) by [`CachedBackend`] and behind a stripe mutex by
/// [`SharedStatsCache`].
pub struct CacheCore {
    capacity: usize,
    /// structural hash → entry. One entry per hash: a colliding insert
    /// replaces (correct either way — the key check decides hit vs miss).
    map: HashMap<u64, Entry>,
    /// recency tick → hash, oldest first (BTreeMap keeps ticks ordered, so
    /// eviction is "remove the first key" without an intrusive list).
    lru: BTreeMap<u64, u64>,
    tick: u64,
    evictions: u64,
}

impl CacheCore {
    pub fn new(capacity: usize) -> Self {
        CacheCore {
            capacity,
            map: HashMap::new(),
            lru: BTreeMap::new(),
            tick: 0,
            evictions: 0,
        }
    }

    fn lookup(&mut self, hash: u64, sf: &StageFeatures) -> Option<StageStats> {
        self.tick += 1;
        let tick = self.tick;
        // One probe: verify the key, bump recency, clone the value.
        let (value, old_tick) = match self.map.get_mut(&hash) {
            Some(e) if e.key.matches(sf) => {
                let old = e.tick;
                e.tick = tick;
                (e.value.clone(), old)
            }
            _ => return None,
        };
        self.lru.remove(&old_tick);
        self.lru.insert(tick, hash);
        Some(value)
    }

    fn insert(&mut self, hash: u64, sf: &StageFeatures, value: StageStats) {
        // Replace a colliding (or stale same-hash) entry outright.
        if let Some(old) = self.map.remove(&hash) {
            self.lru.remove(&old.tick);
        }
        while self.map.len() >= self.capacity {
            let oldest = match self.lru.iter().next() {
                Some((&t, &h)) => (t, h),
                None => break,
            };
            self.lru.remove(&oldest.0);
            self.map.remove(&oldest.1);
            self.evictions += 1;
        }
        self.tick += 1;
        self.lru.insert(self.tick, hash);
        self.map.insert(hash, Entry { key: CacheKey::of(sf), value, tick: self.tick });
    }
}

/// Memo storage behind [`Memoized`] — the one seam between the
/// single-owner and the shared-striped cache. Hit/miss accounting lives in
/// the wrapper (per backend), eviction accounting in the storage (where
/// the eviction happens).
pub trait StageStatsCache {
    /// False ⇒ every call forwards (capacity 0).
    fn enabled(&self) -> bool;
    fn lookup(&mut self, hash: u64, sf: &StageFeatures) -> Option<StageStats>;
    fn store(&mut self, hash: u64, sf: &StageFeatures, value: &StageStats);
    /// Evictions in this storage (global for a shared cache).
    fn evictions(&self) -> u64;
    /// Resident entries (global for a shared cache).
    fn len(&self) -> usize;
    /// Backend name reported through [`StatsBackend::name`].
    fn kind_name(&self) -> &'static str;
}

impl StageStatsCache for CacheCore {
    fn enabled(&self) -> bool {
        self.capacity > 0
    }

    fn lookup(&mut self, hash: u64, sf: &StageFeatures) -> Option<StageStats> {
        CacheCore::lookup(self, hash, sf)
    }

    fn store(&mut self, hash: u64, sf: &StageFeatures, value: &StageStats) {
        CacheCore::insert(self, hash, sf, value.clone());
    }

    fn evictions(&self) -> u64 {
        self.evictions
    }

    fn len(&self) -> usize {
        self.map.len()
    }

    fn kind_name(&self) -> &'static str {
        "cached"
    }
}

/// The cross-worker stage-stats cache: `stripe_count` independent
/// [`CacheCore`]s, each behind its own mutex, selected by the structural
/// hash. Contention is 1/stripes of a single-lock table, and the total
/// capacity is split across stripes (so the configured number bounds
/// resident memory exactly). Capacity 0 disables caching.
pub struct SharedStatsCache {
    capacity: usize,
    stripes: Vec<Mutex<CacheCore>>,
}

impl SharedStatsCache {
    pub fn new(capacity: usize, stripes: usize) -> Self {
        // Never more stripes than capacity — a stripe below one entry
        // would silently inflate the configured bound.
        let n = stripes.max(1).min(capacity.max(1));
        let base = capacity / n;
        let rem = capacity % n;
        SharedStatsCache {
            capacity,
            stripes: (0..n)
                .map(|i| Mutex::new(CacheCore::new(base + usize::from(i < rem))))
                .collect(),
        }
    }

    /// Total configured capacity across all stripes.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn stripe_count(&self) -> usize {
        self.stripes.len()
    }

    fn stripe_of(&self, hash: u64) -> usize {
        // The map inside each stripe keys on the full hash; pick the
        // stripe from the high bits so the two partitions stay independent.
        ((hash >> 32) as usize) % self.stripes.len()
    }

    pub fn lookup(&self, hash: u64, sf: &StageFeatures) -> Option<StageStats> {
        if self.capacity == 0 {
            return None;
        }
        let mut core = self.stripes[self.stripe_of(hash)].lock().unwrap();
        CacheCore::lookup(&mut core, hash, sf)
    }

    pub fn insert(&self, hash: u64, sf: &StageFeatures, value: StageStats) {
        if self.capacity == 0 {
            return;
        }
        let mut core = self.stripes[self.stripe_of(hash)].lock().unwrap();
        CacheCore::insert(&mut core, hash, sf, value);
    }

    /// Resident entries across all stripes.
    pub fn len(&self) -> usize {
        self.stripes.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Evictions across all stripes.
    pub fn evictions(&self) -> u64 {
        self.stripes.iter().map(|s| s.lock().unwrap().evictions).sum()
    }
}

impl StageStatsCache for Arc<SharedStatsCache> {
    fn enabled(&self) -> bool {
        self.capacity > 0
    }

    fn lookup(&mut self, hash: u64, sf: &StageFeatures) -> Option<StageStats> {
        SharedStatsCache::lookup(self.as_ref(), hash, sf)
    }

    fn store(&mut self, hash: u64, sf: &StageFeatures, value: &StageStats) {
        SharedStatsCache::insert(self.as_ref(), hash, sf, value.clone());
    }

    fn evictions(&self) -> u64 {
        SharedStatsCache::evictions(self.as_ref())
    }

    fn len(&self) -> usize {
        SharedStatsCache::len(self.as_ref())
    }

    fn kind_name(&self) -> &'static str {
        "shared-cached"
    }
}

/// A memoizing [`StatsBackend`] wrapper over any [`StageStatsCache`]
/// storage. See module docs. `hits`/`misses` count *this wrapper's*
/// lookups (per worker); `evictions` come from the storage, so for a
/// shared cache they are global.
pub struct Memoized<B, C> {
    inner: B,
    cache: C,
    hits: u64,
    misses: u64,
}

/// Single-owner memo: the classic per-backend LRU (no locks).
pub type CachedBackend<B> = Memoized<B, CacheCore>;

/// Memo over the cross-worker [`SharedStatsCache`].
pub type SharedCachedBackend<B> = Memoized<B, Arc<SharedStatsCache>>;

impl<B: StatsBackend> Memoized<B, CacheCore> {
    pub fn new(inner: B, capacity: usize) -> Self {
        Memoized { inner, cache: CacheCore::new(capacity), hits: 0, misses: 0 }
    }
}

impl<B: StatsBackend> Memoized<B, Arc<SharedStatsCache>> {
    pub fn new(inner: B, cache: Arc<SharedStatsCache>) -> Self {
        Memoized { inner, cache, hits: 0, misses: 0 }
    }
}

impl<B: StatsBackend, C: StageStatsCache> Memoized<B, C> {
    pub fn counters(&self) -> CacheCounters {
        CacheCounters { hits: self.hits, misses: self.misses, evictions: self.cache.evictions() }
    }

    /// This wrapper's own (hits, misses), read without touching the
    /// storage — unlike [`Memoized::counters`], which sums evictions
    /// across every stripe of a shared cache. Hot publish paths (the live
    /// shard workers report after every batch and idle tick) use this to
    /// avoid taking N stripe locks for numbers they don't report.
    pub fn lookup_counts(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Resident entries (in the shared case: across all workers).
    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The wrapped backend (e.g. to read its own counters).
    pub fn inner(&self) -> &B {
        &self.inner
    }
}

// The one blanket impl both memo shapes share — no per-wrapper forwarding.
impl<B: StatsBackend, C: StageStatsCache> StatsBackend for Memoized<B, C> {
    fn stage_stats(&mut self, sf: &StageFeatures) -> StageStats {
        if !self.cache.enabled() {
            self.misses += 1;
            return self.inner.stage_stats(sf);
        }
        let hash = structural_hash(sf);
        let g = crate::obs::span(crate::obs::SpanKind::CacheLookup);
        let found = self.cache.lookup(hash, sf);
        g.finish();
        if let Some(v) = found {
            self.hits += 1;
            return v;
        }
        self.misses += 1;
        let v = self.inner.stage_stats(sf);
        self.cache.store(hash, sf, &v);
        v
    }

    /// Batch-aware memo: look every element up first, then forward *all*
    /// misses to the inner backend as one sub-batch — so a batching inner
    /// backend (the router's large side, an XLA executor) keeps its
    /// amortization instead of degrading to per-element calls. Counter
    /// semantics match the sequential path exactly: an intra-batch
    /// duplicate of a miss is deferred and re-looked-up after the store,
    /// so it counts (and behaves) as a hit.
    fn stage_stats_batch(&mut self, sfs: &[&StageFeatures]) -> Vec<StageStats> {
        if !self.cache.enabled() {
            self.misses += sfs.len() as u64;
            return self.inner.stage_stats_batch(sfs);
        }
        let mut out: Vec<Option<StageStats>> = sfs.iter().map(|_| None).collect();
        let mut hashes: Vec<u64> = Vec::with_capacity(sfs.len());
        // First occurrences of missing shapes, dispatched as one batch.
        let mut miss_idx: Vec<usize> = Vec::new();
        // Later occurrences of an in-batch miss: resolved after the store.
        let mut dup_idx: Vec<usize> = Vec::new();
        for (i, sf) in sfs.iter().enumerate() {
            let hash = structural_hash(sf);
            hashes.push(hash);
            let g = crate::obs::span(crate::obs::SpanKind::CacheLookup);
            let found = self.cache.lookup(hash, sf);
            g.finish();
            if let Some(v) = found {
                self.hits += 1;
                out[i] = Some(v);
                continue;
            }
            let dup = miss_idx
                .iter()
                .any(|&j| hashes[j] == hash && same_stats_key(sfs[j], sf));
            if dup {
                dup_idx.push(i);
            } else {
                self.misses += 1;
                miss_idx.push(i);
            }
        }
        if !miss_idx.is_empty() {
            let refs: Vec<&StageFeatures> = miss_idx.iter().map(|&i| sfs[i]).collect();
            let computed = self.inner.stage_stats_batch(&refs);
            assert_eq!(computed.len(), refs.len(), "backend returned wrong batch size");
            for (j, v) in computed.into_iter().enumerate() {
                let i = miss_idx[j];
                self.cache.store(hashes[i], sfs[i], &v);
                out[i] = Some(v);
            }
        }
        for i in dup_idx {
            // Normally a hit on the entry just stored; under extreme
            // eviction pressure within this batch, fall back to the
            // single-stage path (which recomputes and recounts correctly).
            out[i] = Some(match self.cache.lookup(hashes[i], sfs[i]) {
                Some(v) => {
                    self.hits += 1;
                    v
                }
                None => self.stage_stats(sfs[i]),
            });
        }
        out.into_iter().map(|o| o.expect("memo covered every stage")).collect()
    }

    fn name(&self) -> &'static str {
        self.cache.kind_name()
    }

    fn cache_counters(&self) -> Option<CacheCounters> {
        Some(self.counters())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::features::FeatureKind as F;
    use crate::analysis::stats::{compute_native, NativeBackend};

    fn stage(seed: u64, n: usize) -> StageFeatures {
        let f = F::COUNT;
        let mut rng = crate::util::rng::Pcg64::seeded(seed);
        StageFeatures {
            stage_id: seed,
            task_ids: (0..n as u64).collect(),
            nodes: (0..n).map(|r| r % 3).collect(),
            durations: (0..n).map(|_| rng.range_f64(0.5, 5.0)).collect(),
            matrix: (0..n * f).map(|_| rng.range_f64(0.0, 4.0)).collect(),
            head_means: vec![0.0; n * 3],
            tail_means: vec![0.0; n * 3],
        }
    }

    #[test]
    fn hit_returns_identical_stats() {
        let mut c = CachedBackend::new(NativeBackend::new(), 8);
        let sf = stage(1, 20);
        let first = c.stage_stats(&sf);
        let second = c.stage_stats(&sf);
        assert_eq!(first, second);
        assert_eq!(first, compute_native(&sf));
        assert_eq!(c.counters(), CacheCounters { hits: 1, misses: 1, evictions: 0 });
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn ids_do_not_affect_the_key() {
        // stage_id / task_ids don't influence StageStats — same matrix
        // under different ids must hit.
        let mut c = CachedBackend::new(NativeBackend::new(), 8);
        let a = stage(2, 12);
        let mut b = a.clone();
        b.stage_id = 999;
        b.task_ids = (100..112).collect();
        let ra = c.stage_stats(&a);
        let rb = c.stage_stats(&b);
        assert_eq!(ra, rb);
        assert_eq!(c.counters().hits, 1);
    }

    #[test]
    fn different_matrices_miss() {
        let mut c = CachedBackend::new(NativeBackend::new(), 8);
        let a = stage(3, 10);
        let mut b = a.clone();
        b.matrix[0] += 1.0;
        c.stage_stats(&a);
        c.stage_stats(&b);
        assert_eq!(c.counters(), CacheCounters { hits: 0, misses: 2, evictions: 0 });
        assert_eq!(c.stage_stats(&b), compute_native(&b));
        assert_eq!(c.counters().hits, 1);
    }

    #[test]
    fn eviction_is_lru_and_results_stay_correct() {
        let mut c = CachedBackend::new(NativeBackend::new(), 2);
        let s1 = stage(10, 8);
        let s2 = stage(11, 8);
        let s3 = stage(12, 8);
        c.stage_stats(&s1);
        c.stage_stats(&s2);
        c.stage_stats(&s1); // s1 most recent; s2 is now LRU
        c.stage_stats(&s3); // evicts s2
        assert_eq!(c.counters().evictions, 1);
        assert_eq!(c.len(), 2);
        // s1 still resident → hit; s2 evicted → recomputed, still right.
        let hits_before = c.counters().hits;
        assert_eq!(c.stage_stats(&s1), compute_native(&s1));
        assert_eq!(c.counters().hits, hits_before + 1);
        assert_eq!(c.stage_stats(&s2), compute_native(&s2));
        assert_eq!(c.counters().misses, 4);
    }

    #[test]
    fn capacity_zero_disables_caching() {
        let mut c = CachedBackend::new(NativeBackend::new(), 0);
        let sf = stage(4, 6);
        assert_eq!(c.stage_stats(&sf), compute_native(&sf));
        assert_eq!(c.stage_stats(&sf), compute_native(&sf));
        assert_eq!(c.counters(), CacheCounters { hits: 0, misses: 2, evictions: 0 });
        assert!(c.is_empty());
    }

    #[test]
    fn batch_goes_through_the_cache() {
        let mut c = CachedBackend::new(NativeBackend::new(), 8);
        let a = stage(5, 10);
        let b = stage(6, 10);
        let refs = vec![&a, &b, &a];
        let out = c.stage_stats_batch(&refs);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0], out[2]);
        assert_eq!(out[1], compute_native(&b));
        assert_eq!(c.counters(), CacheCounters { hits: 1, misses: 2, evictions: 0 });
    }

    #[test]
    fn nan_keys_are_cacheable() {
        // NaN != NaN, but keys compare by bits — a NaN-bearing stage must
        // hit on resubmission rather than recompute forever.
        let mut c = CachedBackend::new(NativeBackend::new(), 4);
        let mut sf = stage(7, 6);
        sf.matrix[0] = f64::NAN;
        let a = c.stage_stats(&sf);
        let b = c.stage_stats(&sf);
        assert_eq!(c.counters().hits, 1);
        // Compare through Debug: StageStats PartialEq is false under NaN.
        assert_eq!(format!("{a:?}"), format!("{b:?}"));
    }

    #[test]
    fn hit_rate() {
        let mut cc = CacheCounters::default();
        assert_eq!(cc.hit_rate(), 0.0);
        cc.hits = 3;
        cc.misses = 1;
        assert!((cc.hit_rate() - 0.75).abs() < 1e-12);
    }

    // ---- shared cache ----

    #[test]
    fn shared_cache_hits_across_backends() {
        // Backend A computes; backend B (a different worker) hits the
        // shared table — the cross-shard contract of the live server.
        let cache = Arc::new(SharedStatsCache::new(64, 4));
        let mut a = SharedCachedBackend::new(NativeBackend::new(), Arc::clone(&cache));
        let mut b = SharedCachedBackend::new(NativeBackend::new(), Arc::clone(&cache));
        let sf = stage(20, 16);
        let ra = a.stage_stats(&sf);
        let rb = b.stage_stats(&sf);
        assert_eq!(ra, rb);
        assert_eq!(ra, compute_native(&sf));
        assert_eq!(a.counters().misses, 1);
        assert_eq!(a.counters().hits, 0);
        assert_eq!(b.counters().hits, 1, "second worker must hit the shared entry");
        assert_eq!(b.counters().misses, 0);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn shared_cache_capacity_splits_across_stripes() {
        let c = SharedStatsCache::new(10, 4);
        assert_eq!(c.capacity(), 10);
        assert_eq!(c.stripe_count(), 4);
        // Never more stripes than capacity.
        let tiny = SharedStatsCache::new(2, 16);
        assert_eq!(tiny.stripe_count(), 2);
        let off = SharedStatsCache::new(0, 8);
        assert_eq!(off.stripe_count(), 1);
    }

    #[test]
    fn shared_cache_capacity_zero_disables() {
        let cache = Arc::new(SharedStatsCache::new(0, 4));
        let mut b = SharedCachedBackend::new(NativeBackend::new(), Arc::clone(&cache));
        let sf = stage(21, 8);
        assert_eq!(b.stage_stats(&sf), compute_native(&sf));
        assert_eq!(b.stage_stats(&sf), compute_native(&sf));
        assert_eq!(b.counters(), CacheCounters { hits: 0, misses: 2, evictions: 0 });
        assert!(cache.is_empty());
    }

    #[test]
    fn shared_cache_evicts_within_capacity() {
        // One stripe so the LRU order is observable; capacity 2.
        let cache = Arc::new(SharedStatsCache::new(2, 1));
        let mut b = SharedCachedBackend::new(NativeBackend::new(), Arc::clone(&cache));
        let s1 = stage(30, 8);
        let s2 = stage(31, 8);
        let s3 = stage(32, 8);
        b.stage_stats(&s1);
        b.stage_stats(&s2);
        b.stage_stats(&s3); // evicts s1 (LRU)
        assert_eq!(cache.evictions(), 1);
        assert!(cache.len() <= 2);
        // Every result still bit-identical.
        assert_eq!(b.stage_stats(&s1), compute_native(&s1));
    }

    #[test]
    fn shared_cache_concurrent_mixed_shapes_stay_correct() {
        // Hammer one shared cache from several threads over overlapping
        // shapes; every returned result must equal the uncached compute.
        let cache = Arc::new(SharedStatsCache::new(8, 4));
        let shapes: Vec<StageFeatures> = (0..6).map(|i| stage(40 + i, 10)).collect();
        let want: Vec<StageStats> = shapes.iter().map(compute_native).collect();
        let shapes = Arc::new(shapes);
        let want = Arc::new(want);
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let cache = Arc::clone(&cache);
            let shapes = Arc::clone(&shapes);
            let want = Arc::clone(&want);
            handles.push(std::thread::spawn(move || {
                let mut b = SharedCachedBackend::new(NativeBackend::new(), cache);
                for round in 0..20 {
                    let i = ((t + round) % shapes.len() as u64) as usize;
                    assert_eq!(b.stage_stats(&shapes[i]), want[i]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
