//! Multi-backend routing — dispatch each stage to the backend that suits
//! its size.
//!
//! The stats kernel has two implementations with opposite cost profiles:
//! the pure-rust [`NativeBackend`] (zero dispatch overhead, great for the
//! many small stages a busy fleet produces) and the AOT-compiled XLA
//! artifact behind [`crate::runtime::XlaBackend`] (per-call
//! transfer/selection overhead, amortized on large stages). Sending every
//! stage to one of them wastes the other's sweet spot. [`RoutingBackend`]
//! splits the stream by a size predicate: stages with fewer than
//! `large_task_threshold` tasks go to the *small* backend, the rest to the
//! *large* one. `stage_stats_batch` partitions a batch once and forwards
//! each side as a single sub-batch, so the large backend still amortizes
//! its dispatch overhead.
//!
//! Without the `pjrt` feature (or without `artifacts/`), the large side
//! degrades to a second native backend — routing is then a no-op for
//! results (bit-identical both sides), which is exactly what keeps the
//! parity test suite meaningful while the XLA path stays feature-gated.

use super::features::StageFeatures;
use super::stats::{NativeBackend, StageStats, StatsBackend};
use crate::analysis::cache::CacheCounters;

/// Default task-count boundary between "small" (native) and "large"
/// (XLA-capable) stages — matches the artifact bucket range where batched
/// dispatch starts paying for itself.
pub const DEFAULT_LARGE_TASK_THRESHOLD: usize = 256;

/// Size-predicate dispatcher over two [`StatsBackend`]s. See module docs.
pub struct RoutingBackend<S, L> {
    small: S,
    large: L,
    large_task_threshold: usize,
    small_count: usize,
    large_count: usize,
}

impl<S: StatsBackend, L: StatsBackend> RoutingBackend<S, L> {
    /// Route stages with `>= large_task_threshold` tasks to `large`,
    /// the rest to `small`. A threshold of 0 is floored at 1 (an empty
    /// stage is still "small").
    pub fn new(small: S, large: L, large_task_threshold: usize) -> Self {
        RoutingBackend {
            small,
            large,
            large_task_threshold: large_task_threshold.max(1),
            small_count: 0,
            large_count: 0,
        }
    }

    fn is_large(&self, sf: &StageFeatures) -> bool {
        sf.num_tasks() >= self.large_task_threshold
    }

    /// (stages routed small, stages routed large) so far.
    pub fn route_counts(&self) -> (usize, usize) {
        (self.small_count, self.large_count)
    }

    pub fn large_task_threshold(&self) -> usize {
        self.large_task_threshold
    }
}

impl<S: StatsBackend, L: StatsBackend> StatsBackend for RoutingBackend<S, L> {
    fn stage_stats(&mut self, sf: &StageFeatures) -> StageStats {
        if self.is_large(sf) {
            self.large_count += 1;
            self.large.stage_stats(sf)
        } else {
            self.small_count += 1;
            self.small.stage_stats(sf)
        }
    }

    /// Partition once, dispatch each side as one sub-batch (the large
    /// backend amortizes its per-call overhead), reassemble in input
    /// order.
    fn stage_stats_batch(&mut self, sfs: &[&StageFeatures]) -> Vec<StageStats> {
        let mut small_idx: Vec<usize> = Vec::new();
        let mut large_idx: Vec<usize> = Vec::new();
        for (i, sf) in sfs.iter().enumerate() {
            if self.is_large(sf) {
                large_idx.push(i);
            } else {
                small_idx.push(i);
            }
        }
        let mut out: Vec<Option<StageStats>> = sfs.iter().map(|_| None).collect();
        if !small_idx.is_empty() {
            let refs: Vec<&StageFeatures> = small_idx.iter().map(|&i| sfs[i]).collect();
            let stats = self.small.stage_stats_batch(&refs);
            assert_eq!(stats.len(), refs.len(), "small backend returned wrong batch size");
            for (j, st) in stats.into_iter().enumerate() {
                out[small_idx[j]] = Some(st);
            }
            self.small_count += small_idx.len();
        }
        if !large_idx.is_empty() {
            let refs: Vec<&StageFeatures> = large_idx.iter().map(|&i| sfs[i]).collect();
            let stats = self.large.stage_stats_batch(&refs);
            assert_eq!(stats.len(), refs.len(), "large backend returned wrong batch size");
            for (j, st) in stats.into_iter().enumerate() {
                out[large_idx[j]] = Some(st);
            }
            self.large_count += large_idx.len();
        }
        out.into_iter().map(|o| o.expect("router covered every stage")).collect()
    }

    fn name(&self) -> &'static str {
        "routing"
    }

    /// Sum of the two sides' memo counters, if either side memoizes.
    fn cache_counters(&self) -> Option<CacheCounters> {
        match (self.small.cache_counters(), self.large.cache_counters()) {
            (None, None) => None,
            (a, b) => {
                let a = a.unwrap_or_default();
                let b = b.unwrap_or_default();
                Some(CacheCounters {
                    hits: a.hits + b.hits,
                    misses: a.misses + b.misses,
                    evictions: a.evictions + b.evictions,
                })
            }
        }
    }
}

/// The large-stage backend available to *worker threads*. Real XLA
/// execution needs the `pjrt` feature (the default build's stub PJRT
/// client cannot open) **and** a `Send`-proven PJRT client (the `xla`
/// crate's thread affinity is unverified) — neither holds today, so
/// worker threads run the large side natively and only the
/// single-threaded offline pipeline ([`auto_routed_backend`], which is
/// free of the `Send` bound) dispatches to real XLA when artifacts
/// exist. This function is the seam: once a `Send` device backend lands,
/// returning it here lights up every service and live-shard worker with
/// no other change.
pub fn auto_large_backend() -> Box<dyn StatsBackend + Send> {
    Box::new(NativeBackend::new())
}

/// The offline auto-routed backend: native small side, best-available
/// (XLA if artifacts exist) large side, default threshold. Single-threaded
/// contexts only — the large side is not required to be `Send` here, so
/// real PJRT clients qualify.
pub fn auto_routed_backend() -> RoutingBackend<NativeBackend, Box<dyn StatsBackend>> {
    RoutingBackend::new(
        NativeBackend::new(),
        crate::runtime::auto_backend(),
        DEFAULT_LARGE_TASK_THRESHOLD,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::features::FeatureKind as F;
    use crate::analysis::stats::compute_native;

    fn stage(seed: u64, n: usize) -> StageFeatures {
        let f = F::COUNT;
        let mut rng = crate::util::rng::Pcg64::seeded(seed);
        StageFeatures {
            stage_id: seed,
            task_ids: (0..n as u64).collect(),
            nodes: (0..n).map(|r| r % 2).collect(),
            durations: (0..n).map(|_| rng.range_f64(0.5, 5.0)).collect(),
            matrix: (0..n * f).map(|_| rng.range_f64(0.0, 4.0)).collect(),
            head_means: vec![0.0; n * 3],
            tail_means: vec![0.0; n * 3],
        }
    }

    #[test]
    fn routes_by_task_count() {
        let mut r = RoutingBackend::new(NativeBackend::new(), NativeBackend::new(), 10);
        let small = stage(1, 4);
        let large = stage(2, 16);
        assert_eq!(r.stage_stats(&small), compute_native(&small));
        assert_eq!(r.stage_stats(&large), compute_native(&large));
        assert_eq!(r.route_counts(), (1, 1));
        assert_eq!(r.name(), "routing");
        assert!(r.cache_counters().is_none(), "two native sides expose no memo");
    }

    #[test]
    fn batch_partitions_and_preserves_order() {
        let mut r = RoutingBackend::new(NativeBackend::new(), NativeBackend::new(), 10);
        let stages: Vec<StageFeatures> =
            [3usize, 20, 5, 11, 9, 30].iter().enumerate().map(|(i, &n)| stage(10 + i as u64, n)).collect();
        let refs: Vec<&StageFeatures> = stages.iter().collect();
        let out = r.stage_stats_batch(&refs);
        assert_eq!(out.len(), stages.len());
        for (got, sf) in out.iter().zip(&stages) {
            assert_eq!(got, &compute_native(sf), "stage {} tasks", sf.num_tasks());
        }
        assert_eq!(r.route_counts(), (3, 3));
    }

    #[test]
    fn threshold_edge_goes_large_and_zero_floors() {
        let mut r = RoutingBackend::new(NativeBackend::new(), NativeBackend::new(), 8);
        let edge = stage(40, 8); // exactly the threshold → large
        r.stage_stats(&edge);
        assert_eq!(r.route_counts(), (0, 1));
        let floored = RoutingBackend::new(NativeBackend::new(), NativeBackend::new(), 0);
        assert_eq!(floored.large_task_threshold(), 1);
    }

    #[test]
    fn memoized_side_surfaces_counters() {
        use crate::analysis::cache::CachedBackend;
        let mut r = RoutingBackend::new(
            CachedBackend::new(NativeBackend::new(), 8),
            NativeBackend::new(),
            1_000_000, // everything routes small
        );
        let sf = stage(50, 12);
        r.stage_stats(&sf);
        r.stage_stats(&sf);
        let c = r.cache_counters().expect("memoizing small side");
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn auto_large_backend_works_without_artifacts() {
        let mut b = auto_large_backend();
        let sf = stage(60, 6);
        assert_eq!(b.stage_stats(&sf), compute_native(&sf));
    }

    #[test]
    fn auto_routed_backend_matches_native() {
        let mut r = auto_routed_backend();
        for n in [2usize, 100, 300] {
            let sf = stage(70 + n as u64, n);
            // Without artifacts both sides are native → exact match. (With
            // artifacts the large side is XLA and parity is asserted at
            // f32 tolerance in rust/tests/backend_parity.rs instead.)
            if std::path::Path::new("artifacts/manifest.json").exists() {
                let _ = r.stage_stats(&sf);
            } else {
                assert_eq!(r.stage_stats(&sf), compute_native(&sf));
            }
        }
    }
}
