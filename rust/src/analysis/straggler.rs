//! Straggler detection — the Mantri definition the paper adopts: a task is
//! a straggler when its duration exceeds `ratio` × the *median* task
//! duration of its stage (ratio = 1.5).

use super::features::StageFeatures;
use crate::util::stats::median;

/// Detection result for one stage.
#[derive(Debug, Clone, PartialEq)]
pub struct StragglerSet {
    /// Median task duration of the stage (s).
    pub median: f64,
    /// Duration threshold = ratio × median.
    pub threshold: f64,
    /// Row indices (into the stage's feature matrix) of stragglers.
    pub rows: Vec<usize>,
}

impl StragglerSet {
    pub fn is_straggler(&self, row: usize) -> bool {
        self.rows.binary_search(&row).is_ok()
    }

    /// Task ids of the flagged rows, in row order — the provenance layer
    /// ([`crate::analysis::explain`]) records these with every verdict.
    pub fn flagged_task_ids(&self, sf: &StageFeatures) -> Vec<u64> {
        self.rows.iter().map(|&r| sf.task_ids[r]).collect()
    }

    /// Straggler *scale* of a task: duration / median (the right-hand y-axis
    /// of Figures 3–6).
    pub fn scale(&self, duration: f64) -> f64 {
        if self.median > 0.0 {
            duration / self.median
        } else {
            0.0
        }
    }
}

/// Detect stragglers in a stage.
pub fn detect(sf: &StageFeatures, ratio: f64) -> StragglerSet {
    let med = median(&sf.durations);
    let threshold = ratio * med;
    let rows: Vec<usize> = (0..sf.num_tasks())
        .filter(|&r| sf.durations[r] > threshold && sf.durations[r] > 0.0)
        .collect();
    StragglerSet { median: med, threshold, rows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::features::FeatureKind;

    fn sf(durations: Vec<f64>) -> StageFeatures {
        let n = durations.len();
        StageFeatures {
            stage_id: 0,
            task_ids: (0..n as u64).collect(),
            nodes: vec![0; n],
            durations,
            matrix: vec![0.0; n * FeatureKind::COUNT],
            head_means: vec![0.0; n * 3],
            tail_means: vec![0.0; n * 3],
        }
    }

    #[test]
    fn flags_only_above_threshold() {
        let s = detect(&sf(vec![1.0, 1.0, 1.0, 1.4, 1.6, 3.0]), 1.5);
        assert_eq!(s.median, 1.2);
        assert!((s.threshold - 1.8).abs() < 1e-12);
        assert_eq!(s.rows, vec![5]);
        assert!(s.is_straggler(5));
        assert!(!s.is_straggler(4));
    }

    #[test]
    fn boundary_is_strict() {
        // Exactly 1.5× the median is NOT a straggler ("1.5× larger").
        let s = detect(&sf(vec![2.0, 2.0, 2.0, 3.0]), 1.5);
        assert!(s.rows.is_empty());
    }

    #[test]
    fn empty_and_single() {
        assert!(detect(&sf(vec![]), 1.5).rows.is_empty());
        assert!(detect(&sf(vec![5.0]), 1.5).rows.is_empty());
    }

    #[test]
    fn scale_is_duration_over_median() {
        let s = detect(&sf(vec![1.0, 2.0, 3.0]), 1.5);
        assert_eq!(s.median, 2.0);
        assert!((s.scale(5.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn monotone_in_ratio() {
        // Raising the ratio can only shrink the straggler set.
        let d = vec![1.0, 1.1, 1.2, 1.9, 2.5, 4.0, 0.9, 1.05];
        let lo = detect(&sf(d.clone()), 1.2);
        let hi = detect(&sf(d), 2.0);
        for r in &hi.rows {
            assert!(lo.rows.contains(r));
        }
        assert!(hi.rows.len() <= lo.rows.len());
    }

    #[test]
    fn all_equal_durations_no_stragglers() {
        let s = detect(&sf(vec![2.0; 50]), 1.5);
        assert!(s.rows.is_empty());
    }
}
