//! The PCC baseline — Pearson-correlation root-cause analysis (Eq. 8),
//! the comparison method of Sections IV-B/IV-C (used by prior work
//! [17, 18] in the paper's references).
//!
//! A feature F of a straggler is a root cause iff
//!
//! - `|ρ(F, duration)| > λ_ca` over the stage (Pearson threshold), and
//! - `F > quantile(max_threshold)` over the stage (the "how close to the
//!   max" condition).
//!
//! Both thresholds are swept in the Fig. 8 ROC bench.

use super::features::{FeatureKind, StageFeatures};
use super::stats::{StageStats, StatsBackend};
use super::straggler::detect;
use super::bigroots::{RootCause, PeerEvidence, StageAnalysis};

/// PCC configuration.
#[derive(Debug, Clone, Copy)]
pub struct PccConfig {
    pub straggler_ratio: f64,
    /// λ_ca: minimum |Pearson correlation| between feature and duration.
    pub pearson_threshold: f64,
    /// Quantile the straggler's feature value must exceed ("max threshold").
    pub max_quantile: f64,
}

impl Default for PccConfig {
    fn default() -> Self {
        PccConfig { straggler_ratio: 1.5, pearson_threshold: 0.5, max_quantile: 0.8 }
    }
}

/// Run the PCC baseline on one stage.
pub fn analyze_stage(
    sf: &StageFeatures,
    backend: &mut dyn StatsBackend,
    cfg: &PccConfig,
) -> StageAnalysis {
    let stats = backend.stage_stats(sf);
    analyze_stage_with_stats(sf, &stats, cfg)
}

/// PCC identification given precomputed stats.
pub fn analyze_stage_with_stats(
    sf: &StageFeatures,
    stats: &StageStats,
    cfg: &PccConfig,
) -> StageAnalysis {
    let stragglers = detect(sf, cfg.straggler_ratio);
    let mut causes = Vec::new();
    for &row in &stragglers.rows {
        for &k in &FeatureKind::ALL {
            let rho = stats.pearson[k.index()];
            if rho.abs() <= cfg.pearson_threshold {
                continue;
            }
            let v = sf.get(row, k);
            let gq = stats.quantile(k, cfg.max_quantile);
            if v > gq && v > 0.0 {
                causes.push(RootCause {
                    row,
                    task_id: sf.task_ids[row],
                    kind: k,
                    value: v,
                    global_threshold: gq,
                    // PCC has no peer-group notion; record the evidence slot
                    // as inter-node (whole-stage correlation).
                    peer: PeerEvidence::InterNode,
                });
            }
        }
    }
    StageAnalysis { stage_id: sf.stage_id, stragglers, causes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::features::FeatureKind as F;
    use crate::analysis::stats::NativeBackend;

    /// Stage where feature `k` is linearly tied to duration (ρ = 1) and one
    /// task is a straggler.
    fn correlated_stage(k: F, n: usize) -> StageFeatures {
        let f = F::COUNT;
        let mut matrix = vec![0.0; n * f];
        let mut durations = Vec::with_capacity(n);
        for r in 0..n {
            // Durations ~1.0 with one huge outlier at the end.
            let d = if r == n - 1 { 4.0 } else { 1.0 + r as f64 * 0.01 };
            durations.push(d);
            matrix[r * f + k.index()] = d * 2.0; // perfectly correlated
        }
        StageFeatures {
            stage_id: 0,
            task_ids: (0..n as u64).collect(),
            nodes: (0..n).map(|r| r % 4).collect(),
            durations,
            matrix,
            head_means: vec![0.0; n * 3],
            tail_means: vec![0.0; n * 3],
        }
    }

    #[test]
    fn correlated_feature_identified() {
        let sf = correlated_stage(F::BytesRead, 20);
        let a = analyze_stage(&sf, &mut NativeBackend::new(), &PccConfig::default());
        assert_eq!(a.stragglers.rows, vec![19]);
        assert!(a.causes_of(19).iter().any(|c| c.kind == F::BytesRead));
    }

    #[test]
    fn uncorrelated_feature_ignored() {
        // Feature high on the straggler but constant elsewhere in a pattern
        // with low correlation: alternate high/low independent of duration.
        let f = F::COUNT;
        let n = 21;
        let mut sf = correlated_stage(F::BytesRead, n);
        // Overwrite GC column with alternating values uncorrelated with dur.
        for r in 0..n {
            sf.matrix[r * f + F::JvmGcTime.index()] = if r % 2 == 0 { 0.8 } else { 0.1 };
        }
        let a = analyze_stage(&sf, &mut NativeBackend::new(), &PccConfig::default());
        assert!(a.causes_of(20).iter().all(|c| c.kind != F::JvmGcTime));
    }

    #[test]
    fn pcc_false_positives_on_co_correlated_features() {
        // The paper's critique: features correlated with duration get
        // flagged even when they are consequences, not causes. Two features
        // both ∝ duration → both flagged for the straggler.
        let f = F::COUNT;
        let n = 20;
        let mut sf = correlated_stage(F::BytesRead, n);
        for r in 0..n {
            sf.matrix[r * f + F::ShuffleWriteBytes.index()] = sf.durations[r] * 3.0;
        }
        let a = analyze_stage(&sf, &mut NativeBackend::new(), &PccConfig::default());
        let kinds: Vec<_> = a.causes_of(n - 1).iter().map(|c| c.kind).collect();
        assert!(kinds.contains(&F::BytesRead));
        assert!(kinds.contains(&F::ShuffleWriteBytes), "PCC flags the co-correlate too");
    }

    #[test]
    fn thresholds_monotone() {
        let sf = correlated_stage(F::BytesRead, 30);
        let lo = analyze_stage(
            &sf,
            &mut NativeBackend::new(),
            &PccConfig { pearson_threshold: 0.1, max_quantile: 0.5, ..Default::default() },
        );
        let hi = analyze_stage(
            &sf,
            &mut NativeBackend::new(),
            &PccConfig { pearson_threshold: 0.99, max_quantile: 0.99, ..Default::default() },
        );
        assert!(hi.causes.len() <= lo.causes.len());
    }

    #[test]
    fn non_straggler_rows_unflagged() {
        let sf = correlated_stage(F::BytesRead, 20);
        let a = analyze_stage(&sf, &mut NativeBackend::new(), &PccConfig::default());
        for c in &a.causes {
            assert!(a.stragglers.is_straggler(c.row));
        }
    }
}
