//! The BigRoots analyzer — the paper's contribution (Section III).
//!
//! - [`features`] — feature extraction (Eq. 1–4, Table II): the
//!   `tasks × features` matrix per stage
//! - [`stats`] — batched stage statistics (quantile grid, Pearson, per-node
//!   sums) behind the [`stats::StatsBackend`] trait (native or XLA)
//! - [`cache`] — stage-stats memoization keyed on a structural hash of the
//!   feature matrix: the single-owner [`cache::CachedBackend`] for the
//!   offline pipeline, and the lock-striped [`cache::SharedStatsCache`]
//!   behind [`cache::SharedCachedBackend`] shared by every service /
//!   live-shard worker (repeated stage shapes hit regardless of shard
//!   routing)
//! - [`router`] — [`router::RoutingBackend`], size-predicate multi-backend
//!   dispatch (native for small stages, XLA-capable for large)
//! - [`straggler`] — Mantri-style detection (1.5× stage median)
//! - [`bigroots`] — the identification rules (Eq. 5–7) incl. edge detection
//! - [`pcc`] — the Pearson-correlation baseline (Eq. 8)
//! - [`roc`] — ground-truth scoring, ROC sweeps, AUC (Eq. 9, Fig. 8/9)
//! - [`report`] — straggler annotations, Table VI summaries, figure CSVs
//! - [`whatif`] — counterfactual what-if engine: rank detected causes by
//!   estimated completion-time saved via deterministic trace replay
//! - [`explain`] — verdict provenance: per-cause thresholds, stage
//!   baselines, fleet percentiles, confidence scores, co-occurrence
//!   groups, and bit-identical flight-dump replay

pub mod bigroots;
pub mod cache;
pub mod correlation;
pub mod explain;
pub mod features;
pub mod pcc;
pub mod report;
pub mod roc;
pub mod router;
pub mod stats;
pub mod straggler;
pub mod whatif;

pub use bigroots::{analyze_stage, BigRootsConfig, RootCause, StageAnalysis};
pub use cache::{CacheCounters, CachedBackend, SharedCachedBackend, SharedStatsCache};
pub use correlation::{feature_correlations, joint_causes, FeatureCorrelations, JointCause};
pub use explain::{explain_stage, job_verdict_json, CauseTrace, FlightDump, VerdictTrace};
pub use features::{extract_all, extract_stage, FeatureCategory, FeatureKind, StageFeatures};
pub use pcc::PccConfig;
pub use roc::{ground_truth, score, Confusion, GroundTruth};
pub use router::RoutingBackend;
pub use stats::{NativeBackend, StageStats, StatsBackend};
pub use straggler::{detect, StragglerSet};
pub use whatif::{CauseSavings, WhatIfConfig, WhatIfReport};
