//! Resource samplers — the simulated mpstat / iostat / sar.
//!
//! The engine's resource model records an exact piecewise-constant
//! utilization timeline per node. The paper's tools instead *sample* at
//! 1 Hz; this module integrates the timeline into 1-second buckets and can
//! optionally add sampling jitter, producing the `NodeSeries` the analyzer
//! consumes (Eq. 1–3 average exactly these samples over [t0, t1]).
//!
//! It also implements the Table VII overhead measurement: a real OS thread
//! that wakes at the sampling period and snapshots a shared utilization
//! value, whose CPU cost and memory footprint we measure.

use super::resources::NodeResources;
use crate::trace::NodeSeries;
use crate::util::rng::Pcg64;

/// Sampler configuration.
#[derive(Debug, Clone, Copy)]
pub struct SamplerConfig {
    /// Sampling period in seconds (paper: 1.0).
    pub period: f64,
    /// Multiplicative jitter stddev on each sample (measurement noise of
    /// the real tools); 0.0 disables.
    pub jitter: f64,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig { period: 1.0, jitter: 0.05 }
    }
}

/// Convert one node's exact utilization timelines into sampled series.
pub fn sample_node(
    res: &NodeResources,
    cfg: &SamplerConfig,
    horizon: f64,
    rng: &mut Pcg64,
) -> NodeSeries {
    let jitter = |rng: &mut Pcg64, v: f64| {
        if cfg.jitter > 0.0 {
            (v * (1.0 + rng.normal_ms(0.0, cfg.jitter))).max(0.0)
        } else {
            v
        }
    };
    let cpu: Vec<f64> = res
        .cpu
        .bucketize(cfg.period, horizon)
        .into_iter()
        .map(|v| jitter(rng, v).min(1.0))
        .collect();
    let disk: Vec<f64> = res
        .disk
        .bucketize(cfg.period, horizon)
        .into_iter()
        .map(|v| jitter(rng, v).min(1.0))
        .collect();
    let net_bytes: Vec<f64> = res
        .net
        .bucketize(cfg.period, horizon)
        .into_iter()
        // Net series stores bytes transferred in the bucket (rate × period).
        .map(|v| jitter(rng, v) * cfg.period)
        .collect();
    NodeSeries { node: res.node, period: cfg.period, cpu, disk, net_bytes }
}

/// Overhead measurement of a real sampling thread (Table VII).
///
/// Spawns a thread that wakes every `period` and reads a shared value
/// (the equivalent of parsing /proc — we also do a small fixed amount of
/// parsing work to be honest about per-wake cost), for `duration`. Returns
/// (cpu_fraction, approx_resident_bytes).
pub fn measure_sampler_overhead(period_s: f64, duration_s: f64) -> (f64, usize) {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::Arc;

    let stop = Arc::new(AtomicBool::new(false));
    let busy_ns = Arc::new(AtomicU64::new(0));
    let shared = Arc::new(AtomicU64::new(0));

    let stop2 = Arc::clone(&stop);
    let busy2 = Arc::clone(&busy_ns);
    let shared2 = Arc::clone(&shared);
    // The sampler's working set: a line buffer like the real tools keep.
    let handle = std::thread::spawn(move || {
        let mut buf = String::with_capacity(4096);
        let mut samples: Vec<f64> = Vec::with_capacity(1024);
        while !stop2.load(Ordering::Relaxed) {
            let t0 = std::time::Instant::now();
            // "Parse /proc": format + parse a stat line, store the sample.
            let raw = shared2.fetch_add(1, Ordering::Relaxed);
            buf.clear();
            use std::fmt::Write as _;
            let _ = write!(buf, "cpu {} {} {} {}", raw, raw / 2, raw / 3, raw / 4);
            let parsed: f64 = buf
                .split_whitespace()
                .skip(1)
                .filter_map(|t| t.parse::<f64>().ok())
                .sum();
            samples.push(parsed);
            if samples.len() == samples.capacity() {
                samples.clear(); // bounded buffer like a ring
            }
            busy2.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
            std::thread::sleep(std::time::Duration::from_secs_f64(period_s));
        }
        (buf.capacity(), samples.capacity() * std::mem::size_of::<f64>())
    });

    std::thread::sleep(std::time::Duration::from_secs_f64(duration_s));
    stop.store(true, Ordering::Relaxed);
    let (buf_cap, samples_bytes) = handle.join().unwrap();
    let cpu_frac = busy_ns.load(Ordering::Relaxed) as f64 / 1e9 / duration_s;
    // Resident estimate: thread stack page + buffers (the real tools sit
    // under 1 MB RSS; we report our measurable allocations).
    let resident = 8192 + buf_cap + samples_bytes;
    (cpu_frac, resident)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::resources::NodeResources;

    fn node_with_activity() -> NodeResources {
        let mut r = NodeResources::new(0, 16.0, 100e6, 125e6);
        // CPU: 8 cores busy on [2, 6).
        r.cpu.add_user(2.0, 1, 1.0, 8.0);
        r.cpu.remove_user(6.0, 1);
        // Disk: saturated on [0, 3).
        r.disk.add_user(0.0, 2, 1.0, 200e6);
        r.disk.remove_user(3.0, 2);
        // Net: 10 MB/s on [4, 8).
        r.net.add_user(4.0, 3, 1.0, 10e6);
        r.net.remove_user(8.0, 3);
        r
    }

    #[test]
    fn sample_node_no_jitter_is_exact() {
        let res = node_with_activity();
        let cfg = SamplerConfig { period: 1.0, jitter: 0.0 };
        let mut rng = Pcg64::seeded(1);
        let s = sample_node(&res, &cfg, 10.0, &mut rng);
        assert_eq!(s.len(), 10);
        assert!((s.cpu[3] - 0.5).abs() < 1e-9, "8/16 cores busy");
        assert!((s.cpu[0] - 0.0).abs() < 1e-9);
        assert!((s.disk[1] - 1.0).abs() < 1e-9, "disk saturated");
        assert!((s.disk[5] - 0.0).abs() < 1e-9);
        assert!((s.net_bytes[5] - 10e6).abs() < 1.0, "10 MB in a 1 s bucket");
        assert!((s.net_bytes[1] - 0.0).abs() < 1e-9);
    }

    #[test]
    fn jitter_stays_bounded_and_nonnegative() {
        let res = node_with_activity();
        let cfg = SamplerConfig { period: 1.0, jitter: 0.05 };
        let mut rng = Pcg64::seeded(2);
        let s = sample_node(&res, &cfg, 10.0, &mut rng);
        for &v in s.cpu.iter().chain(&s.disk) {
            assert!((0.0..=1.0).contains(&v));
        }
        for &v in &s.net_bytes {
            assert!(v >= 0.0);
        }
        // Jitter actually perturbs busy samples.
        assert!((s.cpu[3] - 0.5).abs() > 1e-12);
    }

    #[test]
    fn horizon_controls_length() {
        let res = node_with_activity();
        let cfg = SamplerConfig { period: 0.5, jitter: 0.0 };
        let mut rng = Pcg64::seeded(3);
        let s = sample_node(&res, &cfg, 4.0, &mut rng);
        assert_eq!(s.len(), 8);
        assert_eq!(s.period, 0.5);
    }

    #[test]
    fn overhead_measurement_is_small() {
        // 10 ms period for 0.3 s → ~30 wakes; the sampler must be cheap.
        let (cpu_frac, resident) = measure_sampler_overhead(0.01, 0.3);
        assert!(cpu_frac >= 0.0);
        assert!(cpu_frac < 0.5, "sampler burned {cpu_frac} CPU");
        assert!(resident > 0 && resident < 10 * 1024 * 1024);
    }
}
