//! Anomaly generators (AG) — the paper's controlled resource-hog processes.
//!
//! The paper's AGs launch 8 hog processes on one slave node: CPU AG spins on
//! power operations, I/O AG writes 10^8 characters in a loop, network AG
//! exchanges 512-byte messages with a LAN server. Here an AG registers as a
//! *resource user* on the node's shared-resource model with the equivalent
//! demand, which raises the sampled utilization and slows co-located task
//! phases — the same causal path as the real hog processes.
//!
//! Each injection window is recorded as ground truth ([`InjectionRecord`])
//! for TP/FP scoring of the analyzers.

use super::resources::Res;
use crate::trace::{AnomalyKind, InjectionRecord};
use crate::util::rng::Pcg64;

/// Strength of each AG, in resource units, modelled on the paper's setup
/// (8 hog processes on a 16-core node / 1 Gbps LAN).
#[derive(Debug, Clone, Copy)]
pub struct AgIntensity {
    /// CPU AG: cores demanded (paper: 8 spinning processes).
    pub cpu_cores: f64,
    /// I/O AG: fraction of disk bandwidth demanded (8 sequential writers
    /// easily saturate one disk → 1.0).
    pub disk_frac: f64,
    /// Network AG: fraction of NIC bandwidth demanded.
    pub net_frac: f64,
    /// Fair-share weight of the AG's processes relative to one task (8
    /// processes → weight 8).
    pub weight: f64,
}

impl Default for AgIntensity {
    fn default() -> Self {
        // The paper launches 8 hog processes; real nice-0 CPU hogs on a
        // 16-core Xeon grab more than a fair-share unit each relative to
        // executor task threads, so the calibrated demand is 12 cores /
        // weight 12 (see DESIGN.md §Calibration).
        AgIntensity { cpu_cores: 12.0, disk_frac: 1.0, net_frac: 0.85, weight: 12.0 }
    }
}

impl AgIntensity {
    /// (resource, weight, desired-rate) demand of an AG on a node with the
    /// given capacities.
    pub fn demand(&self, kind: AnomalyKind, disk_bw: f64, net_bw: f64) -> (Res, f64, f64) {
        match kind {
            AnomalyKind::Cpu => (Res::Cpu, self.weight, self.cpu_cores),
            AnomalyKind::Io => (Res::Disk, self.weight, self.disk_frac * disk_bw),
            AnomalyKind::Network => (Res::Net, self.weight, self.net_frac * net_bw),
        }
    }
}

/// One planned injection: kind, node, window.
#[derive(Debug, Clone)]
pub struct Injection {
    pub kind: AnomalyKind,
    pub node: usize,
    pub t_start: f64,
    pub t_end: f64,
    pub intensity: AgIntensity,
}

impl Injection {
    pub fn record(&self) -> InjectionRecord {
        InjectionRecord {
            node: self.node,
            kind: self.kind,
            t_start: self.t_start,
            t_end: self.t_end,
        }
    }
}

/// An injection plan for a whole run.
#[derive(Debug, Clone, Default)]
pub struct InjectionPlan {
    pub injections: Vec<Injection>,
}

impl InjectionPlan {
    pub fn none() -> Self {
        Self::default()
    }

    /// The paper's single-AG experiment: start one AG kind *intermittently*
    /// on one slave node ("we start AG in one slave node intermittently to
    /// simulate resource utilization fluctuation"): windows of `on` seconds
    /// separated by `off` seconds, covering [0, horizon).
    pub fn intermittent(
        kind: AnomalyKind,
        node: usize,
        on: f64,
        off: f64,
        horizon: f64,
    ) -> Self {
        let mut injections = Vec::new();
        let mut t = off / 2.0;
        while t < horizon {
            injections.push(Injection {
                kind,
                node,
                t_start: t,
                t_end: (t + on).min(horizon),
                intensity: AgIntensity::default(),
            });
            t += on + off;
        }
        InjectionPlan { injections }
    }

    /// Mixed AGs: kinds rotate randomly across windows on one node.
    pub fn mixed(rng: &mut Pcg64, node: usize, on: f64, off: f64, horizon: f64) -> Self {
        let mut injections = Vec::new();
        let mut t = off / 2.0;
        while t < horizon {
            let kind = AnomalyKind::all()[rng.pick(3)];
            injections.push(Injection {
                kind,
                node,
                t_start: t,
                t_end: (t + on).min(horizon),
                intensity: AgIntensity::default(),
            });
            t += on + off;
        }
        InjectionPlan { injections }
    }

    /// Random AGs across many nodes for random windows — the paper's
    /// "multiple anomalies across nodes" experiment (Table IV).
    pub fn random_multi_node(
        rng: &mut Pcg64,
        nodes: &[usize],
        count: usize,
        window: (f64, f64),
        horizon: f64,
    ) -> Self {
        let mut injections: Vec<Injection> = Vec::new();
        for _ in 0..count {
            let node = nodes[rng.pick(nodes.len())];
            let dur = rng.range_f64(window.0, window.1);
            let t_start = rng.range_f64(0.0, (horizon - dur).max(0.0));
            injections.push(Injection {
                kind: AnomalyKind::all()[rng.pick(3)],
                node,
                t_start,
                t_end: t_start + dur,
                intensity: AgIntensity::default(),
            });
        }
        injections.sort_by(|a, b| a.t_start.total_cmp(&b.t_start));
        InjectionPlan { injections }
    }

    /// The paper's Table IV schedule verbatim: (slave-index, start/end, AG).
    /// Slave indices are 1-based in the paper; `slave_to_node` maps them to
    /// simulator node ids (the master is not a slave).
    pub fn table4<F: Fn(usize) -> usize>(slave_to_node: F) -> Self {
        let rows: [(usize, f64, f64, AnomalyKind); 13] = [
            (1, 0.0, 10.0, AnomalyKind::Cpu),
            (1, 100.0, 110.0, AnomalyKind::Io),
            (2, 30.0, 40.0, AnomalyKind::Cpu),
            (2, 63.0, 73.0, AnomalyKind::Cpu),
            (2, 83.0, 93.0, AnomalyKind::Cpu),
            (3, 99.0, 109.0, AnomalyKind::Io),
            (4, 27.0, 37.0, AnomalyKind::Network),
            (4, 87.0, 97.0, AnomalyKind::Io),
            (4, 112.0, 122.0, AnomalyKind::Network),
            (5, 33.0, 43.0, AnomalyKind::Io),
            (5, 53.0, 63.0, AnomalyKind::Cpu),
            (5, 69.0, 79.0, AnomalyKind::Io),
            (5, 100.0, 110.0, AnomalyKind::Cpu),
        ];
        InjectionPlan {
            injections: rows
                .iter()
                .map(|&(slave, t0, t1, kind)| Injection {
                    kind,
                    node: slave_to_node(slave),
                    t_start: t0,
                    t_end: t1,
                    intensity: AgIntensity::default(),
                })
                .collect(),
        }
    }

    pub fn records(&self) -> Vec<InjectionRecord> {
        self.injections.iter().map(|i| i.record()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intermittent_windows_cover_horizon() {
        let plan = InjectionPlan::intermittent(AnomalyKind::Cpu, 2, 10.0, 15.0, 100.0);
        assert!(!plan.injections.is_empty());
        for w in plan.injections.windows(2) {
            assert!(w[0].t_end <= w[1].t_start, "windows must not overlap");
        }
        for i in &plan.injections {
            assert_eq!(i.node, 2);
            assert_eq!(i.kind, AnomalyKind::Cpu);
            assert!(i.t_end <= 100.0);
            assert!(i.t_end > i.t_start);
        }
    }

    #[test]
    fn mixed_uses_multiple_kinds() {
        let mut rng = Pcg64::seeded(1);
        let plan = InjectionPlan::mixed(&mut rng, 0, 5.0, 5.0, 300.0);
        let kinds: std::collections::HashSet<_> =
            plan.injections.iter().map(|i| i.kind).collect();
        assert!(kinds.len() >= 2, "mixed plan should rotate kinds");
    }

    #[test]
    fn random_multi_node_within_bounds() {
        let mut rng = Pcg64::seeded(2);
        let nodes = [1, 2, 3, 4, 5];
        let plan = InjectionPlan::random_multi_node(&mut rng, &nodes, 13, (8.0, 12.0), 120.0);
        assert_eq!(plan.injections.len(), 13);
        for i in &plan.injections {
            assert!(nodes.contains(&i.node));
            assert!(i.t_start >= 0.0 && i.t_end <= 121.0);
            let d = i.t_end - i.t_start;
            assert!((8.0..=12.0).contains(&d));
        }
        // Sorted by start time.
        for w in plan.injections.windows(2) {
            assert!(w[0].t_start <= w[1].t_start);
        }
    }

    #[test]
    fn table4_matches_paper_rows() {
        let plan = InjectionPlan::table4(|slave| slave); // identity mapping
        assert_eq!(plan.injections.len(), 13);
        let slave5: Vec<_> = plan.injections.iter().filter(|i| i.node == 5).collect();
        assert_eq!(slave5.len(), 4);
        assert_eq!(
            plan.injections.iter().filter(|i| i.kind == AnomalyKind::Cpu).count(),
            6
        );
        assert_eq!(
            plan.injections.iter().filter(|i| i.kind == AnomalyKind::Io).count(),
            5
        );
        assert_eq!(
            plan.injections.iter().filter(|i| i.kind == AnomalyKind::Network).count(),
            2
        );
    }

    #[test]
    fn demand_maps_kind_to_resource() {
        let ag = AgIntensity::default();
        let (r, w, d) = ag.demand(AnomalyKind::Cpu, 100e6, 125e6);
        assert_eq!(r, Res::Cpu);
        assert_eq!(w, 12.0);
        assert_eq!(d, 12.0);
        let (r, _, d) = ag.demand(AnomalyKind::Io, 100e6, 125e6);
        assert_eq!(r, Res::Disk);
        assert!((d - 100e6).abs() < 1.0);
        let (r, _, d) = ag.demand(AnomalyKind::Network, 100e6, 125e6);
        assert_eq!(r, Res::Net);
        assert!(d < 125e6);
    }

    #[test]
    fn records_match_plan() {
        let plan = InjectionPlan::intermittent(AnomalyKind::Io, 1, 5.0, 5.0, 30.0);
        let recs = plan.records();
        assert_eq!(recs.len(), plan.injections.len());
        assert!(recs.iter().all(|r| r.kind == AnomalyKind::Io && r.node == 1));
    }
}
