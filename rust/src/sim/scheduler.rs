//! Locality-aware task scheduler — Spark's *delay scheduling*.
//!
//! Each node exposes one slot per core. HDFS-input tasks prefer the node
//! holding their block: a free slot first serves tasks that are local to it
//! (PROCESS_LOCAL if the executor matches, NODE_LOCAL otherwise). A pending
//! task that has waited longer than `locality_wait` (Spark's
//! `spark.locality.wait`, 3 s by default) degrades to RACK_LOCAL / ANY and
//! accepts any slot. Shuffle-input tasks are NOPREF and schedule anywhere
//! immediately — reducers read from all map outputs, so placement is moot.
//!
//! This reproduces the locality feature of Eq. 4 / Table I: stragglers that
//! degrade to remote reads show `F_locality = 2` while their peers read
//! locally, which is exactly the signal Eq. 7 votes on.

use super::task::{InputKind, TaskSpec};
use crate::trace::Locality;

/// A task waiting for a slot.
#[derive(Debug, Clone)]
struct Pending {
    spec: TaskSpec,
    enqueued_at: f64,
}

/// A dispatch decision.
#[derive(Debug, Clone)]
pub struct Assignment {
    pub spec: TaskSpec,
    pub node: usize,
    pub executor: usize,
    pub slot: usize,
    pub locality: Locality,
}

/// Cluster topology the scheduler needs.
#[derive(Debug, Clone)]
pub struct Topology {
    pub nodes: usize,
    pub slots_per_node: usize,
    pub executors_per_node: usize,
    /// Node → rack id.
    pub racks: Vec<usize>,
}

impl Topology {
    /// Default: 4 nodes per rack.
    pub fn new(nodes: usize, slots_per_node: usize, executors_per_node: usize) -> Self {
        Topology {
            nodes,
            slots_per_node,
            executors_per_node,
            racks: (0..nodes).map(|n| n / 4).collect(),
        }
    }

    fn executor_of_slot(&self, slot: usize) -> usize {
        if self.slots_per_node == 0 {
            return 0;
        }
        slot * self.executors_per_node / self.slots_per_node
    }
}

/// The delay scheduler.
pub struct Scheduler {
    topo: Topology,
    locality_wait: f64,
    pending: Vec<Pending>,
    /// `slots[node][slot]` = running task id or None.
    slots: Vec<Vec<Option<u64>>>,
}

impl Scheduler {
    pub fn new(topo: Topology, locality_wait: f64) -> Self {
        let slots = (0..topo.nodes).map(|_| vec![None; topo.slots_per_node]).collect();
        Scheduler { topo, locality_wait, pending: Vec::new(), slots }
    }

    /// Queue a stage's tasks.
    pub fn submit(&mut self, tasks: Vec<TaskSpec>, now: f64) {
        for spec in tasks {
            self.pending.push(Pending { spec, enqueued_at: now });
        }
    }

    pub fn pending_count(&self) -> usize {
        self.pending.len()
    }

    pub fn running_count(&self) -> usize {
        self.slots.iter().flatten().filter(|s| s.is_some()).count()
    }

    /// Free the slot a task occupied.
    pub fn release(&mut self, node: usize, slot: usize) {
        debug_assert!(self.slots[node][slot].is_some());
        self.slots[node][slot] = None;
    }

    /// Earliest future time a pending task's locality wait expires (the
    /// engine schedules a wake-up then); None if no HDFS task is waiting.
    pub fn next_locality_timeout(&self, now: f64) -> Option<f64> {
        self.pending
            .iter()
            .filter(|p| p.spec.input_kind == InputKind::Hdfs)
            .map(|p| p.enqueued_at + self.locality_wait)
            .filter(|&t| t > now)
            .fold(None, |acc: Option<f64>, t| Some(acc.map_or(t, |a| a.min(t))))
    }

    /// Fill free slots according to delay scheduling; returns dispatches.
    pub fn try_assign(&mut self, now: f64) -> Vec<Assignment> {
        let mut out = Vec::new();
        // Iterate free slots in (node, slot) order for determinism.
        for node in 0..self.topo.nodes {
            for slot in 0..self.topo.slots_per_node {
                if self.slots[node][slot].is_some() {
                    continue;
                }
                if let Some((idx, locality)) = self.pick_for(node, slot, now) {
                    let p = self.pending.remove(idx);
                    self.slots[node][slot] = Some(p.spec.task_id);
                    out.push(Assignment {
                        executor: self.topo.executor_of_slot(slot),
                        spec: p.spec,
                        node,
                        slot,
                        locality,
                    });
                }
            }
        }
        out
    }

    /// Choose a pending task for a free slot on `node`, returning its index
    /// in the pending list plus the locality level it would run at.
    fn pick_for(&self, node: usize, slot: usize, now: f64) -> Option<(usize, Locality)> {
        let executor = self.topo.executor_of_slot(slot);
        // Tier 0: NOPREF (shuffle) tasks run anywhere, first-come.
        // Tier 1: node-local HDFS tasks (process-local if executor matches).
        // Tier 2: HDFS tasks whose locality wait expired → rack / any.
        let mut nopref: Option<usize> = None;
        let mut process_local: Option<usize> = None;
        let mut node_local: Option<usize> = None;
        let mut expired: Option<usize> = None;
        for (i, p) in self.pending.iter().enumerate() {
            match p.spec.input_kind {
                InputKind::Shuffle => {
                    if nopref.is_none() {
                        nopref = Some(i);
                    }
                }
                InputKind::Hdfs => {
                    if p.spec.preferred_node == node {
                        if p.spec.preferred_executor == executor {
                            if process_local.is_none() {
                                process_local = Some(i);
                            }
                        } else if node_local.is_none() {
                            node_local = Some(i);
                        }
                    } else if now - p.enqueued_at >= self.locality_wait && expired.is_none() {
                        expired = Some(i);
                    }
                }
            }
        }
        if let Some(i) = process_local {
            return Some((i, Locality::ProcessLocal));
        }
        if let Some(i) = node_local {
            return Some((i, Locality::NodeLocal));
        }
        if let Some(i) = nopref {
            return Some((i, Locality::NoPref));
        }
        if let Some(i) = expired {
            let pref = self.pending[i].spec.preferred_node;
            let loc = if self.topo.racks.get(pref) == self.topo.racks.get(node) {
                Locality::RackLocal
            } else {
                Locality::Any
            };
            return Some((i, loc));
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::task::StageSpec;
    use crate::util::rng::Pcg64;

    fn specs(n: usize, input: InputKind, nodes: usize) -> Vec<TaskSpec> {
        let mut rng = Pcg64::seeded(1);
        let mut s = StageSpec::base("s", n);
        s.input_kind = input;
        s.materialize(&mut rng, 0, 0, nodes, 2)
    }

    fn sched(nodes: usize, slots: usize) -> Scheduler {
        Scheduler::new(Topology::new(nodes, slots, 2), 3.0)
    }

    #[test]
    fn local_tasks_get_node_or_process_locality() {
        let mut s = sched(4, 2);
        s.submit(specs(8, InputKind::Hdfs, 4), 0.0);
        let assigns = s.try_assign(0.0);
        assert_eq!(assigns.len(), 8); // 4 nodes × 2 slots
        for a in &assigns {
            assert_eq!(a.spec.preferred_node, a.node, "before timeout only local dispatch");
            assert!(matches!(a.locality, Locality::ProcessLocal | Locality::NodeLocal));
        }
    }

    #[test]
    fn nonlocal_waits_until_timeout_then_degrades() {
        let mut s = sched(2, 1);
        // All tasks prefer node 0; node 1's slot must wait for the timeout.
        let mut tasks = specs(2, InputKind::Hdfs, 2);
        for t in &mut tasks {
            t.preferred_node = 0;
        }
        s.submit(tasks, 0.0);
        let assigns = s.try_assign(0.0);
        // Only node 0 slot fills.
        assert_eq!(assigns.len(), 1);
        assert_eq!(assigns[0].node, 0);
        assert_eq!(s.pending_count(), 1);
        // Before timeout: still waiting.
        assert_eq!(s.try_assign(2.9).len(), 0);
        // After timeout: dispatched remotely with degraded locality.
        let late = s.try_assign(3.1);
        assert_eq!(late.len(), 1);
        assert_eq!(late[0].node, 1);
        assert!(matches!(late[0].locality, Locality::RackLocal | Locality::Any));
    }

    #[test]
    fn rack_vs_any_locality() {
        // 8 nodes → racks {0..3}=0, {4..7}=1.
        let mut s = Scheduler::new(Topology::new(8, 1, 1), 0.0); // no wait
        let mut tasks = specs(2, InputKind::Hdfs, 8);
        tasks[0].preferred_node = 0;
        tasks[1].preferred_node = 0;
        s.submit(tasks, 0.0);
        let assigns = s.try_assign(10.0);
        let on_rack = assigns.iter().find(|a| a.node == 1).unwrap();
        assert_eq!(on_rack.locality, Locality::RackLocal);
        let off_rack = assigns.iter().find(|a| a.node >= 4);
        if let Some(a) = off_rack {
            assert_eq!(a.locality, Locality::Any);
        }
    }

    #[test]
    fn shuffle_tasks_are_nopref_and_immediate() {
        let mut s = sched(2, 2);
        s.submit(specs(4, InputKind::Shuffle, 2), 0.0);
        let assigns = s.try_assign(0.0);
        assert_eq!(assigns.len(), 4);
        assert!(assigns.iter().all(|a| a.locality == Locality::NoPref));
    }

    #[test]
    fn release_frees_slot_for_next_task() {
        let mut s = sched(1, 1);
        s.submit(specs(2, InputKind::Shuffle, 1), 0.0);
        let a1 = s.try_assign(0.0);
        assert_eq!(a1.len(), 1);
        assert_eq!(s.try_assign(1.0).len(), 0); // slot busy
        s.release(a1[0].node, a1[0].slot);
        assert_eq!(s.try_assign(2.0).len(), 1);
        assert_eq!(s.pending_count(), 0);
        assert_eq!(s.running_count(), 1);
    }

    #[test]
    fn next_locality_timeout_tracks_earliest_hdfs_task() {
        let mut s = sched(2, 1);
        let mut tasks = specs(2, InputKind::Hdfs, 2);
        for t in &mut tasks {
            t.preferred_node = 0;
        }
        s.submit(tasks, 1.0);
        // One gets the node-0 slot; the other waits.
        s.try_assign(1.0);
        assert_eq!(s.next_locality_timeout(1.0), Some(4.0));
        assert_eq!(s.next_locality_timeout(5.0), None);
        // NOPREF tasks don't produce timeouts.
        let mut s2 = sched(1, 1);
        s2.submit(specs(3, InputKind::Shuffle, 1), 0.0);
        s2.try_assign(0.0);
        assert_eq!(s2.next_locality_timeout(0.0), None);
    }

    #[test]
    fn all_tasks_eventually_dispatched() {
        let mut s = sched(3, 2);
        s.submit(specs(40, InputKind::Hdfs, 3), 0.0);
        let mut done = 0;
        let mut t = 0.0;
        let mut running: Vec<(usize, usize)> = Vec::new();
        while done < 40 {
            for a in s.try_assign(t) {
                running.push((a.node, a.slot));
            }
            // Finish everything running, advance past locality timeout.
            for (n, sl) in running.drain(..) {
                s.release(n, sl);
                done += 1;
            }
            t += 4.0;
            assert!(t < 400.0, "scheduler wedged");
        }
        assert_eq!(s.pending_count(), 0);
    }
}
