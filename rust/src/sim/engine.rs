//! The discrete-event cluster engine: a fluid-flow simulation of tasks
//! executing phases on shared node resources, with delay scheduling,
//! background OS noise, and anomaly-generator injections.
//!
//! Rates are piecewise-constant: whenever any resource's user set changes
//! (phase start/end, AG start/end, noise re-sample), affected tasks'
//! remaining work is advanced at the old rate and their completion events
//! are re-scheduled at the new rate (versioned events make stale
//! completions no-ops). This is the standard processor-sharing DES
//! construction, so contention physics — a CPU hog dilating co-located
//! compute phases — emerges from the model rather than being scripted.

use std::collections::HashMap;

use super::anomaly::InjectionPlan;
use super::event::EventQueue;
use super::resources::{NodeResources, Res};
use super::sampler::{sample_node, SamplerConfig};
use super::scheduler::{Assignment, Scheduler, Topology};
use super::task::{InputKind, StageSpec, TaskSpec};
use crate::trace::{ClusterInfo, JobTrace, Locality, StageRecord, TaskRecord};
use crate::util::rng::Pcg64;

/// Background OS noise configuration: small random demands re-sampled
/// periodically on every node, so baseline utilization fluctuates like the
/// paper's real cluster instead of sitting at exactly zero.
#[derive(Debug, Clone, Copy)]
pub struct NoiseConfig {
    /// Max cores of CPU noise.
    pub cpu_max_cores: f64,
    /// Max fraction of disk bandwidth.
    pub disk_max_frac: f64,
    /// Max fraction of network bandwidth.
    pub net_max_frac: f64,
    /// Re-sample period (s).
    pub tick: f64,
}

impl Default for NoiseConfig {
    fn default() -> Self {
        NoiseConfig { cpu_max_cores: 1.2, disk_max_frac: 0.06, net_max_frac: 0.03, tick: 2.0 }
    }
}

/// Full simulator configuration, defaulting to the paper's testbed: five
/// slave nodes with 16 cores, 1 Gbps network, locality wait 3 s.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub nodes: usize,
    pub cores_per_node: usize,
    pub executors_per_node: usize,
    /// Concurrent task slots per node (Spark: executor cores).
    pub slots_per_node: usize,
    /// Disk bandwidth per node (bytes/s).
    pub disk_bw: f64,
    /// NIC bandwidth per node (bytes/s); 1 Gbps = 125 MB/s.
    pub net_bw: f64,
    /// Delay-scheduling locality wait (s).
    pub locality_wait: f64,
    /// Max disk read/write rate of a single task (bytes/s).
    pub task_disk_rate: f64,
    /// Max network fetch rate of a single task (bytes/s).
    pub task_net_rate: f64,
    pub noise: NoiseConfig,
    pub sampler: SamplerConfig,
    /// Per-node CPU speed heterogeneity: each node's compute work is
    /// dilated by 1/speed with speed ~ N(1, spread) (the paper's testbed
    /// nodes are nominally identical but real clusters drift — Section II
    /// lists heterogeneous hardware among straggler causes).
    pub cpu_speed_spread: f64,
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            nodes: 5,
            cores_per_node: 16,
            executors_per_node: 2,
            slots_per_node: 12,
            disk_bw: 300e6,
            net_bw: 125e6,
            locality_wait: 3.0,
            task_disk_rate: 40e6,
            task_net_rate: 60e6,
            noise: NoiseConfig::default(),
            sampler: SamplerConfig::default(),
            cpu_speed_spread: 0.06,
            seed: 42,
        }
    }
}

/// One phase of a running task: which resource, how much work (core-seconds
/// for CPU, bytes otherwise), and the task's desired rate on it.
#[derive(Debug, Clone, Copy)]
struct PhasePlan {
    res: Res,
    work: f64,
    desired: f64,
}

#[derive(Debug)]
struct Running {
    spec: TaskSpec,
    node: usize,
    executor: usize,
    slot: usize,
    locality: Locality,
    start: f64,
    phases: Vec<PhasePlan>,
    phase_idx: usize,
    work_remaining: f64,
    last_update: f64,
    phase_start: f64,
    /// Elapsed wall time of each completed phase.
    phase_elapsed: Vec<f64>,
    version: u64,
}

impl Running {
    fn current(&self) -> Option<&PhasePlan> {
        self.phases.get(self.phase_idx)
    }

    fn user_id(&self) -> u64 {
        TASK_USER_BASE + self.spec.task_id
    }
}

const TASK_USER_BASE: u64 = 2_000_000;
const INJ_USER_BASE: u64 = 1_000_000;
const NOISE_USER_BASE: u64 = 1_000;

#[derive(Debug, Clone, Copy)]
enum Ev {
    PhaseDone { task: u64, version: u64 },
    InjStart(usize),
    InjEnd(usize),
    NoiseTick,
    SchedWake,
}

/// The engine. Construct with a config, then [`Engine::run`] a workload.
pub struct Engine {
    cfg: SimConfig,
    rng: Pcg64,
    /// Per-node compute speed factors (sampled once per engine).
    node_speed: Vec<f64>,
}

impl Engine {
    pub fn new(cfg: SimConfig) -> Self {
        let mut rng = Pcg64::seeded(cfg.seed);
        let node_speed = (0..cfg.nodes)
            .map(|_| rng.normal_clamped(1.0, cfg.cpu_speed_spread, 0.75, 1.25))
            .collect();
        Engine { cfg, rng, node_speed }
    }

    /// Simulate `stages` sequentially under `plan`, producing a full trace.
    /// `job_name`/`workload` label the trace.
    pub fn run(
        &mut self,
        job_name: &str,
        workload: &str,
        stages: &[StageSpec],
        plan: &InjectionPlan,
    ) -> JobTrace {
        let cfg = self.cfg.clone();
        let mut queue: EventQueue<Ev> = EventQueue::new();
        let mut nodes: Vec<NodeResources> = (0..cfg.nodes)
            .map(|n| NodeResources::new(n, cfg.cores_per_node as f64, cfg.disk_bw, cfg.net_bw))
            .collect();
        let topo = Topology::new(cfg.nodes, cfg.slots_per_node, cfg.executors_per_node);
        let mut scheduler = Scheduler::new(topo, cfg.locality_wait);
        let mut running: HashMap<u64, Running> = HashMap::new();
        let mut records: Vec<TaskRecord> = Vec::new();
        let mut stage_records: Vec<StageRecord> = Vec::new();

        // Register noise users (zero demand initially) and the first tick.
        for n in 0..cfg.nodes {
            for (ri, r) in [Res::Cpu, Res::Disk, Res::Net].into_iter().enumerate() {
                nodes[n].get_mut(r).add_user(0.0, NOISE_USER_BASE + (n * 3 + ri) as u64, 0.5, 0.0);
            }
        }
        queue.schedule(0.0, Ev::NoiseTick);

        // Schedule injections.
        for (i, inj) in plan.injections.iter().enumerate() {
            queue.schedule(inj.t_start, Ev::InjStart(i));
            queue.schedule(inj.t_end, Ev::InjEnd(i));
        }

        // Materialize and submit stage 0.
        let mut next_task_id: u64 = 0;
        let mut stage_cursor = 0usize;
        let mut remaining_in_stage: usize;
        {
            let tasks = stages[0].materialize(
                &mut self.rng,
                0,
                next_task_id,
                cfg.nodes,
                cfg.executors_per_node,
            );
            next_task_id += tasks.len() as u64;
            remaining_in_stage = tasks.len();
            stage_records.push(StageRecord {
                stage_id: 0,
                name: stages[0].name.clone(),
                tasks: tasks.iter().map(|t| t.task_id).collect(),
            });
            scheduler.submit(tasks, 0.0);
        }
        queue.schedule(0.0, Ev::SchedWake);

        let mut guard = 0u64;
        let max_events = 200_000_000u64;
        while let Some((now, ev)) = queue.pop() {
            guard += 1;
            assert!(guard < max_events, "event-budget exceeded: simulator wedged");
            match ev {
                Ev::NoiseTick => {
                    for n in 0..cfg.nodes {
                        let cpu_d = self.rng.range_f64(0.0, cfg.noise.cpu_max_cores);
                        let disk_d = self.rng.range_f64(0.0, cfg.noise.disk_max_frac * cfg.disk_bw);
                        let net_d = self.rng.range_f64(0.0, cfg.noise.net_max_frac * cfg.net_bw);
                        for (ri, (r, d)) in
                            [(Res::Cpu, cpu_d), (Res::Disk, disk_d), (Res::Net, net_d)]
                                .into_iter()
                                .enumerate()
                        {
                            let id = NOISE_USER_BASE + (n * 3 + ri) as u64;
                            with_resource_change(
                                &mut nodes,
                                &mut running,
                                &mut queue,
                                n,
                                r,
                                now,
                                |res| res.set_desired(now, id, d),
                            );
                        }
                    }
                    // Keep ticking while anything remains to simulate.
                    if remaining_in_stage > 0
                        || stage_cursor + 1 < stages.len()
                        || !running.is_empty()
                    {
                        let jitter = self.rng.range_f64(0.8, 1.2);
                        queue.schedule_in(cfg.noise.tick * jitter, Ev::NoiseTick);
                    }
                }
                Ev::InjStart(i) => {
                    let inj = &plan.injections[i];
                    if inj.node >= cfg.nodes {
                        continue;
                    }
                    let (r, w, d) = inj.intensity.demand(inj.kind, cfg.disk_bw, cfg.net_bw);
                    let id = INJ_USER_BASE + i as u64;
                    with_resource_change(&mut nodes, &mut running, &mut queue, inj.node, r, now, |res| {
                        res.add_user(now, id, w, d)
                    });
                }
                Ev::InjEnd(i) => {
                    let inj = &plan.injections[i];
                    if inj.node >= cfg.nodes {
                        continue;
                    }
                    let (r, _, _) = inj.intensity.demand(inj.kind, cfg.disk_bw, cfg.net_bw);
                    let id = INJ_USER_BASE + i as u64;
                    with_resource_change(&mut nodes, &mut running, &mut queue, inj.node, r, now, |res| {
                        res.remove_user(now, id)
                    });
                }
                Ev::SchedWake => {
                    self.dispatch(&mut scheduler, &mut nodes, &mut running, &mut queue, now);
                }
                Ev::PhaseDone { task, version } => {
                    let stale = match running.get(&task) {
                        Some(rt) => rt.version != version,
                        None => true,
                    };
                    if stale {
                        continue;
                    }
                    // Phase complete: advance peers, remove our user.
                    let (node, res) = {
                        let rt = running.get(&task).unwrap();
                        let p = rt.current().unwrap();
                        (rt.node, p.res)
                    };
                    let uid = running.get(&task).unwrap().user_id();
                    with_resource_change(&mut nodes, &mut running, &mut queue, node, res, now, |r| {
                        r.remove_user(now, uid)
                    });
                    let finished = {
                        let rt = running.get_mut(&task).unwrap();
                        rt.phase_elapsed.push(now - rt.phase_start);
                        rt.phase_idx += 1;
                        rt.current().is_none()
                    };
                    if finished {
                        let rt = running.remove(&task).unwrap();
                        scheduler.release(rt.node, rt.slot);
                        records.push(finalize(&rt, now));
                        remaining_in_stage -= 1;
                        if remaining_in_stage == 0 && scheduler.pending_count() == 0 {
                            stage_cursor += 1;
                            if stage_cursor < stages.len() {
                                let spec = &stages[stage_cursor];
                                let tasks = spec.materialize(
                                    &mut self.rng,
                                    stage_cursor as u64,
                                    next_task_id,
                                    cfg.nodes,
                                    cfg.executors_per_node,
                                );
                                next_task_id += tasks.len() as u64;
                                remaining_in_stage = tasks.len();
                                stage_records.push(StageRecord {
                                    stage_id: stage_cursor as u64,
                                    name: spec.name.clone(),
                                    tasks: tasks.iter().map(|t| t.task_id).collect(),
                                });
                                scheduler.submit(tasks, now);
                            }
                        }
                        self.dispatch(&mut scheduler, &mut nodes, &mut running, &mut queue, now);
                    } else {
                        // Start the next phase.
                        start_phase(&mut nodes, &mut running, &mut queue, task, now);
                    }
                }
            }
            // Job complete?
            if running.is_empty()
                && scheduler.pending_count() == 0
                && stage_cursor + 1 >= stages.len()
                && remaining_in_stage == 0
            {
                break;
            }
        }

        let makespan = records.iter().map(|t| t.finish).fold(0.0, f64::max);
        // Sample past the makespan so edge detection has a tail window.
        let horizon = makespan + 10.0;
        let node_series = nodes
            .iter()
            .map(|n| sample_node(n, &cfg.sampler, horizon, &mut self.rng))
            .collect();
        records.sort_by_key(|t| t.task_id);

        JobTrace {
            job_name: job_name.to_string(),
            workload: workload.to_string(),
            cluster: ClusterInfo {
                nodes: cfg.nodes,
                cores_per_node: cfg.cores_per_node,
                executors_per_node: cfg.executors_per_node,
            },
            stages: stage_records,
            tasks: records,
            node_series,
            injections: plan.records(),
        }
    }

    /// Ask the scheduler for assignments and start the dispatched tasks.
    fn dispatch(
        &mut self,
        scheduler: &mut Scheduler,
        nodes: &mut [NodeResources],
        running: &mut HashMap<u64, Running>,
        queue: &mut EventQueue<Ev>,
        now: f64,
    ) {
        let assignments = scheduler.try_assign(now);
        for a in assignments {
            let rt = self.admit(a, now);
            let id = rt.spec.task_id;
            running.insert(id, rt);
            start_phase(nodes, running, queue, id, now);
        }
        if let Some(t) = scheduler.next_locality_timeout(now) {
            queue.schedule(t, Ev::SchedWake);
        }
    }

    /// Build the runtime phase plan for an assignment.
    fn admit(&mut self, a: Assignment, now: f64) -> Running {
        let cfg = &self.cfg;
        let spec = a.spec.clone();
        let mut phases = Vec::with_capacity(5);
        phases.push(PhasePlan { res: Res::Cpu, work: spec.deserialize_work, desired: 1.0 });
        // Input phase: local HDFS reads hit the disk; degraded-locality HDFS
        // reads and shuffle fetches cross the network. Shuffle reads pull
        // (n-1)/n of their bytes from remote nodes; the local fraction is
        // folded in (single-resource phases keep the fluid model simple).
        let remote = matches!(a.locality, Locality::RackLocal | Locality::Any)
            || spec.input_kind == InputKind::Shuffle;
        let input_bytes = match spec.input_kind {
            InputKind::Shuffle => {
                spec.input_bytes * (cfg.nodes.max(2) - 1) as f64 / cfg.nodes.max(2) as f64
            }
            InputKind::Hdfs => spec.input_bytes,
        };
        if input_bytes > 0.0 {
            if remote {
                phases.push(PhasePlan { res: Res::Net, work: input_bytes, desired: cfg.task_net_rate });
            } else {
                phases.push(PhasePlan { res: Res::Disk, work: input_bytes, desired: cfg.task_disk_rate });
            }
        }
        // Node heterogeneity: slower CPUs stretch compute work.
        let speed = self.node_speed.get(a.node).copied().unwrap_or(1.0);
        let compute = (spec.compute_work + spec.gc_work) / speed;
        if compute > 0.0 {
            phases.push(PhasePlan { res: Res::Cpu, work: compute, desired: 1.0 });
        }
        if spec.output_bytes() > 0.0 {
            phases.push(PhasePlan {
                res: Res::Disk,
                work: spec.output_bytes(),
                desired: cfg.task_disk_rate,
            });
        }
        phases.push(PhasePlan { res: Res::Cpu, work: spec.serialize_work, desired: 1.0 });
        Running {
            spec,
            node: a.node,
            executor: a.executor,
            slot: a.slot,
            locality: a.locality,
            start: now,
            phases,
            phase_idx: 0,
            work_remaining: 0.0,
            last_update: now,
            phase_start: now,
            phase_elapsed: Vec::with_capacity(5),
            version: 0,
        }
    }
}

/// Register the current phase's user on its resource and schedule its
/// completion. Must be called exactly once per phase start.
fn start_phase(
    nodes: &mut [NodeResources],
    running: &mut HashMap<u64, Running>,
    queue: &mut EventQueue<Ev>,
    task: u64,
    now: f64,
) {
    let (node, res, work, desired, uid) = {
        let rt = running.get_mut(&task).unwrap();
        let p = *rt.current().expect("start_phase past end");
        rt.work_remaining = p.work;
        rt.last_update = now;
        rt.phase_start = now;
        (rt.node, p.res, p.work, p.desired, rt.user_id())
    };
    let _ = work;
    with_resource_change(nodes, running, queue, node, res, now, |r| {
        r.add_user(now, uid, 1.0, desired)
    });
}

/// The core fluid-model bookkeeping: advance all tasks currently in a phase
/// on `(node, res)` at their *old* rates, apply the mutation (which
/// rebalances), then re-schedule their completions at the *new* rates.
fn with_resource_change<F: FnOnce(&mut super::resources::Resource)>(
    nodes: &mut [NodeResources],
    running: &mut HashMap<u64, Running>,
    queue: &mut EventQueue<Ev>,
    node: usize,
    res: Res,
    now: f64,
    mutate: F,
) {
    // Collect affected tasks (current phase on this node+resource).
    let affected: Vec<u64> = running
        .values()
        .filter(|rt| rt.node == node && rt.current().map(|p| p.res) == Some(res))
        .map(|rt| rt.spec.task_id)
        .collect();
    // Advance at old rates.
    {
        let r = nodes[node].get(res);
        for id in &affected {
            let rt = running.get_mut(id).unwrap();
            let rate = r.rate_of(rt.user_id());
            rt.work_remaining = (rt.work_remaining - (now - rt.last_update) * rate).max(0.0);
            rt.last_update = now;
        }
    }
    mutate(nodes[node].get_mut(res));
    // Re-schedule at new rates (including any task the mutation added).
    let affected_after: Vec<u64> = running
        .values()
        .filter(|rt| rt.node == node && rt.current().map(|p| p.res) == Some(res))
        .map(|rt| rt.spec.task_id)
        .collect();
    let r = nodes[node].get(res);
    for id in affected_after {
        let rt = running.get_mut(&id).unwrap();
        let rate = r.rate_of(rt.user_id());
        rt.version += 1;
        if rate > 1e-12 {
            let eta = now + rt.work_remaining / rate;
            queue.schedule(eta, Ev::PhaseDone { task: id, version: rt.version });
        }
        // rate == 0: starved; a later rebalance will reschedule.
    }
}

/// Build the final task record from runtime state.
fn finalize(rt: &Running, finish: f64) -> TaskRecord {
    // Map phase elapsed times back to the record's time fields. The phase
    // list is [deser, (input)?, (compute)?, (output)?, ser].
    let mut iter = rt.phases.iter().zip(&rt.phase_elapsed);
    let mut deser = 0.0;
    let mut ser = 0.0;
    let mut compute_elapsed = 0.0;
    let mut cpu_phases_seen = 0;
    let total_cpu_phases =
        rt.phases.iter().filter(|p| p.res == Res::Cpu).count();
    for (p, &el) in iter.by_ref() {
        match p.res {
            Res::Cpu => {
                cpu_phases_seen += 1;
                if cpu_phases_seen == 1 {
                    deser = el;
                } else if cpu_phases_seen == total_cpu_phases {
                    ser = el;
                } else {
                    compute_elapsed = el;
                }
            }
            _ => {}
        }
    }
    // GC wall time: the GC share of the (possibly dilated) compute phase.
    let gc_frac = if rt.spec.compute_work + rt.spec.gc_work > 0.0 {
        rt.spec.gc_work / (rt.spec.compute_work + rt.spec.gc_work)
    } else {
        0.0
    };
    let (bytes_read, shuffle_read) = match rt.spec.input_kind {
        InputKind::Hdfs => (rt.spec.input_bytes, 0.0),
        InputKind::Shuffle => (0.0, rt.spec.input_bytes),
    };
    TaskRecord {
        task_id: rt.spec.task_id,
        stage_id: rt.spec.stage_id,
        node: rt.node,
        executor: rt.executor,
        start: rt.start,
        finish,
        locality: rt.locality,
        bytes_read,
        shuffle_read_bytes: shuffle_read,
        shuffle_write_bytes: rt.spec.shuffle_write_bytes,
        memory_bytes_spilled: rt.spec.memory_bytes_spilled,
        disk_bytes_spilled: rt.spec.disk_bytes_spilled,
        jvm_gc_time: compute_elapsed * gc_frac,
        serialize_time: ser,
        deserialize_time: deser,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::AnomalyKind;

    fn small_stage(n: usize) -> StageSpec {
        let mut s = StageSpec::base("map", n);
        s.input_mean_bytes = 8e6;
        s.compute_per_byte = 5e-8;
        s.compute_base = 0.2;
        s
    }

    #[test]
    fn runs_to_completion_and_validates() {
        let mut eng = Engine::new(SimConfig { seed: 1, ..Default::default() });
        let trace = eng.run("job", "unit", &[small_stage(60)], &InjectionPlan::none());
        assert_eq!(trace.tasks.len(), 60);
        trace.validate().expect("trace invariants");
        assert!(trace.makespan() > 0.0);
        assert!(trace.node_series.iter().all(|s| !s.is_empty()));
    }

    #[test]
    fn deterministic_for_same_seed() {
        let run = |seed| {
            let mut eng = Engine::new(SimConfig { seed, ..Default::default() });
            eng.run("job", "unit", &[small_stage(40)], &InjectionPlan::none())
        };
        let a = run(7);
        let b = run(7);
        assert_eq!(a, b);
        let c = run(8);
        assert_ne!(a, c);
    }

    #[test]
    fn two_stage_job_sequences_stages() {
        let mut reduce = StageSpec::base("reduce", 20);
        reduce.input_kind = InputKind::Shuffle;
        reduce.input_mean_bytes = 4e6;
        let mut eng = Engine::new(SimConfig { seed: 2, ..Default::default() });
        let trace = eng.run("job", "unit", &[small_stage(40), reduce], &InjectionPlan::none());
        assert_eq!(trace.stages.len(), 2);
        assert_eq!(trace.tasks.len(), 60);
        let s0_max = trace
            .stage_tasks(0)
            .iter()
            .map(|t| t.finish)
            .fold(0.0, f64::max);
        let s1_min = trace
            .stage_tasks(1)
            .iter()
            .map(|t| t.start)
            .fold(f64::INFINITY, f64::min);
        assert!(s1_min >= s0_max - 1e-9, "stage 1 must start after stage 0 completes");
        // Shuffle-stage tasks populate shuffle_read_bytes, not bytes_read.
        for t in trace.stage_tasks(1) {
            assert_eq!(t.bytes_read, 0.0);
            assert!(t.shuffle_read_bytes > 0.0);
            assert_eq!(t.locality, Locality::NoPref);
        }
    }

    #[test]
    fn cpu_injection_slows_tasks_on_target_node() {
        // Long CPU-heavy stage; inject a CPU AG on node 0 the whole time.
        let mut stage = StageSpec::base("cpu", 100);
        stage.input_mean_bytes = 1e6;
        stage.compute_base = 2.0;
        stage.compute_per_byte = 0.0;
        let base_cfg = SimConfig { seed: 3, ..Default::default() };
        let mut eng = Engine::new(base_cfg.clone());
        let clean = eng.run("job", "unit", &[stage.clone()], &InjectionPlan::none());
        let mut eng2 = Engine::new(base_cfg);
        let plan = InjectionPlan {
            injections: vec![super::super::anomaly::Injection {
                kind: AnomalyKind::Cpu,
                node: 0,
                t_start: 0.0,
                t_end: 1e4,
                intensity: Default::default(),
            }],
        };
        let hot = eng2.run("job", "unit", &[stage], &plan);
        let mean_dur = |tr: &JobTrace, node: usize| {
            let ds: Vec<f64> =
                tr.tasks.iter().filter(|t| t.node == node).map(|t| t.duration()).collect();
            crate::util::stats::mean(&ds)
        };
        // Node 0 tasks slow down substantially vs the clean run...
        assert!(
            mean_dur(&hot, 0) > 1.2 * mean_dur(&clean, 0),
            "hot {} vs clean {}",
            mean_dur(&hot, 0),
            mean_dur(&clean, 0)
        );
        // ...and vs other nodes in the same run.
        assert!(mean_dur(&hot, 0) > 1.15 * mean_dur(&hot, 2));
        // CPU utilization on node 0 is elevated while the job runs (after
        // the job drains, only the AG's 8/16 cores remain busy).
        let busy_window = ((hot.makespan() * 0.6) as usize).max(3);
        let hot_cpu = crate::util::stats::mean(
            &hot.node_series[0].cpu[..busy_window.min(hot.node_series[0].cpu.len())],
        );
        assert!(hot_cpu > 0.75, "cpu util under AG = {hot_cpu}");
    }

    #[test]
    fn io_injection_slows_disk_phases() {
        let mut stage = StageSpec::base("io", 80);
        stage.input_mean_bytes = 60e6; // disk-heavy
        stage.compute_base = 0.1;
        stage.compute_per_byte = 0.0;
        let mk = |plan: &InjectionPlan| {
            let mut eng = Engine::new(SimConfig { seed: 4, ..Default::default() });
            eng.run("job", "unit", &[stage.clone()], plan)
        };
        let clean = mk(&InjectionPlan::none());
        let plan = InjectionPlan {
            injections: vec![super::super::anomaly::Injection {
                kind: AnomalyKind::Io,
                node: 1,
                t_start: 0.0,
                t_end: 1e4,
                intensity: Default::default(),
            }],
        };
        let hot = mk(&plan);
        let mean_dur = |tr: &JobTrace, node: usize| {
            let ds: Vec<f64> =
                tr.tasks.iter().filter(|t| t.node == node).map(|t| t.duration()).collect();
            crate::util::stats::mean(&ds)
        };
        assert!(mean_dur(&hot, 1) > 1.3 * mean_dur(&clean, 1));
        let disk_util = crate::util::stats::mean(
            &hot.node_series[1].disk[..20.min(hot.node_series[1].disk.len())],
        );
        assert!(disk_util > 0.9, "disk util under IO AG = {disk_util}");
    }

    #[test]
    fn records_have_sane_fields() {
        let mut eng = Engine::new(SimConfig { seed: 5, ..Default::default() });
        let trace = eng.run("job", "unit", &[small_stage(50)], &InjectionPlan::none());
        for t in &trace.tasks {
            assert!(t.duration() > 0.0);
            assert!(t.deserialize_time > 0.0);
            assert!(t.serialize_time > 0.0);
            assert!(t.jvm_gc_time >= 0.0);
            assert!(t.jvm_gc_time < t.duration());
            assert!(t.bytes_read > 0.0);
            assert_eq!(t.shuffle_read_bytes, 0.0);
            let span = t.deserialize_time + t.serialize_time + t.jvm_gc_time;
            assert!(span <= t.duration() + 1e-6);
        }
    }

    #[test]
    fn makespan_increases_under_contention() {
        // Fig. 7's premise: injected contention delays the job modestly.
        let stage = small_stage(120);
        let mk = |plan: &InjectionPlan| {
            let mut eng = Engine::new(SimConfig { seed: 6, ..Default::default() });
            eng.run("job", "unit", &[stage.clone()], plan).makespan()
        };
        let base = mk(&InjectionPlan::none());
        let inj = InjectionPlan::intermittent(AnomalyKind::Io, 2, 10.0, 10.0, 1e4);
        let hot = mk(&inj);
        assert!(hot >= base * 0.99, "injection should not speed the job up: {hot} vs {base}");
    }
}
