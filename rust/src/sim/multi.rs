//! Multi-job workload generation — the traffic source for the concurrent
//! [`crate::coordinator::service::AnalysisService`]: simulate N independent
//! jobs (round-robined over the HiBench suite, optionally with injected
//! anomalies) and merge their event logs into one interleaved, job-tagged
//! stream, exactly what a busy cluster's log collector would deliver.
//!
//! Also provides [`shuffle_preserving_job_order`], the adversarial remixer
//! the determinism tests use: cross-job arrival order is randomized while
//! each job's internal order — the only thing the service may rely on —
//! is preserved.

use std::collections::VecDeque;

use crate::sim::workloads::{self, Workload};
use crate::sim::{Engine, InjectionPlan, SimConfig};
use crate::trace::eventlog::{interleave_jobs, TaggedEvent};
use crate::trace::{AnomalyKind, JobTrace};
use crate::util::rng::Pcg64;

/// One job of a multi-job scenario.
#[derive(Debug, Clone)]
pub struct MultiJobSpec {
    pub job_id: u64,
    pub workload: Workload,
    pub seed: u64,
    /// Optional intermittent anomaly injected on node 1 while the job runs.
    pub inject: Option<AnomalyKind>,
}

/// `n_jobs` specs cycling through the HiBench suite at `scale`, with every
/// third job suffering an anomaly (cycling CPU → IO → Network). Fully
/// deterministic in `base_seed`.
pub fn round_robin_specs(n_jobs: usize, scale: f64, base_seed: u64) -> Vec<MultiJobSpec> {
    let suite = workloads::hibench_suite(scale);
    let kinds = AnomalyKind::all();
    (0..n_jobs)
        .map(|i| MultiJobSpec {
            job_id: i as u64,
            workload: suite[i % suite.len()].clone(),
            seed: base_seed.wrapping_add(i as u64 * 1001),
            inject: if i % 3 == 2 { Some(kinds[(i / 3) % kinds.len()]) } else { None },
        })
        .collect()
}

/// Simulate every spec'd job on its own (deterministic) engine.
pub fn run_jobs(specs: &[MultiJobSpec]) -> Vec<(u64, JobTrace)> {
    specs
        .iter()
        .map(|s| {
            let mut eng = Engine::new(SimConfig { seed: s.seed, ..Default::default() });
            let horizon = 400.0;
            let plan = match s.inject {
                Some(kind) => InjectionPlan::intermittent(kind, 1, 15.0, 10.0, horizon),
                None => InjectionPlan::none(),
            };
            let name = format!("job-{}", s.job_id);
            let trace = eng.run(&name, s.workload.name, &s.workload.stages, &plan);
            (s.job_id, trace)
        })
        .collect()
}

/// Simulate the jobs and interleave their event logs by time: the full
/// multi-job scenario in one call. Returns the per-job ground-truth traces
/// (for parity checks) alongside the merged tagged stream.
pub fn interleaved_workload(specs: &[MultiJobSpec]) -> (Vec<(u64, JobTrace)>, Vec<TaggedEvent>) {
    let traces = run_jobs(specs);
    let refs: Vec<(u64, &JobTrace)> = traces.iter().map(|(id, t)| (*id, t)).collect();
    let events = interleave_jobs(&refs);
    (traces, events)
}

/// Randomly remix the cross-job arrival order while preserving each job's
/// internal event order: repeatedly pop the head of a random per-job queue,
/// weighting queues by their remaining length so the mix stays uniform.
pub fn shuffle_preserving_job_order(events: &[TaggedEvent], rng: &mut Pcg64) -> Vec<TaggedEvent> {
    let mut queues: Vec<(u64, VecDeque<TaggedEvent>)> = Vec::new();
    for e in events {
        match queues.iter().position(|(id, _)| *id == e.job_id) {
            Some(idx) => queues[idx].1.push_back(e.clone()),
            None => queues.push((e.job_id, VecDeque::from(vec![e.clone()]))),
        }
    }
    let mut out = Vec::with_capacity(events.len());
    let mut remaining = events.len();
    while remaining > 0 {
        let mut pick = rng.below(remaining as u64) as usize;
        for (_, q) in queues.iter_mut() {
            if pick < q.len() {
                out.push(q.pop_front().expect("non-empty queue"));
                remaining -= 1;
                break;
            }
            pick -= q.len();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::eventlog::demux_jobs;

    #[test]
    fn specs_are_deterministic_and_cycle_workloads() {
        let a = round_robin_specs(6, 0.05, 7);
        let b = round_robin_specs(6, 0.05, 7);
        assert_eq!(a.len(), 6);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.job_id, y.job_id);
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.workload.name, y.workload.name);
        }
        assert!(a.iter().any(|s| s.inject.is_some()));
        assert!(a.iter().any(|s| s.inject.is_none()));
    }

    #[test]
    fn interleaved_workload_tags_every_job() {
        let specs = round_robin_specs(3, 0.05, 11);
        let (traces, events) = interleaved_workload(&specs);
        assert_eq!(traces.len(), 3);
        let per_job = demux_jobs(&events);
        assert_eq!(per_job.len(), 3);
        for ((jid, trace), (eid, ev)) in traces.iter().zip(&per_job) {
            assert_eq!(jid, eid);
            assert!(ev.len() > trace.tasks.len()); // at least start+end per task
        }
    }

    #[test]
    fn shuffle_preserves_per_job_order() {
        let specs = round_robin_specs(3, 0.05, 13);
        let (_, events) = interleaved_workload(&specs);
        let mut rng = Pcg64::seeded(99);
        let shuffled = shuffle_preserving_job_order(&events, &mut rng);
        assert_eq!(shuffled.len(), events.len());
        assert_ne!(shuffled, events); // astronomically unlikely to match
        assert_eq!(demux_jobs(&shuffled), demux_jobs(&events));
    }
}
