//! HiBench-like workload models — the substitution for the paper's
//! evaluation suite (Table VI). Each workload is a sequence of
//! [`StageSpec`]s whose feature distributions encode the skew mechanism the
//! paper attributes to it:
//!
//! - **Kmeans**: Zipf-skewed shuffle reads (uneven cluster centers).
//! - **NaiveBayes**: mild shuffle skew confined to the label-probability
//!   aggregation (a small fraction of tasks).
//! - **LogisticRegression / SVM**: skewed `bytes_read` from Spark's SGD
//!   sampling; SVM additionally fetches remotely (network pressure).
//! - **PCA**: thousands of tiny tasks with broad unexplained variance.
//! - **Sort**: I/O bound; **Terasort/Wordcount**: small micro jobs;
//! - **Nweight**: CPU + network (graph traversal); **Aggregation**: SQL
//!   shuffle; **Pagerank**: CPU-bound iterations.
//!
//! `scale` shrinks task counts for fast tests (1.0 = Table VI scale).

use super::task::{GcProfile, InputKind, SizeDist, StageSpec};

/// A named multi-stage workload.
#[derive(Debug, Clone)]
pub struct Workload {
    pub name: &'static str,
    pub domain: &'static str,
    pub stages: Vec<StageSpec>,
}

fn scaled(n: usize, scale: f64) -> usize {
    ((n as f64 * scale).round() as usize).max(4)
}

/// The NaiveBayes "large" workload of the verification experiments
/// (Section IV-B: 1M pages, 100 classes) — two map stages + an aggregate.
pub fn naive_bayes(scale: f64) -> Workload {
    // Sized so the scale-1.0 job spans ~60-90 s on the 5-slave testbed,
    // matching the Figures 3–6 timelines (and long enough that the Table IV
    // schedule overlaps real work).
    // Natural baseline variance (the Fig. 3 no-AG run already shows ~2.4x
    // stragglers): skewed page sizes and occasional full-GC pauses.
    let mut tokenize = StageSpec::base("tokenize", scaled(500, scale));
    tokenize.input_mean_bytes = 48e6;
    tokenize.input_dist = SizeDist::LogNormal { sigma: 0.45 };
    tokenize.compute_per_byte = 4.0e-8;
    tokenize.compute_base = 0.4;
    tokenize.gc = GcProfile { base_frac: 0.03, tail_prob: 0.015, tail_frac: 1.2 };
    tokenize.shuffle_write_mean = 6e6;

    let mut tf = StageSpec::base("term-frequency", scaled(400, scale));
    tf.input_kind = InputKind::Shuffle;
    tf.input_mean_bytes = 7e6;
    tf.input_dist = SizeDist::LogNormal { sigma: 0.35 };
    tf.compute_dist = SizeDist::LogNormal { sigma: 0.3 };
    tf.compute_per_byte = 5.0e-8;
    tf.compute_base = 0.35;
    tf.gc = GcProfile { base_frac: 0.03, tail_prob: 0.015, tail_frac: 1.2 };
    tf.shuffle_write_mean = 4e6;

    let mut aggregate = StageSpec::base("aggregate-labels", scaled(200, scale));
    aggregate.input_kind = InputKind::Shuffle;
    aggregate.input_mean_bytes = 9e6;
    // Mild skew: only the label-probability partition is hot.
    aggregate.input_dist = SizeDist::Zipf { s: 0.7 };
    aggregate.compute_per_byte = 3.0e-8;
    aggregate.compute_base = 0.3;
    aggregate.shuffle_write_mean = 0.0;
    aggregate.gc = GcProfile::LIGHT;

    Workload {
        name: "NaiveBayes",
        domain: "Machine Learning",
        stages: vec![tokenize, tf, aggregate],
    }
}

/// Kmeans: map + heavily skewed reduceByKey (uneven clustering centers).
pub fn kmeans(scale: f64) -> Workload {
    let mut assign = StageSpec::base("assign-centers", scaled(200, scale));
    assign.input_mean_bytes = 32e6;
    assign.compute_per_byte = 5.0e-8;
    assign.compute_base = 0.5;
    assign.shuffle_write_mean = 8e6;
    assign.shuffle_write_dist = SizeDist::LogNormal { sigma: 0.2 };

    let mut update = StageSpec::base("update-centers", scaled(120, scale));
    update.input_kind = InputKind::Shuffle;
    update.input_mean_bytes = 13e6;
    // Strong Zipf: the disequilibrium of cluster centers (paper: 49
    // shuffle-read stragglers).
    update.input_dist = SizeDist::Zipf { s: 1.3 };
    update.compute_per_byte = 6.0e-8;
    update.compute_base = 0.25;
    update.shuffle_write_mean = 0.5e6;
    update.gc = GcProfile::HEAVY;
    update.spill_prob = 0.05;

    Workload { name: "Kmeans", domain: "Machine Learning", stages: vec![assign, update] }
}

/// Logistic Regression: SGD iterations with skewed input sampling.
pub fn logistic_regression(scale: f64) -> Workload {
    let mut stages = Vec::new();
    for it in 0..4 {
        let mut grad = StageSpec::base(
            match it {
                0 => "sgd-iter-0",
                1 => "sgd-iter-1",
                2 => "sgd-iter-2",
                _ => "sgd-iter-3",
            },
            scaled(260, scale),
        );
        grad.input_mean_bytes = 24e6;
        // Heavy bytes_read skew from SGD partition sampling (paper: 287
        // bytes_read root causes).
        grad.input_dist = SizeDist::LogNormal { sigma: 0.9 };
        grad.compute_per_byte = 4.5e-8;
        grad.compute_base = 0.3;
        grad.shuffle_write_mean = 0.2e6;
        grad.gc = GcProfile::LIGHT;
        stages.push(grad);
    }
    Workload { name: "LogisticRegression", domain: "Machine Learning", stages }
}

/// PCA: thousands of tiny tasks; variance comes from everywhere and nowhere
/// (the paper: 4107 stragglers, mostly unexplained).
pub fn pca(scale: f64) -> Workload {
    let mut stages = Vec::new();
    for (i, name) in ["gramian", "eigen-prep", "project"].iter().enumerate() {
        let mut s = StageSpec::base(name, scaled(900, scale));
        s.input_mean_bytes = 2.5e6;
        s.input_dist = SizeDist::LogNormal { sigma: 0.35 };
        s.compute_per_byte = 6.0e-8;
        s.compute_base = 0.08;
        // Small tasks → scheduler/GC noise dominates; broad compute spread.
        s.compute_dist = SizeDist::LogNormal { sigma: 0.5 };
        s.gc = GcProfile { base_frac: 0.04, tail_prob: 0.01, tail_frac: 1.5 };
        s.shuffle_write_mean = 0.4e6;
        if i > 0 {
            s.input_kind = InputKind::Shuffle;
        }
        stages.push(s);
    }
    Workload { name: "PCA", domain: "Machine Learning", stages }
}

/// SVM: SGD with skewed, often-remote reads (paper: 1634 bytes_read + 167
/// network root causes).
pub fn svm(scale: f64) -> Workload {
    let mut stages = Vec::new();
    for it in 0..3 {
        let mut s = StageSpec::base(
            match it {
                0 => "svm-iter-0",
                1 => "svm-iter-1",
                _ => "svm-iter-2",
            },
            scaled(700, scale),
        );
        s.input_mean_bytes = 20e6;
        s.input_dist = SizeDist::LogNormal { sigma: 1.0 };
        s.compute_per_byte = 3.5e-8;
        s.compute_base = 0.15;
        s.compute_dist = SizeDist::LogNormal { sigma: 0.4 };
        s.shuffle_write_mean = 0.3e6;
        stages.push(s);
    }
    Workload { name: "SVM", domain: "Machine Learning", stages }
}

/// Sort: disk-bound shuffle (paper: I/O root causes).
pub fn sort(scale: f64) -> Workload {
    let mut map = StageSpec::base("sort-map", scaled(60, scale));
    map.input_mean_bytes = 96e6; // heavy reads
    map.input_dist = SizeDist::LogNormal { sigma: 0.25 };
    map.compute_per_byte = 0.6e-8;
    map.compute_base = 0.1;
    map.shuffle_write_mean = 80e6; // heavy writes
    map.spill_prob = 0.12;

    let mut reduce = StageSpec::base("sort-reduce", scaled(40, scale));
    reduce.input_kind = InputKind::Shuffle;
    reduce.input_mean_bytes = 110e6;
    reduce.input_dist = SizeDist::LogNormal { sigma: 0.3 };
    reduce.compute_per_byte = 0.5e-8;
    reduce.compute_base = 0.1;
    reduce.shuffle_write_mean = 0.0;
    reduce.spill_prob = 0.15;

    Workload { name: "Sort", domain: "Micro", stages: vec![map, reduce] }
}

/// Terasort: tiny, well-balanced (paper: 2 stragglers, unexplained).
pub fn terasort(scale: f64) -> Workload {
    let mut map = StageSpec::base("tera-map", scaled(48, scale));
    map.input_mean_bytes = 64e6;
    map.input_dist = SizeDist::Uniform { lo: 0.97, hi: 1.03 };
    map.compute_per_byte = 0.8e-8;
    map.shuffle_write_mean = 48e6;
    let mut reduce = StageSpec::base("tera-reduce", scaled(32, scale));
    reduce.input_kind = InputKind::Shuffle;
    reduce.input_mean_bytes = 72e6;
    reduce.input_dist = SizeDist::Uniform { lo: 0.97, hi: 1.03 };
    reduce.compute_per_byte = 0.7e-8;
    reduce.shuffle_write_mean = 0.0;
    Workload { name: "Terasort", domain: "Micro", stages: vec![map, reduce] }
}

/// Wordcount: compute-light map + tiny aggregate.
pub fn wordcount(scale: f64) -> Workload {
    let mut map = StageSpec::base("wc-map", scaled(72, scale));
    map.input_mean_bytes = 64e6;
    map.input_dist = SizeDist::LogNormal { sigma: 0.3 };
    map.compute_per_byte = 1.5e-8;
    map.gc = GcProfile { base_frac: 0.03, tail_prob: 0.01, tail_frac: 1.0 };
    map.shuffle_write_mean = 1e6;
    let mut reduce = StageSpec::base("wc-reduce", scaled(24, scale));
    reduce.input_kind = InputKind::Shuffle;
    reduce.input_mean_bytes = 3e6;
    reduce.compute_per_byte = 2e-8;
    reduce.shuffle_write_mean = 0.0;
    Workload { name: "Wordcount", domain: "Micro", stages: vec![map, reduce] }
}

/// Nweight: graph traversal — CPU-heavy with remote edge fetches.
pub fn nweight(scale: f64) -> Workload {
    let mut stages = Vec::new();
    for hop in 0..3 {
        let mut s = StageSpec::base(
            match hop {
                0 => "hop-0",
                1 => "hop-1",
                _ => "hop-2",
            },
            scaled(90, scale),
        );
        s.input_kind = if hop == 0 { InputKind::Hdfs } else { InputKind::Shuffle };
        s.input_mean_bytes = 18e6;
        s.input_dist = SizeDist::LogNormal { sigma: 0.45 };
        s.compute_per_byte = 9.0e-8; // CPU-heavy edge joins
        s.compute_base = 0.6;
        s.compute_dist = SizeDist::LogNormal { sigma: 0.3 };
        s.shuffle_write_mean = 14e6;
        s.gc = GcProfile::HEAVY;
        stages.push(s);
    }
    Workload { name: "Nweight", domain: "Graph", stages }
}

/// Aggregation (SQL): scan + group-by.
pub fn aggregation(scale: f64) -> Workload {
    let mut scan = StageSpec::base("scan", scaled(80, scale));
    scan.input_mean_bytes = 48e6;
    scan.input_dist = SizeDist::LogNormal { sigma: 0.3 };
    scan.compute_per_byte = 1.2e-8;
    scan.gc = GcProfile { base_frac: 0.03, tail_prob: 0.012, tail_frac: 1.0 };
    scan.shuffle_write_mean = 4e6;
    let mut group = StageSpec::base("group-by", scaled(40, scale));
    group.input_kind = InputKind::Shuffle;
    group.input_mean_bytes = 8e6;
    group.input_dist = SizeDist::LogNormal { sigma: 0.45 };
    group.compute_per_byte = 2e-8;
    group.shuffle_write_mean = 0.0;
    Workload { name: "Aggregation", domain: "SQL", stages: vec![scan, group] }
}

/// Pagerank: CPU-bound iterations (paper: CPU root causes).
pub fn pagerank(scale: f64) -> Workload {
    let mut stages = Vec::new();
    for it in 0..3 {
        let mut s = StageSpec::base(
            match it {
                0 => "rank-iter-0",
                1 => "rank-iter-1",
                _ => "rank-iter-2",
            },
            scaled(80, scale),
        );
        s.input_kind = if it == 0 { InputKind::Hdfs } else { InputKind::Shuffle };
        s.input_mean_bytes = 16e6;
        s.compute_per_byte = 8.0e-8;
        s.compute_base = 0.7;
        s.compute_dist = SizeDist::LogNormal { sigma: 0.35 };
        s.gc = GcProfile { base_frac: 0.03, tail_prob: 0.01, tail_frac: 1.0 };
        s.shuffle_write_mean = 12e6;
        stages.push(s);
    }
    Workload { name: "Pagerank", domain: "WebSearch", stages }
}

/// All Table VI workloads in the paper's row order.
pub fn hibench_suite(scale: f64) -> Vec<Workload> {
    vec![
        kmeans(scale),
        naive_bayes(scale),
        logistic_regression(scale),
        pca(scale),
        svm(scale),
        sort(scale),
        terasort(scale),
        wordcount(scale),
        nweight(scale),
        aggregation(scale),
        pagerank(scale),
    ]
}

/// Look up a workload by (case-insensitive) name.
pub fn by_name(name: &str, scale: f64) -> Option<Workload> {
    let lower = name.to_ascii_lowercase();
    hibench_suite(scale).into_iter().find(|w| w.name.to_ascii_lowercase() == lower)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::anomaly::InjectionPlan;
    use crate::sim::engine::{Engine, SimConfig};

    #[test]
    fn suite_has_eleven_workloads() {
        let suite = hibench_suite(1.0);
        assert_eq!(suite.len(), 11);
        let names: Vec<_> = suite.iter().map(|w| w.name).collect();
        for expected in [
            "Kmeans",
            "NaiveBayes",
            "LogisticRegression",
            "PCA",
            "SVM",
            "Sort",
            "Terasort",
            "Wordcount",
            "Nweight",
            "Aggregation",
            "Pagerank",
        ] {
            assert!(names.contains(&expected), "missing {expected}");
        }
    }

    #[test]
    fn by_name_is_case_insensitive() {
        assert!(by_name("kmeans", 0.1).is_some());
        assert!(by_name("KMEANS", 0.1).is_some());
        assert!(by_name("nope", 0.1).is_none());
    }

    #[test]
    fn scale_shrinks_task_counts() {
        let big = kmeans(1.0);
        let small = kmeans(0.1);
        assert!(small.stages[0].num_tasks < big.stages[0].num_tasks);
        assert!(small.stages[0].num_tasks >= 4);
    }

    #[test]
    fn every_workload_simulates_cleanly_at_small_scale() {
        for w in hibench_suite(0.06) {
            let mut eng = Engine::new(SimConfig { seed: 11, ..Default::default() });
            let trace = eng.run("t", w.name, &w.stages, &InjectionPlan::none());
            trace.validate().unwrap_or_else(|e| panic!("{}: {e}", w.name));
            assert_eq!(
                trace.tasks.len(),
                w.stages.iter().map(|s| s.num_tasks).sum::<usize>(),
                "{} task count",
                w.name
            );
        }
    }

    #[test]
    fn kmeans_reduce_has_shuffle_skew() {
        let w = kmeans(0.3);
        let mut eng = Engine::new(SimConfig { seed: 12, ..Default::default() });
        let trace = eng.run("t", w.name, &w.stages, &InjectionPlan::none());
        let reduce: Vec<f64> = trace
            .stage_tasks(1)
            .iter()
            .map(|t| t.shuffle_read_bytes)
            .collect();
        let max = reduce.iter().cloned().fold(0.0, f64::max);
        let mean = crate::util::stats::mean(&reduce);
        assert!(max > 3.0 * mean, "kmeans shuffle skew: max {max} mean {mean}");
    }

    #[test]
    fn sort_is_disk_heavy() {
        let w = sort(0.3);
        let mut eng = Engine::new(SimConfig { seed: 13, ..Default::default() });
        let trace = eng.run("t", w.name, &w.stages, &InjectionPlan::none());
        // Disk utilization should be substantial during the run.
        let busy: f64 = trace
            .node_series
            .iter()
            .map(|s| crate::util::stats::mean(&s.disk))
            .sum::<f64>()
            / trace.node_series.len() as f64;
        assert!(busy > 0.07, "sort disk util {busy}");
    }
}
