//! The cluster simulator substrate — the substitution for the paper's
//! 6-node Spark testbed (see DESIGN.md §2).
//!
//! - [`event`] — deterministic discrete-event queue
//! - [`resources`] — weighted max-min fair shared-resource model per node
//! - [`task`] — task/stage specifications, skew distributions, GC profiles
//! - [`scheduler`] — Spark-style delay scheduling with locality degradation
//! - [`anomaly`] — CPU / I/O / network anomaly generators + schedules
//! - [`sampler`] — 1 Hz mpstat/iostat/sar equivalents (+ Table VII overhead)
//! - [`workloads`] — the 11 HiBench workload models of Table VI
//! - [`engine`] — the fluid-flow simulation loop producing [`crate::trace::JobTrace`]s
//! - [`replay`] — deterministic slot-level replay of observed traces (the
//!   counterfactual half of `analysis/whatif.rs`)

pub mod anomaly;
pub mod engine;
pub mod event;
pub mod multi;
pub mod replay;
pub mod resources;
pub mod sampler;
pub mod scheduler;
pub mod task;
pub mod workloads;

pub use anomaly::{AgIntensity, Injection, InjectionPlan};
pub use engine::{Engine, NoiseConfig, SimConfig};
pub use task::{GcProfile, InputKind, SizeDist, StageSpec, TaskSpec};
pub use workloads::Workload;
