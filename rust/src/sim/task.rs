//! Task specifications and the phase model.
//!
//! A simulated task executes a sequence of *phases*, each drawing on one
//! node resource, mirroring the lifecycle Spark reports metrics for:
//!
//! 1. **Deserialize** (CPU) — executor deserialization time
//! 2. **Input** (disk if local, network if remote) — `bytes_read` or
//!    `shuffle_read_bytes`
//! 3. **Compute** (CPU) — the task function, extended by JVM GC pauses
//! 4. **Output** (disk) — shuffle write + spills
//! 5. **Serialize** (CPU) — result serialization
//!
//! Phase *work* is expressed in resource units (core-seconds for CPU,
//! bytes for disk/net); elapsed time emerges from the granted rate under
//! contention ([`super::resources`]). Data skew enters through per-task
//! size distributions ([`SizeDist`]); GC tails through [`GcProfile`].

use crate::util::rng::Pcg64;

/// Per-task size multiplier distribution — the data-skew knob.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SizeDist {
    /// Uniform multiplier in [lo, hi] around the mean.
    Uniform { lo: f64, hi: f64 },
    /// Log-normal multiplier: exp(N(0, sigma)), normalized to mean 1.
    LogNormal { sigma: f64 },
    /// Zipf partition skew: task k of n gets a share ∝ (rank+1)^-s,
    /// normalized so the mean multiplier is 1. Rank is assigned by hashing
    /// the task index, so skewed partitions land on arbitrary nodes.
    Zipf { s: f64 },
}

impl SizeDist {
    /// Draw the size multiplier for task `index` of `n` in a stage.
    pub fn sample(&self, rng: &mut Pcg64, index: usize, n: usize) -> f64 {
        match *self {
            SizeDist::Uniform { lo, hi } => rng.range_f64(lo, hi),
            SizeDist::LogNormal { sigma } => {
                // E[exp(N(0, σ))] = exp(σ²/2); divide to normalize mean to 1.
                rng.lognormal(0.0, sigma) / (sigma * sigma / 2.0).exp()
            }
            SizeDist::Zipf { s } => {
                let n = n.max(1);
                // Normalization: sum of (k+1)^-s over ranks.
                let h: f64 = (0..n).map(|k| 1.0 / ((k + 1) as f64).powf(s)).sum();
                // Deterministic rank *permutation*: rank of task i is the
                // position of mix(i) among {mix(0), ..., mix(n-1)}. SplitMix64
                // is a bijection, so distinct indices give distinct keys and
                // the ranks form an exact permutation (mean multiplier is
                // exactly 1). O(n) per task is negligible at stage sizes.
                let key = mix(index as u64);
                let rank = (0..n).filter(|&j| mix(j as u64) < key).count();
                let share = 1.0 / ((rank + 1) as f64).powf(s) / h;
                share * n as f64 // mean multiplier 1
            }
        }
    }
}

/// SplitMix64 hash — gives a deterministic pseudo-permutation of ranks.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// JVM garbage-collection profile: every task pays `base_frac` of its
/// compute work in GC; with probability `tail_prob` it takes a pathological
/// pause of `tail_frac` of compute work (heap pressure, full GC).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GcProfile {
    pub base_frac: f64,
    pub tail_prob: f64,
    pub tail_frac: f64,
}

impl GcProfile {
    pub const LIGHT: GcProfile = GcProfile { base_frac: 0.02, tail_prob: 0.005, tail_frac: 0.5 };
    pub const HEAVY: GcProfile = GcProfile { base_frac: 0.06, tail_prob: 0.03, tail_frac: 1.0 };

    pub fn sample(&self, rng: &mut Pcg64, compute_work: f64) -> f64 {
        let mut gc = compute_work * self.base_frac * rng.range_f64(0.5, 1.5);
        if rng.chance(self.tail_prob) {
            gc += compute_work * self.tail_frac * rng.range_f64(0.5, 1.5);
        }
        gc
    }
}

/// Where a stage's input comes from — determines both the feature column
/// that carries the skew and the locality behaviour.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InputKind {
    /// Read from distributed storage: tasks have a preferred node (the block
    /// location); `bytes_read` is populated.
    Hdfs,
    /// Read shuffled output of the previous stage: `shuffle_read_bytes` is
    /// populated; most bytes cross the network regardless of placement.
    Shuffle,
}

/// Fully materialized specification of one task, ready for the engine.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub task_id: u64,
    pub stage_id: u64,
    /// Node index holding this task's input data (HDFS block / map outputs).
    pub preferred_node: usize,
    pub preferred_executor: usize,
    pub input_kind: InputKind,
    /// Input bytes (goes to `bytes_read` or `shuffle_read_bytes`).
    pub input_bytes: f64,
    /// Single-core compute work in core-seconds, *excluding* GC.
    pub compute_work: f64,
    /// GC core-seconds added to the compute phase.
    pub gc_work: f64,
    pub shuffle_write_bytes: f64,
    pub memory_bytes_spilled: f64,
    pub disk_bytes_spilled: f64,
    pub serialize_work: f64,
    pub deserialize_work: f64,
}

impl TaskSpec {
    /// Disk bytes written during the output phase.
    pub fn output_bytes(&self) -> f64 {
        self.shuffle_write_bytes + self.disk_bytes_spilled
    }
}

/// Specification of one stage of a workload.
#[derive(Debug, Clone)]
pub struct StageSpec {
    pub name: String,
    pub num_tasks: usize,
    pub input_kind: InputKind,
    /// Mean input bytes per task.
    pub input_mean_bytes: f64,
    pub input_dist: SizeDist,
    /// Compute seconds per input byte (CPU intensity).
    pub compute_per_byte: f64,
    /// Fixed compute seconds independent of input size.
    pub compute_base: f64,
    pub compute_dist: SizeDist,
    pub gc: GcProfile,
    /// Mean shuffle-write bytes per task (0 for final stages).
    pub shuffle_write_mean: f64,
    pub shuffle_write_dist: SizeDist,
    /// Probability a task spills (memory pressure); spills add disk writes
    /// and memory-spill bytes proportional to input.
    pub spill_prob: f64,
}

impl StageSpec {
    /// A neutral stage used as the base for workload definitions.
    pub fn base(name: &str, num_tasks: usize) -> StageSpec {
        StageSpec {
            name: name.to_string(),
            num_tasks,
            input_kind: InputKind::Hdfs,
            input_mean_bytes: 32e6,
            input_dist: SizeDist::Uniform { lo: 0.8, hi: 1.2 },
            compute_per_byte: 2.0e-8,
            compute_base: 0.3,
            compute_dist: SizeDist::Uniform { lo: 0.9, hi: 1.1 },
            gc: GcProfile::LIGHT,
            shuffle_write_mean: 2e6,
            shuffle_write_dist: SizeDist::Uniform { lo: 0.9, hi: 1.1 },
            spill_prob: 0.01,
        }
    }

    /// Materialize the stage's tasks, assigning preferred nodes round-robin
    /// with a shuffled start (HDFS block placement) and sampling all sizes.
    pub fn materialize(
        &self,
        rng: &mut Pcg64,
        stage_id: u64,
        first_task_id: u64,
        nodes: usize,
        executors_per_node: usize,
    ) -> Vec<TaskSpec> {
        let n = self.num_tasks;
        let offset = rng.below(nodes.max(1) as u64) as usize;
        (0..n)
            .map(|i| {
                let input_mult = self.input_dist.sample(rng, i, n);
                let input_bytes = self.input_mean_bytes * input_mult;
                let compute_mult = self.compute_dist.sample(rng, i, n);
                let compute_work =
                    (self.compute_base + self.compute_per_byte * input_bytes) * compute_mult;
                let gc_work = self.gc.sample(rng, compute_work);
                let sw = self.shuffle_write_mean
                    * self.shuffle_write_dist.sample(rng, i, n);
                let (mem_spill, disk_spill) = if rng.chance(self.spill_prob) {
                    (input_bytes * rng.range_f64(0.2, 0.6), input_bytes * rng.range_f64(0.1, 0.3))
                } else {
                    (0.0, 0.0)
                };
                TaskSpec {
                    task_id: first_task_id + i as u64,
                    stage_id,
                    preferred_node: (i + offset) % nodes.max(1),
                    preferred_executor: rng.below(executors_per_node.max(1) as u64) as usize,
                    input_kind: self.input_kind,
                    input_bytes,
                    compute_work,
                    gc_work,
                    shuffle_write_bytes: sw,
                    memory_bytes_spilled: mem_spill,
                    disk_bytes_spilled: disk_spill,
                    serialize_work: rng.range_f64(0.005, 0.02),
                    deserialize_work: rng.range_f64(0.01, 0.05),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_dist_within_bounds() {
        let mut rng = Pcg64::seeded(1);
        let d = SizeDist::Uniform { lo: 0.5, hi: 1.5 };
        for i in 0..1000 {
            let m = d.sample(&mut rng, i, 1000);
            assert!((0.5..1.5).contains(&m));
        }
    }

    #[test]
    fn lognormal_mean_near_one() {
        let mut rng = Pcg64::seeded(2);
        let d = SizeDist::LogNormal { sigma: 0.8 };
        let n = 100_000;
        let mean: f64 = (0..n).map(|i| d.sample(&mut rng, i, n)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn zipf_mean_exactly_one_and_skewed() {
        let mut rng = Pcg64::seeded(3);
        let d = SizeDist::Zipf { s: 1.5 };
        let n = 200;
        let samples: Vec<f64> = (0..n).map(|i| d.sample(&mut rng, i, n)).collect();
        let mean: f64 = samples.iter().sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 1e-9, "zipf mean must be exactly 1, got {mean}");
        let max = samples.iter().cloned().fold(0.0, f64::max);
        assert!(max > 5.0, "zipf should produce a dominant partition, max={max}");
        // Deterministic per (index, n): same index gives same multiplier.
        let mut rng2 = Pcg64::seeded(99);
        assert_eq!(d.sample(&mut rng2, 7, n), samples[7]);
    }

    #[test]
    fn gc_profile_tail() {
        let mut rng = Pcg64::seeded(4);
        let gc = GcProfile { base_frac: 0.02, tail_prob: 0.5, tail_frac: 2.0 };
        let n = 10_000;
        let samples: Vec<f64> = (0..n).map(|_| gc.sample(&mut rng, 10.0)).collect();
        let with_tail = samples.iter().filter(|&&g| g > 1.0).count();
        // ~50% should include the tail pause (tail adds ≥ 10*2*0.5 = 10 ≥ 1).
        assert!((with_tail as f64 / n as f64 - 0.5).abs() < 0.05);
        assert!(samples.iter().all(|&g| g >= 0.0));
    }

    #[test]
    fn materialize_covers_nodes_and_ids() {
        let mut rng = Pcg64::seeded(5);
        let spec = StageSpec::base("s", 50);
        let tasks = spec.materialize(&mut rng, 3, 100, 5, 2);
        assert_eq!(tasks.len(), 50);
        assert_eq!(tasks[0].task_id, 100);
        assert_eq!(tasks[49].task_id, 149);
        assert!(tasks.iter().all(|t| t.stage_id == 3));
        assert!(tasks.iter().all(|t| t.preferred_node < 5));
        assert!(tasks.iter().all(|t| t.preferred_executor < 2));
        // All 5 nodes are preferred by some task (round-robin).
        for n in 0..5 {
            assert!(tasks.iter().any(|t| t.preferred_node == n));
        }
    }

    #[test]
    fn materialize_positive_quantities() {
        let mut rng = Pcg64::seeded(6);
        let spec = StageSpec::base("s", 200);
        for t in spec.materialize(&mut rng, 0, 0, 5, 2) {
            assert!(t.input_bytes > 0.0);
            assert!(t.compute_work > 0.0);
            assert!(t.gc_work >= 0.0);
            assert!(t.shuffle_write_bytes >= 0.0);
            assert!(t.serialize_work > 0.0);
            assert!(t.deserialize_work > 0.0);
            assert!(t.output_bytes() >= t.shuffle_write_bytes);
        }
    }

    #[test]
    fn spill_probability_respected() {
        let mut rng = Pcg64::seeded(7);
        let mut spec = StageSpec::base("s", 2000);
        spec.spill_prob = 0.25;
        let tasks = spec.materialize(&mut rng, 0, 0, 5, 2);
        let spilled = tasks.iter().filter(|t| t.disk_bytes_spilled > 0.0).count();
        let frac = spilled as f64 / tasks.len() as f64;
        assert!((frac - 0.25).abs() < 0.05, "spill frac={frac}");
    }
}
