//! Deterministic trace-replay scheduler — the simulation half of the
//! counterfactual what-if engine (`crate::analysis::whatif`).
//!
//! The fluid engine (`sim/engine.rs`) materializes tasks from `StageSpec`s
//! and *produces* traces; what-if analysis needs the opposite direction:
//! take a trace that was already observed (durations, node placement) and
//! re-derive the job completion time under a modified set of task
//! durations. This module is that replay: a slot-level list scheduler that
//! mirrors the engine's execution discipline —
//!
//! - stages run **sequentially** with a barrier between them, exactly as
//!   the engine runs them (stage *s+1* starts when every task of stage *s*
//!   finished);
//! - within a stage each task runs on its **recorded node** (placement is
//!   not a counterfactual here), on one of `slots_per_node` parallel task
//!   slots, assigned greedily in input order to the earliest-free slot;
//! - the stage completes when its last slot drains; the job completion
//!   time is the sum of stage makespans.
//!
//! Everything is plain `f64` arithmetic over the inputs in a fixed order:
//! replaying the same `(stages, slots_per_node)` twice is **bit-identical**,
//! which is what makes what-if savings exactly testable.

use crate::trace::JobTrace;

/// One task to replay: where it ran and how long it took (possibly a
/// counterfactually adjusted duration).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplayTask {
    pub node: usize,
    pub duration: f64,
}

/// One stage of the replayed job, in scheduling order.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplayStage {
    pub stage_id: u64,
    pub tasks: Vec<ReplayTask>,
}

/// Makespan of one stage under the slot model: tasks are assigned in input
/// order to the earliest-free of `slots_per_node` slots on their node.
pub fn stage_makespan(tasks: &[ReplayTask], slots_per_node: usize) -> f64 {
    let slots = slots_per_node.max(1);
    let nodes = tasks.iter().map(|t| t.node + 1).max().unwrap_or(0);
    // Per-node slot free times, flat: node n owns [n*slots, (n+1)*slots).
    let mut free = vec![0.0f64; nodes * slots];
    for t in tasks {
        let lane = &mut free[t.node * slots..(t.node + 1) * slots];
        // Earliest-free slot; first-wins on ties keeps this deterministic.
        let mut best = 0usize;
        for (i, &f) in lane.iter().enumerate() {
            if f < lane[best] {
                best = i;
            }
        }
        lane[best] += t.duration.max(0.0);
    }
    free.iter().fold(0.0f64, |acc, &f| acc.max(f))
}

/// Job completion time: stage barriers, so the sum of stage makespans.
pub fn job_completion(stages: &[ReplayStage], slots_per_node: usize) -> f64 {
    stages.iter().map(|s| stage_makespan(&s.tasks, slots_per_node)).sum()
}

/// Infer the effective per-node task-slot count from an observed trace:
/// the maximum number of tasks that ever ran concurrently on any node.
/// Deterministic (interval sweep with total-order tie-breaking); at least 1.
pub fn infer_slots_per_node(trace: &JobTrace) -> usize {
    let nodes = trace.cluster.nodes.max(1);
    let mut best = 1usize;
    for node in 0..nodes {
        // (+1 at start, -1 at finish); finishes sort before starts at the
        // same instant so back-to-back waves don't double-count.
        let mut edges: Vec<(f64, i32)> = Vec::new();
        for t in trace.tasks.iter().filter(|t| t.node == node) {
            edges.push((t.start, 1));
            edges.push((t.finish, -1));
        }
        edges.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut cur = 0i64;
        let mut peak = 0i64;
        for (_, d) in edges {
            cur += d as i64;
            peak = peak.max(cur);
        }
        best = best.max(peak.max(1) as usize);
    }
    best
}

/// Build the baseline replay stages straight from a trace: observed
/// durations on observed nodes, stages in id order, tasks in id order.
pub fn stages_from_trace(trace: &JobTrace) -> Vec<ReplayStage> {
    let mut out: Vec<ReplayStage> = Vec::with_capacity(trace.stages.len());
    for stage in &trace.stages {
        let mut tasks: Vec<(u64, ReplayTask)> = trace
            .tasks
            .iter()
            .filter(|t| t.stage_id == stage.stage_id)
            .map(|t| (t.task_id, ReplayTask { node: t.node, duration: t.duration() }))
            .collect();
        tasks.sort_by_key(|(id, _)| *id);
        out.push(ReplayStage {
            stage_id: stage.stage_id,
            tasks: tasks.into_iter().map(|(_, t)| t).collect(),
        });
    }
    out.sort_by_key(|s| s.stage_id);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{workloads, Engine, InjectionPlan, SimConfig};

    #[test]
    fn single_slot_serializes_a_node() {
        let tasks = vec![
            ReplayTask { node: 0, duration: 1.0 },
            ReplayTask { node: 0, duration: 2.0 },
            ReplayTask { node: 0, duration: 3.0 },
        ];
        assert_eq!(stage_makespan(&tasks, 1), 6.0);
        // Three slots: all parallel, bound by the longest task.
        assert_eq!(stage_makespan(&tasks, 3), 3.0);
    }

    #[test]
    fn nodes_run_independently() {
        let tasks = vec![
            ReplayTask { node: 0, duration: 5.0 },
            ReplayTask { node: 1, duration: 1.0 },
            ReplayTask { node: 1, duration: 1.0 },
        ];
        assert_eq!(stage_makespan(&tasks, 1), 5.0);
    }

    #[test]
    fn empty_stage_is_zero() {
        assert_eq!(stage_makespan(&[], 4), 0.0);
        assert_eq!(job_completion(&[], 4), 0.0);
    }

    #[test]
    fn job_completion_sums_stage_barriers() {
        let stages = vec![
            ReplayStage { stage_id: 0, tasks: vec![ReplayTask { node: 0, duration: 2.0 }] },
            ReplayStage {
                stage_id: 1,
                tasks: vec![
                    ReplayTask { node: 0, duration: 1.0 },
                    ReplayTask { node: 1, duration: 4.0 },
                ],
            },
        ];
        assert_eq!(job_completion(&stages, 2), 6.0);
    }

    #[test]
    fn shrinking_a_task_never_grows_a_single_stage_much() {
        // Replay the same stage with one straggler shortened: the makespan
        // must not increase (greedy keeps assignment order fixed).
        let tasks: Vec<ReplayTask> = (0..40)
            .map(|i| ReplayTask { node: i % 4, duration: 1.0 + (i == 13) as usize as f64 * 9.0 })
            .collect();
        let base = stage_makespan(&tasks, 3);
        let mut fixed = tasks.clone();
        fixed[13].duration = 1.0;
        assert!(stage_makespan(&fixed, 3) <= base);
    }

    #[test]
    fn replay_of_a_real_trace_is_deterministic() {
        let w = workloads::wordcount(0.3);
        let mut eng = Engine::new(SimConfig { seed: 9, ..Default::default() });
        let t = eng.run("replay-det", w.name, &w.stages, &InjectionPlan::none());
        let slots = infer_slots_per_node(&t);
        assert!(slots >= 1);
        let s1 = stages_from_trace(&t);
        let s2 = stages_from_trace(&t);
        assert_eq!(s1, s2);
        let c1 = job_completion(&s1, slots);
        let c2 = job_completion(&s2, slots);
        assert_eq!(c1.to_bits(), c2.to_bits(), "replay must be bit-identical");
        assert!(c1 > 0.0);
    }

    #[test]
    fn inferred_slots_bounded_by_config() {
        let w = workloads::wordcount(0.3);
        let cfg = SimConfig { seed: 10, ..Default::default() };
        let slots_cfg = cfg.slots;
        let mut eng = Engine::new(cfg);
        let t = eng.run("replay-slots", w.name, &w.stages, &InjectionPlan::none());
        let got = infer_slots_per_node(&t);
        assert!(got >= 1 && got <= slots_cfg, "inferred {got}, config {slots_cfg}");
    }
}
