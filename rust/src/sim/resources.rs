//! Per-node shared-resource model with weighted max-min fair sharing.
//!
//! Each node owns three resources — CPU (capacity = cores), disk (bytes/s)
//! and network (bytes/s). Active *users* (task phases, anomaly-generator hog
//! processes, OS background noise) register a weight and a desired rate;
//! the model computes each user's granted rate by weighted max-min fairness
//! and the node's resulting utilization. Rates are piecewise-constant
//! between simulator events; the utilization timeline is recorded on every
//! change and later integrated into 1 Hz samples by [`super::sampler`].
//!
//! This is the substitution for the paper's real Xeon cluster: co-located
//! load slows tasks through *exactly* the shared-capacity mechanism that
//! makes the paper's hog processes create stragglers.

use crate::trace::AnomalyKind;

/// Resource dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Res {
    Cpu,
    Disk,
    Net,
}

impl Res {
    pub fn from_anomaly(kind: AnomalyKind) -> Res {
        match kind {
            AnomalyKind::Cpu => Res::Cpu,
            AnomalyKind::Io => Res::Disk,
            AnomalyKind::Network => Res::Net,
        }
    }
}

/// A registered consumer of one resource on one node.
#[derive(Debug, Clone)]
struct User {
    id: u64,
    weight: f64,
    /// Max rate this user can consume (cores for CPU, bytes/s otherwise).
    desired: f64,
    /// Granted rate after the last rebalance.
    rate: f64,
}

/// One (time, utilization) change-point; utilization holds until the next.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UtilPoint {
    pub time: f64,
    /// CPU/disk: fraction of capacity in [0,1]. Net: absolute bytes/s.
    pub value: f64,
}

/// One resource on one node.
#[derive(Debug, Clone)]
pub struct Resource {
    pub kind: Res,
    pub capacity: f64,
    users: Vec<User>,
    /// Recorded piecewise-constant utilization timeline.
    pub timeline: Vec<UtilPoint>,
}

impl Resource {
    pub fn new(kind: Res, capacity: f64) -> Self {
        assert!(capacity > 0.0);
        Resource { kind, capacity, users: Vec::new(), timeline: vec![UtilPoint { time: 0.0, value: 0.0 }] }
    }

    /// Register a user; returns nothing — caller tracks ids. Rebalances.
    pub fn add_user(&mut self, now: f64, id: u64, weight: f64, desired: f64) {
        debug_assert!(weight > 0.0 && desired >= 0.0);
        self.users.push(User { id, weight, desired, rate: 0.0 });
        self.rebalance(now);
    }

    /// Remove a user by id (no-op if absent). Rebalances.
    pub fn remove_user(&mut self, now: f64, id: u64) {
        self.users.retain(|u| u.id != id);
        self.rebalance(now);
    }

    /// Change a user's desired rate (e.g. noise fluctuation). Rebalances.
    pub fn set_desired(&mut self, now: f64, id: u64, desired: f64) {
        if let Some(u) = self.users.iter_mut().find(|u| u.id == id) {
            u.desired = desired;
            self.rebalance(now);
        }
    }

    /// Granted rate for a user (0.0 if unknown).
    pub fn rate_of(&self, id: u64) -> f64 {
        self.users.iter().find(|u| u.id == id).map(|u| u.rate).unwrap_or(0.0)
    }

    /// Current total granted rate.
    pub fn total_rate(&self) -> f64 {
        self.users.iter().map(|u| u.rate).sum()
    }

    /// Current utilization: fraction of capacity for CPU/disk, absolute
    /// bytes/s for network (Eq. 3 uses absolute traffic).
    pub fn utilization(&self) -> f64 {
        match self.kind {
            Res::Net => self.total_rate(),
            _ => (self.total_rate() / self.capacity).min(1.0),
        }
    }

    /// Weighted max-min fair allocation:
    /// repeatedly give each unfrozen user `capacity_left * w_i / W_unfrozen`,
    /// freezing users whose desired rate is below their share.
    fn rebalance(&mut self, now: f64) {
        let n = self.users.len();
        let mut frozen = vec![false; n];
        let mut rates = vec![0.0f64; n];
        let mut cap_left = self.capacity;
        loop {
            let active: Vec<usize> = (0..n).filter(|&i| !frozen[i]).collect();
            if active.is_empty() || cap_left <= 1e-12 {
                break;
            }
            let w_total: f64 = active.iter().map(|&i| self.users[i].weight).sum();
            let mut any_frozen = false;
            for &i in &active {
                let share = cap_left * self.users[i].weight / w_total;
                if self.users[i].desired <= share + 1e-12 {
                    rates[i] = self.users[i].desired;
                    frozen[i] = true;
                    any_frozen = true;
                }
            }
            if !any_frozen {
                // All remaining users are bottlenecked: give exact shares.
                for &i in &active {
                    rates[i] = cap_left * self.users[i].weight / w_total;
                    frozen[i] = true;
                }
                break;
            }
            cap_left = self.capacity - rates.iter().sum::<f64>();
        }
        for (i, u) in self.users.iter_mut().enumerate() {
            u.rate = rates[i];
        }
        self.record(now);
    }

    fn record(&mut self, now: f64) {
        let v = self.utilization();
        match self.timeline.last_mut() {
            Some(last) if (last.time - now).abs() < 1e-12 => last.value = v,
            Some(last) if (last.value - v).abs() < 1e-15 => {} // no change
            _ => self.timeline.push(UtilPoint { time: now, value: v }),
        }
    }

    /// Integrate the piecewise-constant timeline into fixed-period buckets
    /// covering [0, horizon). Bucket k = mean value over [k·p, (k+1)·p).
    pub fn bucketize(&self, period: f64, horizon: f64) -> Vec<f64> {
        let n = (horizon / period).ceil().max(0.0) as usize;
        let mut out = vec![0.0f64; n];
        if n == 0 {
            return out;
        }
        // Walk segments [t_i, t_{i+1}) with value v_i.
        for (i, pt) in self.timeline.iter().enumerate() {
            let seg_start = pt.time;
            let seg_end = self
                .timeline
                .get(i + 1)
                .map(|p| p.time)
                .unwrap_or(horizon)
                .min(horizon);
            if seg_end <= seg_start {
                continue;
            }
            let first = (seg_start / period).floor() as usize;
            let last = ((seg_end / period).ceil() as usize).min(n);
            for b in first..last {
                let b0 = b as f64 * period;
                let b1 = b0 + period;
                let overlap = (seg_end.min(b1) - seg_start.max(b0)).max(0.0);
                out[b] += pt.value * overlap / period;
            }
        }
        out
    }
}

/// All three resources of one node.
#[derive(Debug, Clone)]
pub struct NodeResources {
    pub node: usize,
    pub cpu: Resource,
    pub disk: Resource,
    pub net: Resource,
}

impl NodeResources {
    pub fn new(node: usize, cores: f64, disk_bw: f64, net_bw: f64) -> Self {
        NodeResources {
            node,
            cpu: Resource::new(Res::Cpu, cores),
            disk: Resource::new(Res::Disk, disk_bw),
            net: Resource::new(Res::Net, net_bw),
        }
    }

    pub fn get(&self, r: Res) -> &Resource {
        match r {
            Res::Cpu => &self.cpu,
            Res::Disk => &self.disk,
            Res::Net => &self.net,
        }
    }

    pub fn get_mut(&mut self, r: Res) -> &mut Resource {
        match r {
            Res::Cpu => &mut self.cpu,
            Res::Disk => &mut self.disk,
            Res::Net => &mut self.net,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_user_gets_desired_when_undersubscribed() {
        let mut r = Resource::new(Res::Disk, 100.0);
        r.add_user(0.0, 1, 1.0, 30.0);
        assert!((r.rate_of(1) - 30.0).abs() < 1e-9);
        assert!((r.utilization() - 0.3).abs() < 1e-9);
    }

    #[test]
    fn equal_weights_split_when_saturated() {
        let mut r = Resource::new(Res::Disk, 100.0);
        r.add_user(0.0, 1, 1.0, 100.0);
        r.add_user(0.0, 2, 1.0, 100.0);
        assert!((r.rate_of(1) - 50.0).abs() < 1e-9);
        assert!((r.rate_of(2) - 50.0).abs() < 1e-9);
        assert!((r.utilization() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn weighted_shares() {
        let mut r = Resource::new(Res::Disk, 90.0);
        r.add_user(0.0, 1, 1.0, 1000.0);
        r.add_user(0.0, 2, 2.0, 1000.0);
        assert!((r.rate_of(1) - 30.0).abs() < 1e-9);
        assert!((r.rate_of(2) - 60.0).abs() < 1e-9);
    }

    #[test]
    fn maxmin_redistributes_slack() {
        // User 1 wants only 10 of 100; user 2 gets the remaining 90.
        let mut r = Resource::new(Res::Disk, 100.0);
        r.add_user(0.0, 1, 1.0, 10.0);
        r.add_user(0.0, 2, 1.0, 1000.0);
        assert!((r.rate_of(1) - 10.0).abs() < 1e-9);
        assert!((r.rate_of(2) - 90.0).abs() < 1e-9);
    }

    #[test]
    fn remove_user_rebalances() {
        let mut r = Resource::new(Res::Cpu, 16.0);
        r.add_user(0.0, 1, 1.0, 16.0);
        r.add_user(1.0, 2, 1.0, 16.0);
        assert!((r.rate_of(1) - 8.0).abs() < 1e-9);
        r.remove_user(2.0, 2);
        assert!((r.rate_of(1) - 16.0).abs() < 1e-9);
        assert_eq!(r.rate_of(2), 0.0);
    }

    #[test]
    fn net_utilization_is_absolute() {
        let mut r = Resource::new(Res::Net, 125e6);
        r.add_user(0.0, 1, 1.0, 10e6);
        assert!((r.utilization() - 10e6).abs() < 1.0);
    }

    #[test]
    fn timeline_records_changes() {
        let mut r = Resource::new(Res::Cpu, 4.0);
        r.add_user(1.0, 1, 1.0, 2.0); // util 0.5 at t=1
        r.add_user(3.0, 2, 1.0, 2.0); // util 1.0 at t=3
        r.remove_user(5.0, 1); // util 0.5 at t=5
        let tl = &r.timeline;
        assert_eq!(tl[0], UtilPoint { time: 0.0, value: 0.0 });
        assert!(tl.contains(&UtilPoint { time: 1.0, value: 0.5 }));
        assert!(tl.contains(&UtilPoint { time: 3.0, value: 1.0 }));
        assert!(tl.contains(&UtilPoint { time: 5.0, value: 0.5 }));
    }

    #[test]
    fn bucketize_integrates_exactly() {
        let mut r = Resource::new(Res::Cpu, 1.0);
        // util: 0.0 on [0,1), 1.0 on [1,2), 0.5 on [2,4)
        r.add_user(1.0, 1, 1.0, 1.0);
        r.set_desired(2.0, 1, 0.5);
        let buckets = r.bucketize(1.0, 4.0);
        assert_eq!(buckets.len(), 4);
        assert!((buckets[0] - 0.0).abs() < 1e-9);
        assert!((buckets[1] - 1.0).abs() < 1e-9);
        assert!((buckets[2] - 0.5).abs() < 1e-9);
        assert!((buckets[3] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn bucketize_partial_segment() {
        let mut r = Resource::new(Res::Cpu, 1.0);
        r.add_user(0.5, 1, 1.0, 1.0); // util 1.0 from t=0.5
        let buckets = r.bucketize(1.0, 2.0);
        assert!((buckets[0] - 0.5).abs() < 1e-9); // half the bucket at 1.0
        assert!((buckets[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ag_hog_starves_task_share() {
        // A task wanting 1 core competes with an 8-process CPU AG on a
        // 16-core node that is also running 15 other tasks: demand 24 > 16.
        let mut r = Resource::new(Res::Cpu, 16.0);
        for i in 0..16 {
            r.add_user(0.0, i, 1.0, 1.0);
        }
        // All fit exactly: each gets 1.0.
        assert!((r.rate_of(0) - 1.0).abs() < 1e-9);
        // AG arrives: 8 more single-core hogs.
        for i in 100..108 {
            r.add_user(1.0, i, 1.0, 1.0);
        }
        let rate = r.rate_of(0);
        assert!(rate < 1.0 - 1e-9, "task should be slowed, rate={rate}");
        assert!((r.utilization() - 1.0).abs() < 1e-9);
    }
}
