//! Discrete-event machinery: a time-ordered event queue with deterministic
//! tie-breaking (insertion sequence), the foundation of the fluid-flow
//! cluster simulator in [`super::engine`].

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Heap entry: earliest time first; FIFO among equal times.
struct Entry<E> {
    time: f64,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// A deterministic discrete-event queue.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: f64,
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: 0.0 }
    }

    /// Current simulation time (the time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `payload` at absolute time `time`. Scheduling in the past
    /// clamps to `now` (fluid-model rate changes can produce tiny negative
    /// deltas from floating-point error).
    pub fn schedule(&mut self, time: f64, payload: E) {
        let time = if time < self.now { self.now } else { time };
        self.seq += 1;
        self.heap.push(Entry { time, seq: self.seq, payload });
    }

    /// Schedule at `now + delay`.
    pub fn schedule_in(&mut self, delay: f64, payload: E) {
        self.schedule(self.now + delay.max(0.0), payload);
    }

    /// Pop the next event, advancing `now`.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|e| {
            debug_assert!(e.time >= self.now - 1e-9);
            self.now = self.now.max(e.time);
            (self.now, e.payload)
        })
    }

    /// Time of the next event without popping.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (2.0, "b"));
        assert_eq!(q.pop().unwrap(), (3.0, "c"));
        assert!(q.pop().is_none());
    }

    #[test]
    fn fifo_among_ties() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn now_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule(5.0, ());
        q.schedule(2.0, ());
        let (t1, _) = q.pop().unwrap();
        let (t2, _) = q.pop().unwrap();
        assert!(t1 <= t2);
        assert_eq!(q.now(), 5.0);
    }

    #[test]
    fn past_scheduling_clamps_to_now() {
        let mut q = EventQueue::new();
        q.schedule(10.0, "late");
        q.pop();
        q.schedule(1.0, "past"); // clamped to now=10
        let (t, p) = q.pop().unwrap();
        assert_eq!(t, 10.0);
        assert_eq!(p, "past");
    }

    #[test]
    fn schedule_in_uses_now() {
        let mut q = EventQueue::new();
        q.schedule(4.0, "first");
        q.pop();
        q.schedule_in(1.5, "second");
        assert_eq!(q.pop().unwrap(), (5.5, "second"));
    }
}
