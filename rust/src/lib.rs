//! # BigRoots — root-cause analysis of stragglers in big data systems
//!
//! A full reproduction of *"BigRoots: An Effective Approach for Root-cause
//! Analysis of Stragglers in Big Data System"* (Zhou, Li, Yang, Jia, Li;
//! 2018) as a Rust + JAX + Pallas three-layer stack:
//!
//! - **L3 (this crate)** — the coordinator: a discrete-event Spark-like
//!   cluster simulator substrate ([`sim`]), the trace model ([`trace`]), the
//!   BigRoots analyzer and PCC baseline ([`analysis`]), a PJRT runtime that
//!   executes the AOT-compiled stats kernel ([`runtime`]), and the pipeline
//!   that ties them together ([`coordinator`]).
//! - **L2 (python/compile/model.py)** — the batched per-stage feature
//!   statistics graph in JAX, lowered once to HLO text.
//! - **L1 (python/compile/kernels/)** — Pallas kernels for the fused
//!   moments/Pearson reduction and edge-detection window means.
//!
//! Python never runs at analysis time: `make artifacts` AOT-compiles the
//! L1/L2 stack, and the rust binary loads `artifacts/*.hlo.txt` via PJRT.
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod analysis;
pub mod coordinator;
pub mod runtime;
pub mod sim;
pub mod testing;
pub mod trace;
pub mod util;
