//! # BigRoots — root-cause analysis of stragglers in big data systems
//!
//! A full reproduction of *"BigRoots: An Effective Approach for Root-cause
//! Analysis of Stragglers in Big Data System"* (Zhou, Li, Yang, Jia, Li;
//! 2018) as a Rust + JAX + Pallas three-layer stack:
//!
//! - **L3 (this crate)** — the coordinator: a discrete-event Spark-like
//!   cluster simulator substrate ([`sim`]), the trace model ([`trace`]), the
//!   BigRoots analyzer and PCC baseline ([`analysis`]), a PJRT runtime that
//!   executes the AOT-compiled stats kernel ([`runtime`]), and the pipeline
//!   that ties them together ([`coordinator`]).
//!
//! Two analysis front-ends share the analyzer core:
//!
//! - the offline batch [`coordinator::Pipeline`] (whole trace in, report
//!   out), and
//! - the **multi-job streaming [`coordinator::AnalysisService`]**: an
//!   interleaved, job-tagged event stream
//!   ([`trace::eventlog::TaggedEvent`]) is demultiplexed onto per-job
//!   [`coordinator::streaming::JobState`] accumulators grouped into
//!   shards; stage analyses are batched onto a
//!   [`util::threadpool::ThreadPool`] of workers (one
//!   [`analysis::stats::StatsBackend`] each, dispatched through
//!   `stage_stats_batch`), with backpressure on ingest and per-job /
//!   per-shard throughput metrics. A per-node sample watermark defers each
//!   stage until its edge windows are covered, so streaming results are
//!   bit-identical to the batch pipeline — the parity, determinism and
//!   interleaving-invariance tests live in `rust/tests/`.
//!   `examples/multi_job_service.rs` drives it; [`sim::multi`] generates
//!   interleaved multi-job traffic.
//! - the **live multi-tenant control plane [`live::LiveServer`]**
//!   (sources → sharded ingest → analysis/routing → registry/persistence
//!   → control plane): pluggable transports ([`live::source`] — NDJSON
//!   file tail with rotation detection, TCP listener that counts mid-line
//!   disconnect losses, stdin) feed one worker thread per shard over
//!   bounded queues ([`util::queue`], per-shard backpressure); a job
//!   lifecycle manager ([`live::lifecycle`]) flushes and evicts
//!   `JobState` after `JobEnd` plus a quiescence window (bounded memory
//!   on unbounded streams, revived job ids are fresh incarnations); shard
//!   workers compute through a [`analysis::router::RoutingBackend`]
//!   (native for small stages, XLA-capable for large) memoized by one
//!   lock-striped [`analysis::cache::SharedStatsCache`] (a repeated stage
//!   shape hits across shards); a cross-job
//!   [`live::registry::FleetRegistry`] folds every completed stage into
//!   P² quantile sketches and root-cause incidence counters, answering
//!   fleet queries and flagging stages anomalous versus the fleet
//!   baseline — and **survives restarts** through versioned, bit-exact,
//!   atomically-written snapshots ([`live::persist`], restore-on-boot);
//!   a line-delimited TCP **control socket** ([`live::control`]:
//!   `fleet-report`, `jobs` with cause/confidence/time filters and a
//!   keyset cursor, `job <id>`, `explain <id> [dump <path>]`,
//!   `what-if <id>`, `metrics`, `metrics-prom`, `self-report`,
//!   `snapshot`, `shutdown`) shares one query path with the CLI's
//!   periodic snapshot printing and gives `bigroots serve` a clean
//!   drain-then-snapshot shutdown.
//!   `bigroots serve --tail/--listen --control-port --snapshot-path`,
//!   `examples/live_tail.rs` and `examples/control_client.rs` drive it
//!   end to end.
//! - the **verdict provenance layer** ([`analysis::explain`] +
//!   [`obs::flight`]): every flagged task/cause pair carries the feature
//!   value, the Eq. 5 threshold it crossed, the stage median/MAD
//!   baseline, its percentile against the fleet baseline and an
//!   effect-size-derived confidence in `[0, 1]`, with co-occurring
//!   causes grouped; a bounded per-shard flight recorder freezes the
//!   implicated job's raw event window when a straggler verdict fires,
//!   and the `explain <id> dump <path>` NDJSON dump replays offline
//!   through `bigroots explain --replay` to the recorded verdict
//!   bit-identically. Per-cause confidence aggregates persist in
//!   snapshot v3 and export as `bigroots_verdicts_total{cause}`. See
//!   `docs/EXPLAIN.md`.
//! - the **counterfactual what-if engine** ([`analysis::whatif`] over
//!   the deterministic replay scheduler [`sim::replay`]): every detected
//!   cause is neutralized in turn (GC zeroed, bytes normalized to the
//!   benign target, slow node swapped to fleet-median speed, remote
//!   reads localized) and the job replayed, ranking causes by estimated
//!   completion time saved — bit-identical given `(trace, seed)`.
//!   Surfaced as the `what-if <id>` control verb, the `bigroots whatif`
//!   offline subcommand, a ranked `estimated_savings` column in the
//!   fleet report (persisted in snapshot v2), and the mitigation picker
//!   in `examples/mitigation.rs`. See `docs/WHATIF.md`.
//!
//! The event→feature→stats **hot path** is allocation-free and
//! cache-aware end to end:
//!
//! - [`trace::codec::decode_event_line`] — a zero-allocation
//!   borrowed-token NDJSON decoder (no `Json` DOM per line); every stream
//!   reader ([`trace::eventlog::NdjsonTail`], the live [`live::source`]
//!   transports, `parse_events`/`parse_tagged_events`, the threaded
//!   stream analyzer) routes through it, with property-tested parity
//!   against the generic parser;
//! - [`analysis::stats::StatsScratch`] — each worker's
//!   [`analysis::stats::NativeBackend`] reuses its intermediate buffers
//!   across stages, resolves node slots through a hash map, and reads the
//!   quantile grid via `select_nth_unstable_by` multi-selection instead
//!   of a full per-column sort (NaN-safe `total_cmp` throughout);
//! - [`analysis::cache::CachedBackend`] — an LRU stage-stats memoizer
//!   keyed on a structural hash of the feature matrix, wired into the
//!   service workers, the live shard workers and the offline pipeline;
//!   hit/miss counters surface in service and fleet metrics. Job → shard
//!   routing uses rendezvous hashing ([`util::shard`]), so skewed tenant
//!   id schemes spread evenly. `benches/hotpath.rs` tracks decode-only,
//!   stats-only and end-to-end events/sec in `BENCH_hotpath.json`.
//! - [`trace::wire`] — the **compact binary event wire format**: a
//!   length-prefixed frame per event (fixed-width LE ids and raw
//!   `f64::to_bits` floats, varint-prefixed strings, per-frame kind tag,
//!   `BGRW` magic + version header with a tagged/untagged flag), with a
//!   bit-identical `Event` round-trip and an [`trace::wire::EventCodec`]
//!   seam shared by the NDJSON and binary paths. On replay the parser
//!   disappears entirely: [`live::MmapReplaySource`] maps a `.bew`
//!   capture (raw `mmap(2)`, heap-read fallback) and decodes frames
//!   straight off the mapped pages, [`live::BinaryTailSource`] follows a
//!   growing capture with partial-frame resync and rotation detection,
//!   and `bigroots convert` streams between encodings (`--format`
//!   plumbs through `serve`/`explain`/`whatif`). Round-trip, NaN-bit
//!   and corruption properties live in `rust/tests/wire_roundtrip.rs`;
//!   `rust/tests/wire_integration.rs` pins FleetReport equality between
//!   NDJSON and binary ingest. See `docs/WIRE_FORMAT.md`.
//! - [`trace::batch::EventBatch`] — the **batched columnar ingest
//!   path**: events cross the shard queues as struct-of-arrays batches
//!   (one shared string arena per batch, f64 payloads as raw bits), one
//!   lock acquisition and one condvar signal per batch instead of per
//!   event, with drained batches recycled through a per-shard free
//!   list. Routing is amortized by run-length demux (one rendezvous
//!   hash per same-job run) and workers self-tick lifecycle scans via
//!   [`util::queue`]'s `pop_timeout`. [`live::MmapReplaySource`] can
//!   decode a capture across the in-tree thread pool
//!   (`--decode-threads`): `wire::partition_frames` cuts frame-aligned
//!   byte ranges whose in-order concatenation is bit-identical to the
//!   sequential walk. `rust/tests/batch_parity.rs` and
//!   `examples/batch_parity.rs` pin FleetReport equality across any
//!   chunking and any thread count. See `docs/BATCHING.md`.
//! - **L2 (python/compile/model.py)** — the batched per-stage feature
//!   statistics graph in JAX, lowered once to HLO text.
//! - **L1 (python/compile/kernels/)** — Pallas kernels for the fused
//!   moments/Pearson reduction and edge-detection window means.
//!
//! The server watches itself through the **self-observability layer**
//! ([`obs`]): every pipeline phase — source poll, decode, enqueue/dequeue
//! wait, stats kernel, cache lookup, registry fold, control handling,
//! snapshot writes — is timed into lock-free sharded log2 histograms
//! ([`obs::hist`]) behind a near-zero-cost disabled flag; diagnostics go
//! through a leveled, rate-limited structured logger ([`obs::log`],
//! `--log-level`/`--log-json`); counters, histograms and P²-sketch
//! quantiles are exported as Prometheus text ([`obs::prom`]) via the
//! `metrics-prom` control verb and a `--metrics-port` HTTP endpoint; and
//! `serve --self-analyze` feeds the server's own per-shard batch timings
//! back through the [`coordinator::AnalysisService`] ([`obs::selfmon`]),
//! so BigRoots diagnoses its own stragglers (queue wait vs. stats kernel
//! vs. cache misses). `docs/OBSERVABILITY.md` catalogs the metrics;
//! `benches/table7_overhead.rs` measures the enabled-vs-disabled cost.
//!
//! Python never runs at analysis time: `make artifacts` AOT-compiles the
//! L1/L2 stack, and the rust binary loads `artifacts/*.hlo.txt` via PJRT.
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod analysis;
pub mod coordinator;
pub mod live;
pub mod obs;
pub mod runtime;
pub mod sim;
pub mod testing;
pub mod trace;
pub mod util;
