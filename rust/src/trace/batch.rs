//! `EventBatch` — the struct-of-arrays container the batched ingest path
//! moves through queues instead of one `TaggedEvent` at a time.
//!
//! A batch holds its events columnar: one `u64` job column, one kind-tag
//! column (the wire format's frame tags), one packed `u64` column for
//! ids/counts/enum tags, one packed `u64` column for `f64` raw bits, and
//! a **single shared string arena** for every job/stage name in the
//! batch — so a drained batch is five `Vec`s and a `String`, and
//! [`EventBatch::clear`] keeps all six allocations for reuse. That is
//! what lets the live server recycle batch buffers through a free-list
//! and ingest steady-state without allocating (see docs/BATCHING.md for
//! the ownership rules).
//!
//! Per-kind column arity is fixed (the same layout discipline as
//! `trace/wire.rs` frames), so no per-event offset tables are stored:
//! [`EventBatch::iter`] walks the columns with running cursors. Floats
//! are stored as raw bits, so NaN payloads, ±inf and -0.0 survive the
//! round-trip bit-identically — `from_events` → `iter` is lossless by
//! construction, which is what keeps batched ingest results bit-identical
//! to the per-event path.

use crate::trace::eventlog::{Event, TaggedEvent};
use crate::trace::model::{ClusterInfo, InjectionRecord, TaskRecord};
use crate::trace::wire;

/// A columnar batch of job-tagged events. See module docs.
#[derive(Debug, Default, Clone)]
pub struct EventBatch {
    /// Per-event job id (the demux key; runs of equal ids are routed once).
    jobs: Vec<u64>,
    /// Per-event kind tag (`trace/wire.rs` frame tags).
    kinds: Vec<u8>,
    /// Packed ids / counts / enum tags, fixed arity per kind.
    ints: Vec<u64>,
    /// Packed `f64::to_bits` payloads, fixed arity per kind.
    bits: Vec<u64>,
    /// One shared arena for every string in the batch.
    arena: String,
    /// (start, end) byte spans into `arena`, in consumption order.
    spans: Vec<(u32, u32)>,
}

impl EventBatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// A batch with room for roughly `events` task-shaped events before
    /// the columns reallocate.
    pub fn with_capacity(events: usize) -> Self {
        EventBatch {
            jobs: Vec::with_capacity(events),
            kinds: Vec::with_capacity(events),
            ints: Vec::with_capacity(events * 5),
            bits: Vec::with_capacity(events * 4),
            arena: String::new(),
            spans: Vec::new(),
        }
    }

    /// Events in the batch.
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// The job-id column — what the router's run-length demux scans.
    pub fn jobs(&self) -> &[u64] {
        &self.jobs
    }

    /// Forget the contents, keep every allocation. A cleared batch pushed
    /// through the free-list pool makes the next fill allocation-free.
    pub fn clear(&mut self) {
        self.jobs.clear();
        self.kinds.clear();
        self.ints.clear();
        self.bits.clear();
        self.arena.clear();
        self.spans.clear();
    }

    fn push_str(&mut self, s: &str) {
        let start = self.arena.len() as u32;
        self.arena.push_str(s);
        self.spans.push((start, self.arena.len() as u32));
    }

    /// Append one event. Column writes mirror [`EventBatch::iter`]'s
    /// reads exactly — the per-kind order below is the layout contract.
    pub fn push(&mut self, ev: &TaggedEvent) {
        self.jobs.push(ev.job_id);
        match &ev.event {
            Event::JobStart { job_name, workload, cluster } => {
                self.kinds.push(wire::K_JOB_START);
                self.push_str(job_name);
                self.push_str(workload);
                self.ints.push(cluster.nodes as u64);
                self.ints.push(cluster.cores_per_node as u64);
                self.ints.push(cluster.executors_per_node as u64);
            }
            Event::StageSubmitted { stage_id, name, num_tasks } => {
                self.kinds.push(wire::K_STAGE_SUBMITTED);
                self.push_str(name);
                self.ints.push(*stage_id);
                self.ints.push(*num_tasks as u64);
            }
            Event::TaskStart { task_id, stage_id, node, executor, time, locality } => {
                self.kinds.push(wire::K_TASK_START);
                self.ints.push(*task_id);
                self.ints.push(*stage_id);
                self.ints.push(*node as u64);
                self.ints.push(*executor as u64);
                self.ints.push(wire::locality_tag(*locality) as u64);
                self.bits.push(time.to_bits());
            }
            Event::TaskEnd(t) => {
                self.kinds.push(wire::K_TASK_END);
                self.ints.push(t.task_id);
                self.ints.push(t.stage_id);
                self.ints.push(t.node as u64);
                self.ints.push(t.executor as u64);
                self.ints.push(wire::locality_tag(t.locality) as u64);
                self.bits.push(t.start.to_bits());
                self.bits.push(t.finish.to_bits());
                self.bits.push(t.bytes_read.to_bits());
                self.bits.push(t.shuffle_read_bytes.to_bits());
                self.bits.push(t.shuffle_write_bytes.to_bits());
                self.bits.push(t.memory_bytes_spilled.to_bits());
                self.bits.push(t.disk_bytes_spilled.to_bits());
                self.bits.push(t.jvm_gc_time.to_bits());
                self.bits.push(t.serialize_time.to_bits());
                self.bits.push(t.deserialize_time.to_bits());
            }
            Event::ResourceSample { node, time, cpu, disk, net_bytes } => {
                self.kinds.push(wire::K_RESOURCE_SAMPLE);
                self.ints.push(*node as u64);
                self.bits.push(time.to_bits());
                self.bits.push(cpu.to_bits());
                self.bits.push(disk.to_bits());
                self.bits.push(net_bytes.to_bits());
            }
            Event::Injection(inj) => {
                self.kinds.push(wire::K_INJECTION);
                self.ints.push(inj.node as u64);
                self.ints.push(wire::anomaly_tag(inj.kind) as u64);
                self.bits.push(inj.t_start.to_bits());
                self.bits.push(inj.t_end.to_bits());
            }
            Event::JobEnd { time } => {
                self.kinds.push(wire::K_JOB_END);
                self.bits.push(time.to_bits());
            }
        }
    }

    /// Build a batch from a slice of events (the adapter existing
    /// consumers use; the live sources fill batches directly).
    pub fn from_events(events: &[TaggedEvent]) -> Self {
        let mut b = EventBatch::with_capacity(events.len());
        for e in events {
            b.push(e);
        }
        b
    }

    /// Walk the batch, reconstructing each event. Most kinds rebuild
    /// without touching the heap; only the two named kinds (`JobStart`,
    /// `StageSubmitted` — a tiny fraction of real traffic) copy their
    /// strings out of the arena.
    pub fn iter(&self) -> EventBatchIter<'_> {
        EventBatchIter { batch: self, idx: 0, int_i: 0, bit_i: 0, str_i: 0 }
    }

    /// The whole batch as owned events (test/adapter convenience).
    pub fn to_events(&self) -> Vec<TaggedEvent> {
        self.iter().collect()
    }
}

/// Cursor-walking iterator over an [`EventBatch`]. The per-kind read
/// order mirrors [`EventBatch::push`] — that pairing is the only place
/// the column layout exists.
pub struct EventBatchIter<'a> {
    batch: &'a EventBatch,
    idx: usize,
    int_i: usize,
    bit_i: usize,
    str_i: usize,
}

impl EventBatchIter<'_> {
    fn int(&mut self) -> u64 {
        let v = self.batch.ints[self.int_i];
        self.int_i += 1;
        v
    }

    fn f(&mut self) -> f64 {
        let v = f64::from_bits(self.batch.bits[self.bit_i]);
        self.bit_i += 1;
        v
    }

    fn s(&mut self) -> String {
        let (start, end) = self.batch.spans[self.str_i];
        self.str_i += 1;
        self.batch.arena[start as usize..end as usize].to_string()
    }
}

impl Iterator for EventBatchIter<'_> {
    type Item = TaggedEvent;

    fn next(&mut self) -> Option<TaggedEvent> {
        if self.idx >= self.batch.len() {
            return None;
        }
        let job_id = self.batch.jobs[self.idx];
        let kind = self.batch.kinds[self.idx];
        self.idx += 1;
        let event = match kind {
            wire::K_JOB_START => {
                let job_name = self.s();
                let workload = self.s();
                Event::JobStart {
                    job_name,
                    workload,
                    cluster: ClusterInfo {
                        nodes: self.int() as usize,
                        cores_per_node: self.int() as usize,
                        executors_per_node: self.int() as usize,
                    },
                }
            }
            wire::K_STAGE_SUBMITTED => {
                let name = self.s();
                Event::StageSubmitted {
                    stage_id: self.int(),
                    name,
                    num_tasks: self.int() as usize,
                }
            }
            wire::K_TASK_START => Event::TaskStart {
                task_id: self.int(),
                stage_id: self.int(),
                node: self.int() as usize,
                executor: self.int() as usize,
                locality: wire::locality_from_tag(self.int() as u8)
                    .expect("EventBatch wrote a valid locality tag"),
                time: self.f(),
            },
            wire::K_TASK_END => Event::TaskEnd(TaskRecord {
                task_id: self.int(),
                stage_id: self.int(),
                node: self.int() as usize,
                executor: self.int() as usize,
                locality: wire::locality_from_tag(self.int() as u8)
                    .expect("EventBatch wrote a valid locality tag"),
                start: self.f(),
                finish: self.f(),
                bytes_read: self.f(),
                shuffle_read_bytes: self.f(),
                shuffle_write_bytes: self.f(),
                memory_bytes_spilled: self.f(),
                disk_bytes_spilled: self.f(),
                jvm_gc_time: self.f(),
                serialize_time: self.f(),
                deserialize_time: self.f(),
            }),
            wire::K_RESOURCE_SAMPLE => Event::ResourceSample {
                node: self.int() as usize,
                time: self.f(),
                cpu: self.f(),
                disk: self.f(),
                net_bytes: self.f(),
            },
            wire::K_INJECTION => Event::Injection(InjectionRecord {
                node: self.int() as usize,
                kind: wire::anomaly_from_tag(self.int() as u8)
                    .expect("EventBatch wrote a valid anomaly tag"),
                t_start: self.f(),
                t_end: self.f(),
            }),
            wire::K_JOB_END => Event::JobEnd { time: self.f() },
            other => unreachable!("corrupt EventBatch kind tag {other}"),
        };
        Some(TaggedEvent { job_id, event })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::multi::{interleaved_workload, round_robin_specs};
    use crate::trace::model::Locality;

    fn sample_events() -> Vec<TaggedEvent> {
        let (_, events) = interleaved_workload(&round_robin_specs(3, 0.08, 21));
        events
    }

    #[test]
    fn roundtrip_is_lossless() {
        let events = sample_events();
        let batch = EventBatch::from_events(&events);
        assert_eq!(batch.len(), events.len());
        assert_eq!(batch.to_events(), events);
        assert_eq!(batch.jobs(), events.iter().map(|e| e.job_id).collect::<Vec<_>>());
    }

    #[test]
    fn float_bit_patterns_survive() {
        let v = f64::from_bits(0x7ff8_dead_beef_0001); // NaN with payload
        let ev = TaggedEvent {
            job_id: 3,
            event: Event::TaskEnd(TaskRecord {
                task_id: 1,
                stage_id: 2,
                node: 0,
                executor: 0,
                start: -0.0,
                finish: f64::NEG_INFINITY,
                locality: Locality::Any,
                bytes_read: v,
                shuffle_read_bytes: v,
                shuffle_write_bytes: v,
                memory_bytes_spilled: v,
                disk_bytes_spilled: v,
                jvm_gc_time: v,
                serialize_time: v,
                deserialize_time: v,
            }),
        };
        let batch = EventBatch::from_events(std::slice::from_ref(&ev));
        let back = batch.to_events();
        match (&back[0].event, &ev.event) {
            (Event::TaskEnd(a), Event::TaskEnd(b)) => {
                assert_eq!(a.start.to_bits(), b.start.to_bits());
                assert_eq!(a.finish.to_bits(), b.finish.to_bits());
                assert_eq!(a.bytes_read.to_bits(), b.bytes_read.to_bits());
            }
            _ => panic!("wrong kind back"),
        }
    }

    #[test]
    fn clear_retains_capacity_and_reuses() {
        let events = sample_events();
        let mut batch = EventBatch::from_events(&events);
        let cap = batch.ints.capacity();
        batch.clear();
        assert!(batch.is_empty());
        assert_eq!(batch.ints.capacity(), cap, "clear must keep the allocation");
        for e in &events {
            batch.push(e);
        }
        assert_eq!(batch.to_events(), events);
    }

    #[test]
    fn incremental_push_matches_from_events() {
        let events = sample_events();
        let mut batch = EventBatch::new();
        for e in &events {
            batch.push(e);
        }
        assert_eq!(batch.to_events(), EventBatch::from_events(&events).to_events());
    }
}
