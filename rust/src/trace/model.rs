//! The trace data model — the interchange between the cluster (simulated or
//! real) and the analyzer. It mirrors what the paper collects: per-task
//! framework metrics from Spark event logs plus per-node 1 Hz resource
//! utilization series from mpstat/iostat/sar.

/// Task data locality, Table I of the paper. `NoPref` means location makes
/// no difference (e.g. reading from a database).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Locality {
    ProcessLocal,
    NodeLocal,
    RackLocal,
    Any,
    NoPref,
}

impl Locality {
    /// Numeric encoding of Eq. 4: PROCESS_LOCAL → 0, NODE_LOCAL → 1,
    /// otherwise → 2.
    pub fn numeric(self) -> f64 {
        match self {
            Locality::ProcessLocal => 0.0,
            Locality::NodeLocal => 1.0,
            _ => 2.0,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Locality::ProcessLocal => "PROCESS_LOCAL",
            Locality::NodeLocal => "NODE_LOCAL",
            Locality::RackLocal => "RACK_LOCAL",
            Locality::Any => "ANY",
            Locality::NoPref => "NOPREF",
        }
    }

    pub fn from_str(s: &str) -> Option<Locality> {
        Some(match s {
            "PROCESS_LOCAL" => Locality::ProcessLocal,
            "NODE_LOCAL" => Locality::NodeLocal,
            "RACK_LOCAL" => Locality::RackLocal,
            "ANY" => Locality::Any,
            "NOPREF" => Locality::NoPref,
            _ => return None,
        })
    }
}

/// One completed task: identity, placement, timing, and the framework
/// metrics Spark reports per task (Table II numerators).
///
/// All times are in seconds of trace time; byte quantities in bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskRecord {
    pub task_id: u64,
    pub stage_id: u64,
    /// Index of the node the task ran on.
    pub node: usize,
    /// Executor slot within the node (for intra-process locality).
    pub executor: usize,
    pub start: f64,
    pub finish: f64,
    pub locality: Locality,
    /// Input bytes read (from HDFS or cache).
    pub bytes_read: f64,
    pub shuffle_read_bytes: f64,
    pub shuffle_write_bytes: f64,
    pub memory_bytes_spilled: f64,
    pub disk_bytes_spilled: f64,
    /// Time spent in JVM garbage collection during the task (s).
    pub jvm_gc_time: f64,
    /// Result serialization time (s).
    pub serialize_time: f64,
    /// Executor deserialization time (s).
    pub deserialize_time: f64,
}

impl TaskRecord {
    pub fn duration(&self) -> f64 {
        (self.finish - self.start).max(0.0)
    }
}

/// A stage groups tasks that run the same function over different partitions;
/// the straggler definition (1.5× median) is evaluated within a stage.
#[derive(Debug, Clone, PartialEq)]
pub struct StageRecord {
    pub stage_id: u64,
    pub name: String,
    /// Task ids belonging to this stage (into `JobTrace::tasks`).
    pub tasks: Vec<u64>,
}

/// Per-node 1 Hz resource utilization series — the simulated mpstat
/// (`cpu`), iostat (`disk`) and sar (`net_bytes`) outputs.
///
/// `cpu[t]` and `disk[t]` are utilizations in [0, 1] for the window
/// [t·period, (t+1)·period); `net_bytes[t]` is bytes sent+received in that
/// window (Eq. 3 sums absolute traffic, not a utilization ratio).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSeries {
    pub node: usize,
    /// Sampling period in seconds (1.0 in the paper).
    pub period: f64,
    pub cpu: Vec<f64>,
    pub disk: Vec<f64>,
    pub net_bytes: Vec<f64>,
}

impl NodeSeries {
    pub fn empty(node: usize, period: f64) -> Self {
        NodeSeries { node, period, cpu: Vec::new(), disk: Vec::new(), net_bytes: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.cpu.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cpu.is_empty()
    }

    /// Mean of a series slice over the time window [t0, t1), clamped to the
    /// recorded range; returns 0.0 for empty/degenerate windows.
    pub fn window_mean(series: &[f64], period: f64, t0: f64, t1: f64) -> f64 {
        if series.is_empty() || t1 <= t0 {
            return 0.0;
        }
        let i0 = ((t0 / period).floor().max(0.0) as usize).min(series.len().saturating_sub(1));
        let i1 = ((t1 / period).ceil().max(1.0) as usize).min(series.len());
        if i0 >= i1 {
            return 0.0;
        }
        series[i0..i1].iter().sum::<f64>() / (i1 - i0) as f64
    }
}

/// The kind of resource anomaly injected (Anomaly Generator type).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AnomalyKind {
    Cpu,
    Io,
    Network,
}

impl AnomalyKind {
    pub fn as_str(self) -> &'static str {
        match self {
            AnomalyKind::Cpu => "CPU",
            AnomalyKind::Io => "IO",
            AnomalyKind::Network => "NETWORK",
        }
    }

    pub fn from_str(s: &str) -> Option<AnomalyKind> {
        Some(match s {
            "CPU" => AnomalyKind::Cpu,
            "IO" => AnomalyKind::Io,
            "NETWORK" => AnomalyKind::Network,
            _ => return None,
        })
    }

    pub fn all() -> [AnomalyKind; 3] {
        [AnomalyKind::Cpu, AnomalyKind::Io, AnomalyKind::Network]
    }
}

/// Ground-truth record of one injected anomaly window — what the AG did.
/// The scorer uses these to label features TP/FP/TN/FN (Section IV.B).
#[derive(Debug, Clone, PartialEq)]
pub struct InjectionRecord {
    pub node: usize,
    pub kind: AnomalyKind,
    pub t_start: f64,
    pub t_end: f64,
}

impl InjectionRecord {
    /// Does this injection window overlap a task's execution on its node?
    pub fn affects(&self, task: &TaskRecord) -> bool {
        task.node == self.node && self.t_start < task.finish && self.t_end > task.start
    }

    /// Fraction of the task's duration covered by the injection window.
    pub fn coverage(&self, task: &TaskRecord) -> f64 {
        if task.node != self.node {
            return 0.0;
        }
        let lo = self.t_start.max(task.start);
        let hi = self.t_end.min(task.finish);
        let d = task.duration();
        if d <= 0.0 {
            return 0.0;
        }
        ((hi - lo) / d).clamp(0.0, 1.0)
    }
}

/// Static cluster description embedded in the trace.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterInfo {
    pub nodes: usize,
    pub cores_per_node: usize,
    pub executors_per_node: usize,
}

/// A complete job trace: everything the offline analyzer consumes.
#[derive(Debug, Clone, PartialEq)]
pub struct JobTrace {
    pub job_name: String,
    pub workload: String,
    pub cluster: ClusterInfo,
    pub stages: Vec<StageRecord>,
    pub tasks: Vec<TaskRecord>,
    pub node_series: Vec<NodeSeries>,
    /// Ground-truth anomaly injections (empty for real/un-injected traces).
    pub injections: Vec<InjectionRecord>,
}

impl JobTrace {
    /// Tasks belonging to stage `stage_id`, in task-id order.
    pub fn stage_tasks(&self, stage_id: u64) -> Vec<&TaskRecord> {
        self.tasks.iter().filter(|t| t.stage_id == stage_id).collect()
    }

    /// Total trace makespan (latest finish).
    pub fn makespan(&self) -> f64 {
        self.tasks.iter().map(|t| t.finish).fold(0.0, f64::max)
    }

    /// The resource series for a node (panics on bad index — construction
    /// invariant, traces always carry one series per node).
    pub fn series(&self, node: usize) -> &NodeSeries {
        &self.node_series[node]
    }

    /// Basic structural invariants — used by proptest and after decoding.
    pub fn validate(&self) -> Result<(), String> {
        if self.node_series.len() != self.cluster.nodes {
            return Err(format!(
                "node_series {} != cluster.nodes {}",
                self.node_series.len(),
                self.cluster.nodes
            ));
        }
        let mut stage_task_count = 0usize;
        for s in &self.stages {
            stage_task_count += s.tasks.len();
            for tid in &s.tasks {
                let t = self
                    .tasks
                    .iter()
                    .find(|t| t.task_id == *tid)
                    .ok_or_else(|| format!("stage {} references missing task {}", s.stage_id, tid))?;
                if t.stage_id != s.stage_id {
                    return Err(format!("task {} stage mismatch", tid));
                }
            }
        }
        if stage_task_count != self.tasks.len() {
            return Err(format!(
                "stages cover {} tasks but trace has {}",
                stage_task_count,
                self.tasks.len()
            ));
        }
        for t in &self.tasks {
            if t.finish < t.start {
                return Err(format!("task {} finish < start", t.task_id));
            }
            if t.node >= self.cluster.nodes {
                return Err(format!("task {} on unknown node {}", t.task_id, t.node));
            }
        }
        for i in &self.injections {
            if i.node >= self.cluster.nodes {
                return Err(format!("injection on unknown node {}", i.node));
            }
            if i.t_end < i.t_start {
                return Err("injection window inverted".to_string());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub fn tiny_trace() -> JobTrace {
        let mk = |task_id, stage_id, node, start, finish| TaskRecord {
            task_id,
            stage_id,
            node,
            executor: 0,
            start,
            finish,
            locality: Locality::NodeLocal,
            bytes_read: 100.0,
            shuffle_read_bytes: 10.0,
            shuffle_write_bytes: 5.0,
            memory_bytes_spilled: 0.0,
            disk_bytes_spilled: 0.0,
            jvm_gc_time: 0.1,
            serialize_time: 0.01,
            deserialize_time: 0.02,
        };
        JobTrace {
            job_name: "test".into(),
            workload: "unit".into(),
            cluster: ClusterInfo { nodes: 2, cores_per_node: 4, executors_per_node: 1 },
            stages: vec![StageRecord { stage_id: 0, name: "s0".into(), tasks: vec![0, 1, 2] }],
            tasks: vec![mk(0, 0, 0, 0.0, 1.0), mk(1, 0, 0, 0.0, 1.1), mk(2, 0, 1, 0.0, 3.0)],
            node_series: vec![
                NodeSeries { node: 0, period: 1.0, cpu: vec![0.5; 5], disk: vec![0.1; 5], net_bytes: vec![100.0; 5] },
                NodeSeries { node: 1, period: 1.0, cpu: vec![0.9; 5], disk: vec![0.2; 5], net_bytes: vec![50.0; 5] },
            ],
            injections: vec![InjectionRecord {
                node: 1,
                kind: AnomalyKind::Cpu,
                t_start: 0.5,
                t_end: 2.5,
            }],
        }
    }

    #[test]
    fn locality_numeric_eq4() {
        assert_eq!(Locality::ProcessLocal.numeric(), 0.0);
        assert_eq!(Locality::NodeLocal.numeric(), 1.0);
        assert_eq!(Locality::RackLocal.numeric(), 2.0);
        assert_eq!(Locality::Any.numeric(), 2.0);
        assert_eq!(Locality::NoPref.numeric(), 2.0);
    }

    #[test]
    fn locality_string_roundtrip() {
        for l in [
            Locality::ProcessLocal,
            Locality::NodeLocal,
            Locality::RackLocal,
            Locality::Any,
            Locality::NoPref,
        ] {
            assert_eq!(Locality::from_str(l.as_str()), Some(l));
        }
        assert_eq!(Locality::from_str("bogus"), None);
    }

    #[test]
    fn injection_affects_and_coverage() {
        let t = tiny_trace();
        let inj = &t.injections[0];
        assert!(!inj.affects(&t.tasks[0])); // wrong node
        assert!(inj.affects(&t.tasks[2]));
        // task2: [0,3], injection [0.5,2.5] → coverage 2/3
        assert!((inj.coverage(&t.tasks[2]) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(inj.coverage(&t.tasks[0]), 0.0);
    }

    #[test]
    fn window_mean_clamps() {
        let s = [1.0, 2.0, 3.0, 4.0];
        assert!((NodeSeries::window_mean(&s, 1.0, 0.0, 2.0) - 1.5).abs() < 1e-12);
        assert!((NodeSeries::window_mean(&s, 1.0, 3.0, 100.0) - 4.0).abs() < 1e-12);
        assert_eq!(NodeSeries::window_mean(&s, 1.0, 2.0, 2.0), 0.0);
        assert_eq!(NodeSeries::window_mean(&[], 1.0, 0.0, 1.0), 0.0);
    }

    #[test]
    fn validate_accepts_wellformed() {
        assert!(tiny_trace().validate().is_ok());
    }

    #[test]
    fn validate_rejects_broken() {
        let mut t = tiny_trace();
        t.tasks[0].stage_id = 99;
        assert!(t.validate().is_err());

        let mut t = tiny_trace();
        t.tasks[1].finish = -1.0;
        assert!(t.validate().is_err());

        let mut t = tiny_trace();
        t.node_series.pop();
        assert!(t.validate().is_err());

        let mut t = tiny_trace();
        t.injections[0].node = 10;
        assert!(t.validate().is_err());
    }

    #[test]
    fn makespan_and_stage_tasks() {
        let t = tiny_trace();
        assert_eq!(t.makespan(), 3.0);
        assert_eq!(t.stage_tasks(0).len(), 3);
        assert_eq!(t.stage_tasks(1).len(), 0);
    }
}
