//! Trace model and codecs: the interchange between the cluster (simulated
//! or real) and the BigRoots analyzer.
//!
//! - [`model`] — in-memory structures: tasks, stages, node resource series,
//!   anomaly ground truth.
//! - [`codec`] — whole-trace JSON file format (offline analysis workflow).
//! - [`eventlog`] — Spark-style newline-delimited event stream (streaming
//!   analysis workflow).
//! - [`wire`] — compact length-prefixed binary event frames (the
//!   parser-free hot-path encoding) and the [`wire::EventCodec`] seam that
//!   puts NDJSON and binary behind one interface.
//! - [`batch`] — [`batch::EventBatch`], the columnar struct-of-arrays
//!   batch container the batched ingest path moves through queues (one
//!   shared string arena per batch, recyclable buffers).

pub mod batch;
pub mod codec;
pub mod eventlog;
pub mod model;
pub mod wire;

pub use model::{
    AnomalyKind, ClusterInfo, InjectionRecord, JobTrace, Locality, NodeSeries, StageRecord,
    TaskRecord,
};
