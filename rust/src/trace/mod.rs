//! Trace model and codecs: the interchange between the cluster (simulated
//! or real) and the BigRoots analyzer.
//!
//! - [`model`] — in-memory structures: tasks, stages, node resource series,
//!   anomaly ground truth.
//! - [`codec`] — whole-trace JSON file format (offline analysis workflow).
//! - [`eventlog`] — Spark-style newline-delimited event stream (streaming
//!   analysis workflow).

pub mod codec;
pub mod eventlog;
pub mod model;

pub use model::{
    AnomalyKind, ClusterInfo, InjectionRecord, JobTrace, Locality, NodeSeries, StageRecord,
    TaskRecord,
};
