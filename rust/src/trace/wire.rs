//! Compact binary event wire format — the parser-free ingest path.
//!
//! PR 4's zero-alloc NDJSON decoder still pays a UTF-8 scan plus a float
//! parse for every event, so ingest throughput is parser-bound, not
//! kernel-bound. This module fixes the structure in the *frame* instead
//! of re-discovering it at parse time: a length-prefixed binary frame per
//! event with fixed-width little-endian ids/timestamps/floats and
//! varint-prefixed strings. Decode is bounds-checked reads — no text
//! scan, no float parse, no transmute.
//!
//! ## Stream layout
//!
//! ```text
//! ┌────────────────────────── stream header (8 bytes) ─────────────────────────┐
//! │ magic "BGRW" (4) │ version u16 LE │ flags u16 LE (bit 0 = frames tagged)   │
//! └────────────────────────────────────────────────────────────────────────────┘
//! ┌───────────────────────────── frame (repeated) ─────────────────────────────┐
//! │ payload_len u32 LE │ kind u8 │ [job u64 LE if tagged] │ kind-specific body │
//! └────────────────────────────────────────────────────────────────────────────┘
//! ```
//!
//! Floats travel as raw IEEE-754 bit patterns (`f64::to_bits`, LE), so
//! NaN payloads and ±inf round-trip bit-identically — the same
//! bit-exactness contract [`crate::live::persist`] keeps with its hex
//! convention. Strings are varint(LEB128)-length-prefixed UTF-8. The
//! per-frame length prefix lets a reader skip, resync after a partial
//! append, and walk an mmap'd capture with zero-copy frame views
//! ([`crate::live::source::MmapReplaySource`]).
//!
//! Untagged streams (flag bit 0 clear) mirror the NDJSON convention: no
//! per-frame job id, every event belongs to job 0.
//!
//! [`BinaryCodec`] and [`NdjsonCodec`] sit behind the [`EventCodec`]
//! trait — one seam for every consumer that ships event streams
//! (`bigroots convert`, the live sources, future federation snapshot
//! shipping). [`BinaryTail`] is the incremental reader
//! ([`crate::trace::eventlog::NdjsonTail`]'s binary twin): feed it byte
//! chunks exactly as they come off a growing file, partial frames stay
//! buffered until the rest arrives. See `docs/WIRE_FORMAT.md`.

use super::eventlog::{parse_tagged_events, Event, TaggedEvent};
use super::model::{AnomalyKind, ClusterInfo, InjectionRecord, Locality, TaskRecord};

/// First four bytes of every binary capture.
pub const MAGIC: [u8; 4] = *b"BGRW";
/// Current wire version, written by every encoder.
pub const WIRE_VERSION: u16 = 1;
/// Oldest wire version this build still decodes.
pub const MIN_WIRE_VERSION: u16 = 1;
/// Stream-header flag bit: frames carry a u64 job id.
pub const FLAG_TAGGED: u16 = 1;
/// Stream header length in bytes (magic + version + flags).
pub const HEADER_LEN: usize = 8;
/// Upper bound on a single frame's payload: anything larger is treated
/// as corruption (a flipped length prefix must not make a reader buffer
/// gigabytes waiting for a frame that never completes).
pub const MAX_FRAME_LEN: usize = 1 << 22;
/// Upper bound on one varint-prefixed string.
pub const MAX_STR_LEN: usize = 1 << 20;

// Frame kind tags. Stable on the wire — append, never renumber.
pub(crate) const K_JOB_START: u8 = 1;
pub(crate) const K_STAGE_SUBMITTED: u8 = 2;
pub(crate) const K_TASK_START: u8 = 3;
pub(crate) const K_TASK_END: u8 = 4;
pub(crate) const K_RESOURCE_SAMPLE: u8 = 5;
pub(crate) const K_INJECTION: u8 = 6;
pub(crate) const K_JOB_END: u8 = 7;

/// Decode failure: byte offset (relative to the buffer handed in) plus a
/// human-readable reason. Corrupt and truncated input always surfaces
/// here — never as a panic.
#[derive(Debug, Clone, PartialEq)]
pub struct WireError {
    pub offset: usize,
    pub message: String,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "wire error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for WireError {}

fn err<T>(offset: usize, message: impl Into<String>) -> Result<T, WireError> {
    Err(WireError { offset, message: message.into() })
}

/// Parsed stream header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamHeader {
    pub version: u16,
    /// Whether frames carry a u64 job id. Untagged streams decode with
    /// every event assigned to job 0, mirroring the NDJSON convention.
    pub tagged: bool,
}

/// Build the 8-byte stream header.
pub fn encode_header(tagged: bool) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[..4].copy_from_slice(&MAGIC);
    h[4..6].copy_from_slice(&WIRE_VERSION.to_le_bytes());
    let flags: u16 = if tagged { FLAG_TAGGED } else { 0 };
    h[6..8].copy_from_slice(&flags.to_le_bytes());
    h
}

/// Parse and validate a stream header. The buffer must hold at least
/// [`HEADER_LEN`] bytes.
pub fn decode_header(buf: &[u8]) -> Result<StreamHeader, WireError> {
    if buf.len() < HEADER_LEN {
        return err(0, format!("stream header needs {HEADER_LEN} bytes, have {}", buf.len()));
    }
    if buf[..4] != MAGIC {
        return err(0, "bad magic (not a bigroots binary event capture)");
    }
    let version = u16::from_le_bytes([buf[4], buf[5]]);
    if !(MIN_WIRE_VERSION..=WIRE_VERSION).contains(&version) {
        return err(
            4,
            format!(
                "unsupported wire version {version} (this build reads \
                 {MIN_WIRE_VERSION}..={WIRE_VERSION})"
            ),
        );
    }
    let flags = u16::from_le_bytes([buf[6], buf[7]]);
    if flags & !FLAG_TAGGED != 0 {
        return err(6, format!("unknown header flags {flags:#06x}"));
    }
    Ok(StreamHeader { version, tagged: flags & FLAG_TAGGED != 0 })
}

/// Cheap sniff: does this buffer start like a binary capture? Used by the
/// `--format auto` paths to pick a codec without a second file read.
pub fn is_binary(buf: &[u8]) -> bool {
    buf.len() >= 4 && buf[..4] == MAGIC
}

// ---------------------------------------------------------------------------
// Encoding

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_varint(out: &mut Vec<u8>, mut v: u32) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    // MAX_STR_LEN bounds the decoder; encoders never emit longer strings
    // in practice (job/stage names), but truncating silently would break
    // round-trips, so a pathological name is kept and rejected on decode.
    put_varint(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

pub(crate) fn locality_tag(l: Locality) -> u8 {
    match l {
        Locality::ProcessLocal => 0,
        Locality::NodeLocal => 1,
        Locality::RackLocal => 2,
        Locality::Any => 3,
        Locality::NoPref => 4,
    }
}

pub(crate) fn locality_from_tag(t: u8) -> Option<Locality> {
    Some(match t {
        0 => Locality::ProcessLocal,
        1 => Locality::NodeLocal,
        2 => Locality::RackLocal,
        3 => Locality::Any,
        4 => Locality::NoPref,
        _ => return None,
    })
}

pub(crate) fn anomaly_tag(k: AnomalyKind) -> u8 {
    match k {
        AnomalyKind::Cpu => 0,
        AnomalyKind::Io => 1,
        AnomalyKind::Network => 2,
    }
}

pub(crate) fn anomaly_from_tag(t: u8) -> Option<AnomalyKind> {
    Some(match t {
        0 => AnomalyKind::Cpu,
        1 => AnomalyKind::Io,
        2 => AnomalyKind::Network,
        _ => return None,
    })
}

/// Append one length-prefixed frame. `job` is `Some` exactly when the
/// stream header declared [`FLAG_TAGGED`] — mixing is a caller bug and
/// produces a capture the decoder rejects.
pub fn encode_frame_into(out: &mut Vec<u8>, job: Option<u64>, event: &Event) {
    let len_at = out.len();
    out.extend_from_slice(&[0u8; 4]); // payload length backpatched below
    let payload_at = out.len();
    match event {
        Event::JobStart { job_name, workload, cluster } => {
            out.push(K_JOB_START);
            if let Some(j) = job {
                put_u64(out, j);
            }
            put_str(out, job_name);
            put_str(out, workload);
            put_u64(out, cluster.nodes as u64);
            put_u64(out, cluster.cores_per_node as u64);
            put_u64(out, cluster.executors_per_node as u64);
        }
        Event::StageSubmitted { stage_id, name, num_tasks } => {
            out.push(K_STAGE_SUBMITTED);
            if let Some(j) = job {
                put_u64(out, j);
            }
            put_u64(out, *stage_id);
            put_str(out, name);
            put_u64(out, *num_tasks as u64);
        }
        Event::TaskStart { task_id, stage_id, node, executor, time, locality } => {
            out.push(K_TASK_START);
            if let Some(j) = job {
                put_u64(out, j);
            }
            put_u64(out, *task_id);
            put_u64(out, *stage_id);
            put_u64(out, *node as u64);
            put_u64(out, *executor as u64);
            put_f64(out, *time);
            out.push(locality_tag(*locality));
        }
        Event::TaskEnd(t) => {
            out.push(K_TASK_END);
            if let Some(j) = job {
                put_u64(out, j);
            }
            put_u64(out, t.task_id);
            put_u64(out, t.stage_id);
            put_u64(out, t.node as u64);
            put_u64(out, t.executor as u64);
            put_f64(out, t.start);
            put_f64(out, t.finish);
            out.push(locality_tag(t.locality));
            put_f64(out, t.bytes_read);
            put_f64(out, t.shuffle_read_bytes);
            put_f64(out, t.shuffle_write_bytes);
            put_f64(out, t.memory_bytes_spilled);
            put_f64(out, t.disk_bytes_spilled);
            put_f64(out, t.jvm_gc_time);
            put_f64(out, t.serialize_time);
            put_f64(out, t.deserialize_time);
        }
        Event::ResourceSample { node, time, cpu, disk, net_bytes } => {
            out.push(K_RESOURCE_SAMPLE);
            if let Some(j) = job {
                put_u64(out, j);
            }
            put_u64(out, *node as u64);
            put_f64(out, *time);
            put_f64(out, *cpu);
            put_f64(out, *disk);
            put_f64(out, *net_bytes);
        }
        Event::Injection(i) => {
            out.push(K_INJECTION);
            if let Some(j) = job {
                put_u64(out, j);
            }
            put_u64(out, i.node as u64);
            out.push(anomaly_tag(i.kind));
            put_f64(out, i.t_start);
            put_f64(out, i.t_end);
        }
        Event::JobEnd { time } => {
            out.push(K_JOB_END);
            if let Some(j) = job {
                put_u64(out, j);
            }
            put_f64(out, *time);
        }
    }
    let payload_len = (out.len() - payload_at) as u32;
    out[len_at..len_at + 4].copy_from_slice(&payload_len.to_le_bytes());
}

// ---------------------------------------------------------------------------
// Decoding

/// Bounds-checked cursor over a frame payload. Every read either advances
/// or returns a [`WireError`] carrying the absolute offset (`base + pos`).
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
    /// Offset of `buf[0]` in the caller's buffer, for error messages.
    base: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8], base: usize) -> Self {
        Reader { buf, pos: 0, base }
    }

    fn at(&self) -> usize {
        self.base + self.pos
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        match self.buf.get(self.pos) {
            Some(&b) => {
                self.pos += 1;
                Ok(b)
            }
            None => err(self.at(), "frame truncated (u8)"),
        }
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        match self.buf.get(self.pos..self.pos + 8) {
            Some(b) => {
                self.pos += 8;
                Ok(u64::from_le_bytes(b.try_into().expect("8-byte slice")))
            }
            None => err(self.at(), "frame truncated (u64)"),
        }
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        self.u64().map(f64::from_bits)
    }

    fn usize(&mut self) -> Result<usize, WireError> {
        let at = self.at();
        let v = self.u64()?;
        usize::try_from(v).or_else(|_| err(at, format!("value {v} overflows usize")))
    }

    fn varint(&mut self) -> Result<u32, WireError> {
        let at = self.at();
        let mut v: u32 = 0;
        for i in 0..5 {
            let b = self.u8()?;
            let bits = (b & 0x7f) as u32;
            if i == 4 && bits > 0x0f {
                return err(at, "varint overflows u32");
            }
            v |= bits << (7 * i);
            if b & 0x80 == 0 {
                return Ok(v);
            }
        }
        err(at, "varint longer than 5 bytes")
    }

    fn str(&mut self) -> Result<String, WireError> {
        let at = self.at();
        let n = self.varint()? as usize;
        if n > MAX_STR_LEN {
            return err(at, format!("string length {n} exceeds {MAX_STR_LEN}"));
        }
        match self.buf.get(self.pos..self.pos + n) {
            Some(b) => {
                self.pos += n;
                std::str::from_utf8(b)
                    .map(|s| s.to_string())
                    .or_else(|_| err(at, "string is not valid UTF-8"))
            }
            None => err(self.at(), "frame truncated (string body)"),
        }
    }

    fn locality(&mut self) -> Result<Locality, WireError> {
        let at = self.at();
        let t = self.u8()?;
        locality_from_tag(t).ok_or_else(|| WireError {
            offset: at,
            message: format!("bad locality tag {t}"),
        })
    }
}

/// One frame successfully pulled off the buffer.
#[derive(Debug, Clone, PartialEq)]
pub struct DecodedFrame {
    /// Total bytes consumed, length prefix included.
    pub consumed: usize,
    /// The frame's job id (`None` on untagged streams).
    pub job: Option<u64>,
    pub event: Event,
}

/// Decode one frame from the front of `buf` (which must start at a frame
/// boundary, i.e. past the stream header). Returns `Ok(None)` when the
/// buffer holds only part of a frame — feed more bytes and retry; the
/// partial-frame resync contract of the tailing readers. Corruption (bad
/// kind/tag, implausible length, trailing bytes inside the frame) is an
/// error, never a panic.
pub fn decode_frame(buf: &[u8], tagged: bool) -> Result<Option<DecodedFrame>, WireError> {
    let Some(len_bytes) = buf.get(..4) else {
        return Ok(None);
    };
    let payload_len = u32::from_le_bytes(len_bytes.try_into().expect("4-byte slice")) as usize;
    if payload_len == 0 {
        return err(0, "zero-length frame");
    }
    if payload_len > MAX_FRAME_LEN {
        return err(0, format!("frame length {payload_len} exceeds {MAX_FRAME_LEN} (corrupt?)"));
    }
    let Some(payload) = buf.get(4..4 + payload_len) else {
        return Ok(None);
    };
    let mut r = Reader::new(payload, 4);
    let kind = r.u8()?;
    let job = if tagged { Some(r.u64()?) } else { None };
    let event = match kind {
        K_JOB_START => Event::JobStart {
            job_name: r.str()?,
            workload: r.str()?,
            cluster: ClusterInfo {
                nodes: r.usize()?,
                cores_per_node: r.usize()?,
                executors_per_node: r.usize()?,
            },
        },
        K_STAGE_SUBMITTED => Event::StageSubmitted {
            stage_id: r.u64()?,
            name: r.str()?,
            num_tasks: r.usize()?,
        },
        K_TASK_START => Event::TaskStart {
            task_id: r.u64()?,
            stage_id: r.u64()?,
            node: r.usize()?,
            executor: r.usize()?,
            time: r.f64()?,
            locality: r.locality()?,
        },
        K_TASK_END => Event::TaskEnd(TaskRecord {
            task_id: r.u64()?,
            stage_id: r.u64()?,
            node: r.usize()?,
            executor: r.usize()?,
            start: r.f64()?,
            finish: r.f64()?,
            locality: r.locality()?,
            bytes_read: r.f64()?,
            shuffle_read_bytes: r.f64()?,
            shuffle_write_bytes: r.f64()?,
            memory_bytes_spilled: r.f64()?,
            disk_bytes_spilled: r.f64()?,
            jvm_gc_time: r.f64()?,
            serialize_time: r.f64()?,
            deserialize_time: r.f64()?,
        }),
        K_RESOURCE_SAMPLE => Event::ResourceSample {
            node: r.usize()?,
            time: r.f64()?,
            cpu: r.f64()?,
            disk: r.f64()?,
            net_bytes: r.f64()?,
        },
        K_INJECTION => {
            let node = r.usize()?;
            let at = r.at();
            let tag = r.u8()?;
            let kind = anomaly_from_tag(tag).ok_or_else(|| WireError {
                offset: at,
                message: format!("bad anomaly tag {tag}"),
            })?;
            Event::Injection(InjectionRecord {
                node,
                kind,
                t_start: r.f64()?,
                t_end: r.f64()?,
            })
        }
        K_JOB_END => Event::JobEnd { time: r.f64()? },
        other => return err(4, format!("unknown frame kind {other}")),
    };
    if r.pos != payload_len {
        return err(
            4 + r.pos,
            format!("frame length mismatch: payload {payload_len} bytes, decoded {}", r.pos),
        );
    }
    Ok(Some(DecodedFrame { consumed: 4 + payload_len, job, event }))
}

/// Encode a job-tagged stream: header + one frame per event.
pub fn encode_stream(events: &[TaggedEvent]) -> Vec<u8> {
    // Frames average well under 160 bytes; reserving up front keeps the
    // encoder allocation-quiet on large captures.
    let mut out = Vec::with_capacity(HEADER_LEN + events.len() * 160);
    out.extend_from_slice(&encode_header(true));
    for e in events {
        encode_frame_into(&mut out, Some(e.job_id), &e.event);
    }
    out
}

/// Encode an untagged single-job stream (no per-frame job ids).
pub fn encode_untagged_stream(events: &[Event]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + events.len() * 160);
    out.extend_from_slice(&encode_header(false));
    for e in events {
        encode_frame_into(&mut out, None, e);
    }
    out
}

/// Decode a whole capture. Untagged streams come back with every event
/// assigned to job 0 (the NDJSON convention). A trailing partial frame is
/// a truncation error — this is the strict whole-file path; use
/// [`BinaryTail`] to follow a still-growing capture.
pub fn decode_stream(bytes: &[u8]) -> Result<Vec<TaggedEvent>, WireError> {
    let header = decode_header(bytes)?;
    let mut out = Vec::new();
    let mut pos = HEADER_LEN;
    while pos < bytes.len() {
        match decode_frame(&bytes[pos..], header.tagged) {
            Ok(Some(f)) => {
                out.push(TaggedEvent { job_id: f.job.unwrap_or(0), event: f.event });
                pos += f.consumed;
            }
            Ok(None) => {
                return err(
                    pos,
                    format!("truncated frame at end of capture ({} bytes left)", bytes.len() - pos),
                );
            }
            Err(e) => {
                return Err(WireError { offset: pos + e.offset, message: e.message });
            }
        }
    }
    Ok(out)
}

/// Split a whole capture into at most `parts` contiguous, frame-aligned
/// byte ranges of roughly equal size — the partition step of parallel
/// mmap decode. Only the length prefixes are walked (two loads per
/// frame, no payload decode), so the scan costs a tiny fraction of the
/// decode it parallelizes. Ranges come back in file order and cover the
/// frames region exactly, so concatenating their decoded events in range
/// order reproduces the sequential decode bit for bit (the "merge" of
/// the parallel path is ordered concatenation; see docs/BATCHING.md).
///
/// The prefix walk applies the same corruption rules as
/// [`decode_stream`]: a zero-length or oversized frame and a capture cut
/// mid-frame are errors carrying an absolute byte offset.
pub fn partition_frames(bytes: &[u8], parts: usize) -> Result<Vec<(usize, usize)>, WireError> {
    decode_header(bytes)?;
    let parts = parts.max(1);
    let end = bytes.len();
    let mut pos = HEADER_LEN;
    let span = end - pos;
    // Cut at the first frame boundary at or past each ideal byte edge.
    let target = (span + parts - 1) / parts.max(1);
    let target = target.max(1);
    let mut ranges: Vec<(usize, usize)> = Vec::new();
    let mut start = pos;
    while pos < end {
        if end - pos < 4 {
            return err(
                pos,
                format!("truncated frame length prefix ({} bytes left)", end - pos),
            );
        }
        let len = u32::from_le_bytes([bytes[pos], bytes[pos + 1], bytes[pos + 2], bytes[pos + 3]])
            as usize;
        if len == 0 {
            return err(pos, "zero-length frame".to_string());
        }
        if len > MAX_FRAME_LEN {
            return err(pos, format!("frame length {len} exceeds cap {MAX_FRAME_LEN}"));
        }
        if end - pos - 4 < len {
            return err(
                pos,
                format!("truncated frame at end of capture ({} bytes left)", end - pos),
            );
        }
        pos += 4 + len;
        if pos - start >= target && ranges.len() + 1 < parts {
            ranges.push((start, pos));
            start = pos;
        }
    }
    if pos > start {
        ranges.push((start, pos));
    }
    Ok(ranges)
}

// ---------------------------------------------------------------------------
// Incremental reader

/// Incremental binary-capture reader — [`super::eventlog::NdjsonTail`]'s
/// twin for the wire format, and the parsing half of the binary live
/// sources. Feed it raw byte chunks exactly as they come off a growing
/// file (chunks may end mid-frame, even mid-header); complete frames come
/// back as events, a trailing partial frame stays buffered until the rest
/// arrives (partial-frame resync). [`BinaryTail::reset`] (log rotation)
/// starts a fresh stream — buffer *and* header are cleared.
#[derive(Debug, Default)]
pub struct BinaryTail {
    buf: Vec<u8>,
    header: Option<StreamHeader>,
    frames: usize,
    /// Feeds that completed a frame begun in an earlier chunk. Cumulative
    /// across resets — it is a health counter, not per-stream state.
    resyncs: usize,
    /// Partial frames abandoned by [`BinaryTail::reset`] (rotation or
    /// reconnect cut a half-written frame). Cumulative across resets.
    dropped_partial: usize,
}

impl BinaryTail {
    pub fn new() -> Self {
        Self::default()
    }

    /// Consume one chunk; returns every event whose frame completed.
    pub fn feed(&mut self, chunk: &[u8]) -> Result<Vec<TaggedEvent>, WireError> {
        let pending = !self.buf.is_empty();
        self.buf.extend_from_slice(chunk);
        if self.header.is_none() {
            if self.buf.len() < HEADER_LEN {
                return Ok(Vec::new());
            }
            self.header = Some(decode_header(&self.buf)?);
            self.buf.drain(..HEADER_LEN);
        }
        let tagged = self.header.expect("header parsed above").tagged;
        let mut out = Vec::new();
        let mut pos = 0;
        loop {
            match decode_frame(&self.buf[pos..], tagged) {
                Ok(Some(f)) => {
                    out.push(TaggedEvent { job_id: f.job.unwrap_or(0), event: f.event });
                    pos += f.consumed;
                    self.frames += 1;
                }
                Ok(None) => break,
                Err(e) => {
                    return Err(WireError { offset: pos + e.offset, message: e.message });
                }
            }
        }
        self.buf.drain(..pos);
        if pending && !out.is_empty() {
            self.resyncs += 1;
        }
        Ok(out)
    }

    /// End of stream: a partial frame still buffered means the capture
    /// was truncated mid-write — an error, unlike NDJSON where a trailing
    /// unterminated line can still parse.
    pub fn finish(&mut self) -> Result<(), WireError> {
        let left = std::mem::take(&mut self.buf);
        if left.is_empty() {
            Ok(())
        } else {
            err(0, format!("stream ended inside a frame ({} bytes buffered)", left.len()))
        }
    }

    /// Start over on a fresh stream (log rotation / reconnect). A
    /// half-buffered frame is abandoned and counted in
    /// [`BinaryTail::dropped_partial`].
    pub fn reset(&mut self) {
        if self.header.is_some() && !self.buf.is_empty() {
            self.dropped_partial += 1;
        }
        self.buf.clear();
        self.header = None;
        self.frames = 0;
    }

    /// Bytes held for the current partial frame (or pre-header prefix).
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Complete frames decoded since creation or the last reset.
    pub fn frames(&self) -> usize {
        self.frames
    }

    /// Feeds that completed a frame begun in an earlier chunk — how often
    /// the reader had to resync across a partial append. Cumulative.
    pub fn resyncs(&self) -> usize {
        self.resyncs
    }

    /// Partial frames abandoned at [`BinaryTail::reset`] (rotation cut a
    /// half-written frame). Cumulative.
    pub fn dropped_partial(&self) -> usize {
        self.dropped_partial
    }

    /// The stream header, once enough bytes arrived to parse it.
    pub fn header(&self) -> Option<StreamHeader> {
        self.header
    }
}

// ---------------------------------------------------------------------------
// The codec seam

/// One interface over the two event-stream encodings, so every consumer
/// that ships streams (`bigroots convert`, replay sources, federation
/// snapshot shipping) binds to the seam instead of a concrete format.
pub trait EventCodec {
    /// Short format name for CLI flags and logs ("ndjson" / "binary").
    fn name(&self) -> &'static str;

    /// Serialize a job-tagged stream, container header included.
    fn encode_stream(&self, events: &[TaggedEvent]) -> Vec<u8>;

    /// Parse a capture produced by [`EventCodec::encode_stream`] (or any
    /// valid stream in this encoding; untagged input maps to job 0).
    fn decode_stream(&self, bytes: &[u8]) -> Result<Vec<TaggedEvent>, String>;

    /// Does this capture look like this codec's format?
    fn sniff(&self, bytes: &[u8]) -> bool;
}

/// Newline-delimited JSON (the PR-4 zero-alloc text path).
pub struct NdjsonCodec;

impl EventCodec for NdjsonCodec {
    fn name(&self) -> &'static str {
        "ndjson"
    }

    fn encode_stream(&self, events: &[TaggedEvent]) -> Vec<u8> {
        let mut out = String::new();
        for e in events {
            out.push_str(&e.encode().to_string());
            out.push('\n');
        }
        out.into_bytes()
    }

    fn decode_stream(&self, bytes: &[u8]) -> Result<Vec<TaggedEvent>, String> {
        let text = std::str::from_utf8(bytes).map_err(|e| format!("not UTF-8: {e}"))?;
        parse_tagged_events(text).map_err(|e| e.to_string())
    }

    fn sniff(&self, bytes: &[u8]) -> bool {
        !is_binary(bytes)
    }
}

/// The length-prefixed binary frame format defined by this module.
pub struct BinaryCodec;

impl EventCodec for BinaryCodec {
    fn name(&self) -> &'static str {
        "binary"
    }

    fn encode_stream(&self, events: &[TaggedEvent]) -> Vec<u8> {
        encode_stream(events)
    }

    fn decode_stream(&self, bytes: &[u8]) -> Result<Vec<TaggedEvent>, String> {
        decode_stream(bytes).map_err(|e| e.to_string())
    }

    fn sniff(&self, bytes: &[u8]) -> bool {
        is_binary(bytes)
    }
}

/// Pick the codec whose container format matches the capture's first
/// bytes (binary magic wins; anything else is treated as NDJSON).
pub fn codec_for(bytes: &[u8]) -> &'static dyn EventCodec {
    if is_binary(bytes) {
        &BinaryCodec
    } else {
        &NdjsonCodec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::multi::{interleaved_workload, round_robin_specs};
    use crate::trace::eventlog::trace_to_events;
    use crate::trace::model::StageRecord;
    use crate::trace::{JobTrace, NodeSeries};

    fn sample_events() -> Vec<TaggedEvent> {
        let (_, events) = interleaved_workload(&round_robin_specs(3, 0.08, 11));
        events
    }

    fn single_job_events() -> Vec<Event> {
        let t = JobTrace {
            job_name: "wire-j".into(),
            workload: "wire-w".into(),
            cluster: ClusterInfo { nodes: 2, cores_per_node: 2, executors_per_node: 1 },
            stages: vec![StageRecord { stage_id: 0, name: "s0".into(), tasks: vec![0, 1] }],
            tasks: vec![
                TaskRecord {
                    task_id: 0,
                    stage_id: 0,
                    node: 0,
                    executor: 0,
                    start: 0.0,
                    finish: 1.5,
                    locality: Locality::ProcessLocal,
                    bytes_read: 11.0,
                    shuffle_read_bytes: 1.0,
                    shuffle_write_bytes: 2.0,
                    memory_bytes_spilled: 0.0,
                    disk_bytes_spilled: 0.0,
                    jvm_gc_time: 0.1,
                    serialize_time: 0.01,
                    deserialize_time: 0.02,
                },
                TaskRecord {
                    task_id: 1,
                    stage_id: 0,
                    node: 1,
                    executor: 0,
                    start: 0.25,
                    finish: 2.0,
                    locality: Locality::NoPref,
                    bytes_read: 7.0,
                    shuffle_read_bytes: 0.5,
                    shuffle_write_bytes: 0.25,
                    memory_bytes_spilled: 3.0,
                    disk_bytes_spilled: 4.0,
                    jvm_gc_time: 0.2,
                    serialize_time: 0.03,
                    deserialize_time: 0.04,
                },
            ],
            node_series: vec![
                NodeSeries {
                    node: 0,
                    period: 1.0,
                    cpu: vec![0.1, 0.9],
                    disk: vec![0.2, 0.8],
                    net_bytes: vec![5.0, 6.0],
                },
                NodeSeries {
                    node: 1,
                    period: 1.0,
                    cpu: vec![0.3, 0.7],
                    disk: vec![0.4, 0.6],
                    net_bytes: vec![7.0, 8.0],
                },
            ],
            injections: vec![InjectionRecord {
                node: 1,
                kind: AnomalyKind::Network,
                t_start: 0.5,
                t_end: 1.0,
            }],
        };
        trace_to_events(&t)
    }

    #[test]
    fn header_roundtrip_and_sniff() {
        for tagged in [true, false] {
            let h = encode_header(tagged);
            let parsed = decode_header(&h).unwrap();
            assert_eq!(parsed.version, WIRE_VERSION);
            assert_eq!(parsed.tagged, tagged);
            assert!(is_binary(&h));
        }
        assert!(!is_binary(b"{\"event\":\"job_end\"}"));
        assert!(!is_binary(b"BG"));
    }

    #[test]
    fn tagged_stream_roundtrip() {
        let events = sample_events();
        let bytes = encode_stream(&events);
        let back = decode_stream(&bytes).unwrap();
        assert_eq!(events, back);
    }

    #[test]
    fn untagged_stream_roundtrip_maps_to_job_zero() {
        let events = single_job_events();
        let bytes = encode_untagged_stream(&events);
        assert!(!decode_header(&bytes).unwrap().tagged);
        let back = decode_stream(&bytes).unwrap();
        assert_eq!(back.len(), events.len());
        assert!(back.iter().all(|e| e.job_id == 0));
        let plain: Vec<Event> = back.into_iter().map(|e| e.event).collect();
        assert_eq!(plain, events);
    }

    #[test]
    fn binary_reencode_is_byte_identical() {
        let events = sample_events();
        let bytes = encode_stream(&events);
        let back = decode_stream(&bytes).unwrap();
        assert_eq!(encode_stream(&back), bytes);
    }

    #[test]
    fn nan_and_inf_bit_patterns_survive() {
        // A NaN with a payload, the quiet NaN, ±inf and -0.0 must all come
        // back with the exact same bit pattern (PartialEq would lie for
        // NaN, so compare bits).
        let patterns: Vec<u64> = vec![
            0x7ff8_0000_0000_0000,         // quiet NaN
            0x7ff8_dead_beef_0001,         // NaN with payload
            0xfff0_0000_0000_0001,         // signaling-ish negative NaN
            f64::INFINITY.to_bits(),
            f64::NEG_INFINITY.to_bits(),
            (-0.0f64).to_bits(),
        ];
        for &bits in &patterns {
            let v = f64::from_bits(bits);
            let ev = Event::TaskEnd(TaskRecord {
                task_id: 1,
                stage_id: 2,
                node: 3,
                executor: 4,
                start: v,
                finish: v,
                locality: Locality::RackLocal,
                bytes_read: v,
                shuffle_read_bytes: v,
                shuffle_write_bytes: v,
                memory_bytes_spilled: v,
                disk_bytes_spilled: v,
                jvm_gc_time: v,
                serialize_time: v,
                deserialize_time: v,
            });
            let mut buf = Vec::new();
            encode_frame_into(&mut buf, Some(9), &ev);
            let f = decode_frame(&buf, true).unwrap().expect("complete frame");
            assert_eq!(f.job, Some(9));
            match f.event {
                Event::TaskEnd(t) => {
                    for got in [
                        t.start,
                        t.finish,
                        t.bytes_read,
                        t.shuffle_read_bytes,
                        t.shuffle_write_bytes,
                        t.memory_bytes_spilled,
                        t.disk_bytes_spilled,
                        t.jvm_gc_time,
                        t.serialize_time,
                        t.deserialize_time,
                    ] {
                        assert_eq!(got.to_bits(), bits, "bit pattern {bits:#018x} mangled");
                    }
                }
                other => panic!("wrong event kind back: {other:?}"),
            }
        }
    }

    #[test]
    fn every_truncation_point_errors_or_waits_never_panics() {
        let events = sample_events();
        let bytes = encode_stream(&events);
        for cut in 0..bytes.len().min(600) {
            // Whole-file decode of a truncated capture: always Err.
            assert!(decode_stream(&bytes[..cut]).is_err(), "cut at {cut} must error");
        }
        // Truncating anywhere past the header leaves a partial trailing
        // frame: strict decode errors, the tail reader just waits.
        let mid = bytes.len() - 3;
        let mut tail = BinaryTail::new();
        let got = tail.feed(&bytes[..mid]).unwrap();
        assert!(got.len() < events.len());
        assert!(tail.buffered() > 0);
        assert!(tail.finish().is_err(), "EOF inside a frame is truncation");
    }

    #[test]
    fn corrupt_frames_error_not_panic() {
        let events = sample_events();
        let bytes = encode_stream(&events);

        // Bad magic.
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert!(decode_stream(&bad).is_err());

        // Unsupported version.
        let mut bad = bytes.clone();
        bad[4] = 0xff;
        bad[5] = 0xff;
        assert!(decode_stream(&bad).is_err());

        // Unknown flag bit.
        let mut bad = bytes.clone();
        bad[6] |= 0x80;
        assert!(decode_stream(&bad).is_err());

        // Unknown frame kind (first payload byte after the first length
        // prefix).
        let mut bad = bytes.clone();
        bad[HEADER_LEN + 4] = 0xee;
        assert!(decode_stream(&bad).is_err());

        // Implausible length prefix.
        let mut bad = bytes.clone();
        bad[HEADER_LEN..HEADER_LEN + 4]
            .copy_from_slice(&((MAX_FRAME_LEN as u32) + 1).to_le_bytes());
        assert!(decode_stream(&bad).is_err());

        // Length prefix that lies (longer than the real payload): either
        // the next frame's bytes misparse or the length check trips —
        // both are errors, not panics or silent misreads.
        let mut bad = bytes.clone();
        let real = u32::from_le_bytes(bad[HEADER_LEN..HEADER_LEN + 4].try_into().unwrap());
        bad[HEADER_LEN..HEADER_LEN + 4].copy_from_slice(&(real + 3).to_le_bytes());
        assert!(decode_stream(&bad).is_err());

        // Random byte flips through the first few frames: must never
        // panic (errors and even silently-wrong field values are
        // acceptable for flipped *data* bytes; crashes are not).
        for i in 0..bytes.len().min(400) {
            let mut bad = bytes.clone();
            bad[i] ^= 0x5a;
            let _ = decode_stream(&bad);
        }
    }

    #[test]
    fn binary_tail_byte_by_byte_equals_batch_decode() {
        let events = sample_events();
        let bytes = encode_stream(&events);
        let mut tail = BinaryTail::new();
        let mut got = Vec::new();
        for b in &bytes {
            got.extend(tail.feed(std::slice::from_ref(b)).unwrap());
        }
        tail.finish().unwrap();
        assert_eq!(got, events);
        assert_eq!(tail.frames(), events.len());
        assert_eq!(tail.buffered(), 0);
        assert_eq!(tail.header().unwrap().tagged, true);
    }

    #[test]
    fn binary_tail_reset_reads_a_fresh_stream() {
        let tagged = encode_stream(&sample_events());
        let untagged = encode_untagged_stream(&single_job_events());
        let mut tail = BinaryTail::new();
        let a = tail.feed(&tagged).unwrap();
        assert!(!a.is_empty());
        tail.reset();
        assert_eq!(tail.frames(), 0);
        let b = tail.feed(&untagged).unwrap();
        assert!(b.iter().all(|e| e.job_id == 0));
        tail.finish().unwrap();
    }

    #[test]
    fn codec_seam_parity() {
        let events = sample_events();
        for codec in [&NdjsonCodec as &dyn EventCodec, &BinaryCodec] {
            let bytes = codec.encode_stream(&events);
            assert!(codec.sniff(&bytes), "{} must sniff its own output", codec.name());
            let back = codec.decode_stream(&bytes).unwrap();
            assert_eq!(back, events, "{} round-trip", codec.name());
        }
        let nd = NdjsonCodec.encode_stream(&events);
        let bi = BinaryCodec.encode_stream(&events);
        assert_eq!(codec_for(&nd).name(), "ndjson");
        assert_eq!(codec_for(&bi).name(), "binary");
        assert!(bi.len() < nd.len(), "binary must be the compact encoding");
    }

    #[test]
    fn partition_frames_covers_the_stream_in_order() {
        let events = sample_events();
        let bytes = encode_stream(&events);
        for parts in [1usize, 2, 3, 8, 64, 10_000] {
            let ranges = partition_frames(&bytes, parts).unwrap();
            assert!(!ranges.is_empty());
            assert!(ranges.len() <= parts);
            assert_eq!(ranges.first().unwrap().0, HEADER_LEN);
            assert_eq!(ranges.last().unwrap().1, bytes.len());
            for w in ranges.windows(2) {
                assert_eq!(w[0].1, w[1].0, "ranges must be contiguous");
            }
            // Frame-aligned: every range decodes standalone, and the
            // in-order concatenation is exactly the sequential decode.
            let tagged = decode_header(&bytes).unwrap().tagged;
            let mut all = Vec::new();
            for &(s, e) in &ranges {
                let mut pos = s;
                while pos < e {
                    let f = decode_frame(&bytes[pos..e], tagged)
                        .unwrap()
                        .expect("range cut on a frame boundary");
                    all.push(TaggedEvent { job_id: f.job.unwrap_or(0), event: f.event });
                    pos += f.consumed;
                }
                assert_eq!(pos, e);
            }
            assert_eq!(all, events);
        }
        // Header-only capture: no frames, no ranges.
        assert!(partition_frames(&encode_header(true), 4).unwrap().is_empty());
        // Truncated capture: the scan errors like the strict decoder.
        assert!(partition_frames(&bytes[..bytes.len() - 1], 4).is_err());
    }

    #[test]
    fn binary_tail_counts_resyncs_and_rotation_drops() {
        let events = sample_events();
        let bytes = encode_stream(&events);
        let mut tail = BinaryTail::new();
        // Whole stream in one feed: nothing to resync.
        tail.feed(&bytes).unwrap();
        assert_eq!(tail.resyncs(), 0);
        assert_eq!(tail.dropped_partial(), 0);
        // Clean rotation (no buffered bytes) drops nothing.
        tail.reset();
        assert_eq!(tail.dropped_partial(), 0);
        // A chunk cut mid-frame: the next feed completes the buffered
        // frame and counts one resync.
        let cut = HEADER_LEN + 7;
        tail.feed(&bytes[..cut]).unwrap();
        assert!(tail.buffered() > 0);
        let got = tail.feed(&bytes[cut..]).unwrap();
        assert_eq!(got, events);
        assert_eq!(tail.resyncs(), 1);
        // Rotation mid-frame abandons the half-written frame.
        tail.reset();
        tail.feed(&bytes[..cut]).unwrap();
        assert!(tail.buffered() > 0);
        tail.reset();
        assert_eq!(tail.dropped_partial(), 1);
    }

    #[test]
    fn varint_boundaries() {
        for v in [0u32, 1, 127, 128, 16383, 16384, u32::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let mut r = Reader::new(&buf, 0);
            assert_eq!(r.varint().unwrap(), v);
            assert_eq!(r.pos, buf.len());
        }
        // 5-byte varint with high bits set past u32 range.
        let mut r = Reader::new(&[0xff, 0xff, 0xff, 0xff, 0x7f], 0);
        assert!(r.varint().is_err());
        // Varint that never terminates.
        let mut r = Reader::new(&[0x80, 0x80, 0x80, 0x80, 0x80], 0);
        assert!(r.varint().is_err());
    }
}
