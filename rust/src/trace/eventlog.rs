//! Spark-style streaming event log: newline-delimited JSON events emitted
//! *while a job runs* (task start / task end / resource sample / injection),
//! consumed by the streaming coordinator (`coordinator::streaming`).
//!
//! This mirrors how the paper's scheduler "periodically collects information
//! from Spark and AG log files" — the analyzer can follow an event stream
//! instead of waiting for the full offline trace.

use super::model::*;
use crate::util::json::{Json, JsonError};

/// One line of the event log.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// Job metadata — first line of every log.
    JobStart { job_name: String, workload: String, cluster: ClusterInfo },
    StageSubmitted { stage_id: u64, name: String, num_tasks: usize },
    TaskStart { task_id: u64, stage_id: u64, node: usize, executor: usize, time: f64, locality: Locality },
    /// Task completion with the full metric set (Spark reports metrics on
    /// completion, not incrementally).
    TaskEnd(TaskRecord),
    /// One 1 Hz sample from a node's mpstat/iostat/sar equivalent.
    ResourceSample { node: usize, time: f64, cpu: f64, disk: f64, net_bytes: f64 },
    /// Anomaly-generator activity (ground truth channel, separate log file
    /// in the paper; merged into one stream here with its own event type).
    Injection(InjectionRecord),
    JobEnd { time: f64 },
}

impl Event {
    pub fn encode(&self) -> Json {
        let mut o = Json::obj();
        match self {
            Event::JobStart { job_name, workload, cluster } => {
                o.set("event", "job_start".into());
                o.set("job_name", job_name.as_str().into());
                o.set("workload", workload.as_str().into());
                o.set("nodes", cluster.nodes.into());
                o.set("cores_per_node", cluster.cores_per_node.into());
                o.set("executors_per_node", cluster.executors_per_node.into());
            }
            Event::StageSubmitted { stage_id, name, num_tasks } => {
                o.set("event", "stage_submitted".into());
                o.set("stage_id", (*stage_id).into());
                o.set("name", name.as_str().into());
                o.set("num_tasks", (*num_tasks).into());
            }
            Event::TaskStart { task_id, stage_id, node, executor, time, locality } => {
                o.set("event", "task_start".into());
                o.set("task_id", (*task_id).into());
                o.set("stage_id", (*stage_id).into());
                o.set("node", (*node).into());
                o.set("executor", (*executor).into());
                o.set("time", (*time).into());
                o.set("locality", locality.as_str().into());
            }
            Event::TaskEnd(t) => {
                o.set("event", "task_end".into());
                o.set("task_id", t.task_id.into());
                o.set("stage_id", t.stage_id.into());
                o.set("node", t.node.into());
                o.set("executor", t.executor.into());
                o.set("start", t.start.into());
                o.set("finish", t.finish.into());
                o.set("locality", t.locality.as_str().into());
                o.set("bytes_read", t.bytes_read.into());
                o.set("shuffle_read_bytes", t.shuffle_read_bytes.into());
                o.set("shuffle_write_bytes", t.shuffle_write_bytes.into());
                o.set("memory_bytes_spilled", t.memory_bytes_spilled.into());
                o.set("disk_bytes_spilled", t.disk_bytes_spilled.into());
                o.set("jvm_gc_time", t.jvm_gc_time.into());
                o.set("serialize_time", t.serialize_time.into());
                o.set("deserialize_time", t.deserialize_time.into());
            }
            Event::ResourceSample { node, time, cpu, disk, net_bytes } => {
                o.set("event", "resource_sample".into());
                o.set("node", (*node).into());
                o.set("time", (*time).into());
                o.set("cpu", (*cpu).into());
                o.set("disk", (*disk).into());
                o.set("net_bytes", (*net_bytes).into());
            }
            Event::Injection(i) => {
                o.set("event", "injection".into());
                o.set("node", i.node.into());
                o.set("kind", i.kind.as_str().into());
                o.set("t_start", i.t_start.into());
                o.set("t_end", i.t_end.into());
            }
            Event::JobEnd { time } => {
                o.set("event", "job_end".into());
                o.set("time", (*time).into());
            }
        }
        o
    }

    pub fn decode(j: &Json) -> Result<Event, JsonError> {
        let bad = |m: &str| JsonError { offset: 0, message: m.to_string() };
        Ok(match j.req_str("event")? {
            "job_start" => Event::JobStart {
                job_name: j.req_str("job_name")?.to_string(),
                workload: j.req_str("workload")?.to_string(),
                cluster: ClusterInfo {
                    nodes: j.req_usize("nodes")?,
                    cores_per_node: j.req_usize("cores_per_node")?,
                    executors_per_node: j.req_usize("executors_per_node")?,
                },
            },
            "stage_submitted" => Event::StageSubmitted {
                stage_id: j.req_u64("stage_id")?,
                name: j.req_str("name")?.to_string(),
                num_tasks: j.req_usize("num_tasks")?,
            },
            "task_start" => Event::TaskStart {
                task_id: j.req_u64("task_id")?,
                stage_id: j.req_u64("stage_id")?,
                node: j.req_usize("node")?,
                executor: j.req_usize("executor")?,
                time: j.req_f64("time")?,
                locality: Locality::from_str(j.req_str("locality")?)
                    .ok_or_else(|| bad("bad locality"))?,
            },
            "task_end" => Event::TaskEnd(TaskRecord {
                task_id: j.req_u64("task_id")?,
                stage_id: j.req_u64("stage_id")?,
                node: j.req_usize("node")?,
                executor: j.req_usize("executor")?,
                start: j.req_f64("start")?,
                finish: j.req_f64("finish")?,
                locality: Locality::from_str(j.req_str("locality")?)
                    .ok_or_else(|| bad("bad locality"))?,
                bytes_read: j.req_f64("bytes_read")?,
                shuffle_read_bytes: j.req_f64("shuffle_read_bytes")?,
                shuffle_write_bytes: j.req_f64("shuffle_write_bytes")?,
                memory_bytes_spilled: j.req_f64("memory_bytes_spilled")?,
                disk_bytes_spilled: j.req_f64("disk_bytes_spilled")?,
                jvm_gc_time: j.req_f64("jvm_gc_time")?,
                serialize_time: j.req_f64("serialize_time")?,
                deserialize_time: j.req_f64("deserialize_time")?,
            }),
            "resource_sample" => Event::ResourceSample {
                node: j.req_usize("node")?,
                time: j.req_f64("time")?,
                cpu: j.req_f64("cpu")?,
                disk: j.req_f64("disk")?,
                net_bytes: j.req_f64("net_bytes")?,
            },
            "injection" => Event::Injection(InjectionRecord {
                node: j.req_usize("node")?,
                kind: AnomalyKind::from_str(j.req_str("kind")?)
                    .ok_or_else(|| bad("bad anomaly kind"))?,
                t_start: j.req_f64("t_start")?,
                t_end: j.req_f64("t_end")?,
            }),
            "job_end" => Event::JobEnd { time: j.req_f64("time")? },
            other => return Err(bad(&format!("unknown event '{other}'"))),
        })
    }

    /// The event's timestamp, for events that carry one. `JobStart` and
    /// `StageSubmitted` are control events without a clock reading — the
    /// live job-lifecycle watermark skips them.
    pub fn time(&self) -> Option<f64> {
        match self {
            Event::JobStart { .. } | Event::StageSubmitted { .. } => None,
            Event::TaskStart { time, .. } => Some(*time),
            Event::TaskEnd(t) => Some(t.finish),
            Event::ResourceSample { time, .. } => Some(*time),
            Event::Injection(i) => Some(i.t_start),
            Event::JobEnd { time } => Some(*time),
        }
    }
}

/// An [`Event`] tagged with the job it belongs to — one line of a
/// *multi-job* event log, where streams from many concurrent jobs are
/// interleaved into a single file (the paper's scheduler watches one log
/// per application; a busy cluster produces many at once). The JSON form
/// is the plain event object with an extra `"job"` field, so a single-job
/// consumer that ignores unknown fields still parses each line.
#[derive(Debug, Clone, PartialEq)]
pub struct TaggedEvent {
    pub job_id: u64,
    pub event: Event,
}

impl TaggedEvent {
    pub fn encode(&self) -> Json {
        let mut o = self.event.encode();
        o.set("job", self.job_id.into());
        o
    }

    pub fn decode(j: &Json) -> Result<TaggedEvent, JsonError> {
        Ok(TaggedEvent { job_id: j.req_u64("job")?, event: Event::decode(j)? })
    }
}

/// Serialize a trace to the time-keyed event list: `(time, tiebreak,
/// event)` triples, sorted. The tiebreak keeps job start first, stage
/// submission before its tasks, and job end last within one instant.
/// [`trace_to_events`] strips the keys; [`interleave_jobs`] merges the
/// keyed streams of many jobs.
pub fn trace_to_keyed_events(trace: &JobTrace) -> Vec<(f64, u8, Event)> {
    let mut events: Vec<(f64, u8, Event)> = Vec::new();
    events.push((
        -1.0,
        0,
        Event::JobStart {
            job_name: trace.job_name.clone(),
            workload: trace.workload.clone(),
            cluster: trace.cluster.clone(),
        },
    ));
    for s in &trace.stages {
        let t0 = s
            .tasks
            .iter()
            .filter_map(|tid| trace.tasks.iter().find(|t| t.task_id == *tid))
            .map(|t| t.start)
            .fold(f64::INFINITY, f64::min);
        let t0 = if t0.is_finite() { t0 } else { 0.0 };
        events.push((
            t0,
            1,
            Event::StageSubmitted {
                stage_id: s.stage_id,
                name: s.name.clone(),
                num_tasks: s.tasks.len(),
            },
        ));
    }
    for t in &trace.tasks {
        events.push((
            t.start,
            2,
            Event::TaskStart {
                task_id: t.task_id,
                stage_id: t.stage_id,
                node: t.node,
                executor: t.executor,
                time: t.start,
                locality: t.locality,
            },
        ));
        events.push((t.finish, 3, Event::TaskEnd(t.clone())));
    }
    for s in &trace.node_series {
        for (i, ((&cpu, &disk), &net)) in
            s.cpu.iter().zip(&s.disk).zip(&s.net_bytes).enumerate()
        {
            let time = i as f64 * s.period;
            events.push((
                time,
                2,
                Event::ResourceSample { node: s.node, time, cpu, disk, net_bytes: net },
            ));
        }
    }
    for i in &trace.injections {
        events.push((i.t_start, 2, Event::Injection(i.clone())));
    }
    events.push((trace.makespan(), 9, Event::JobEnd { time: trace.makespan() }));
    events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    events
}

/// Serialize a trace to an event-log stream, ordered by time (job start,
/// then interleaved stage/task/sample/injection events, then job end).
pub fn trace_to_events(trace: &JobTrace) -> Vec<Event> {
    trace_to_keyed_events(trace).into_iter().map(|(_, _, e)| e).collect()
}

/// Merge the event streams of several jobs into one interleaved, job-tagged
/// stream ordered by event time. Within a job the relative event order is
/// exactly that of [`trace_to_events`]; across jobs, ties break by job id
/// then original position, so the result is deterministic.
pub fn interleave_jobs(jobs: &[(u64, &JobTrace)]) -> Vec<TaggedEvent> {
    let mut keyed: Vec<(f64, u8, u64, usize, Event)> = Vec::new();
    for (job_id, trace) in jobs {
        for (pos, (t, tie, e)) in trace_to_keyed_events(trace).into_iter().enumerate() {
            keyed.push((t, tie, *job_id, pos, e));
        }
    }
    keyed.sort_by(|a, b| {
        a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)).then(a.3.cmp(&b.3))
    });
    keyed
        .into_iter()
        .map(|(_, _, job_id, _, event)| TaggedEvent { job_id, event })
        .collect()
}

/// Write events as newline-delimited JSON.
pub fn write_events(events: &[Event], path: &str) -> anyhow::Result<()> {
    let mut out = String::new();
    for e in events {
        out.push_str(&e.encode().to_string());
        out.push('\n');
    }
    std::fs::write(path, out)?;
    Ok(())
}

/// Parse newline-delimited JSON events (skipping blank lines) through the
/// zero-allocation decoder ([`crate::trace::codec::decode_event_line`]).
/// A `"job"` tag, if present, is ignored — use [`parse_tagged_events`] for
/// multi-job logs.
pub fn parse_events(text: &str) -> Result<Vec<Event>, JsonError> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| super::codec::decode_event_line(l).map(|d| d.event))
        .collect()
}

/// Write job-tagged events as newline-delimited JSON.
pub fn write_tagged_events(events: &[TaggedEvent], path: &str) -> anyhow::Result<()> {
    let mut out = String::new();
    for e in events {
        out.push_str(&e.encode().to_string());
        out.push('\n');
    }
    std::fs::write(path, out)?;
    Ok(())
}

/// Parse a newline-delimited multi-job event log. A fully *untagged* log
/// (no `"job"` fields anywhere) is assigned to job 0, so single-job logs
/// remain valid input for the multi-job service. Mixing tagged and
/// untagged lines is ambiguous — untagged lines would silently merge into
/// a real job 0 — and is rejected.
pub fn parse_tagged_events(text: &str) -> Result<Vec<TaggedEvent>, JsonError> {
    let mut saw_tagged = false;
    let mut saw_untagged = false;
    let mut out = Vec::new();
    for l in text.lines().filter(|l| !l.trim().is_empty()) {
        let d = super::codec::decode_event_line(l)?;
        if d.has_job {
            saw_tagged = true;
            let job_id = d.require_job()?;
            out.push(TaggedEvent { job_id, event: d.event });
        } else {
            saw_untagged = true;
            out.push(TaggedEvent { job_id: 0, event: d.event });
        }
        if saw_tagged && saw_untagged {
            return Err(JsonError {
                offset: 0,
                message: "mixed tagged and untagged event lines: tag every line with \
                          \"job\" or none"
                    .to_string(),
            });
        }
    }
    Ok(out)
}

/// Incremental NDJSON reader — the parsing half of every live
/// [`crate::live::source::EventSource`]: feed it raw byte chunks exactly
/// as they come off a growing file, a socket, or stdin (chunks may end
/// mid-line, even mid-UTF-8-sequence), get back the complete events. The
/// trailing partial line stays buffered until its newline arrives or
/// [`NdjsonTail::finish`] flushes it at end of stream.
///
/// Tagged/untagged handling matches [`parse_tagged_events`]: a fully
/// untagged stream is job 0, and mixing tagged with untagged lines is
/// rejected as ambiguous. A [`NdjsonTail::reset`] (log rotation) starts a
/// fresh stream — buffer *and* tag mode are cleared.
#[derive(Debug, Default)]
pub struct NdjsonTail {
    buf: Vec<u8>,
    saw_tagged: bool,
    saw_untagged: bool,
    lines: usize,
}

impl NdjsonTail {
    pub fn new() -> Self {
        Self::default()
    }

    /// Consume one chunk; returns every event whose line completed.
    pub fn feed(&mut self, chunk: &[u8]) -> Result<Vec<TaggedEvent>, JsonError> {
        self.buf.extend_from_slice(chunk);
        let Some(last_nl) = self.buf.iter().rposition(|&b| b == b'\n') else {
            return Ok(Vec::new());
        };
        let complete: Vec<u8> = self.buf.drain(..=last_nl).collect();
        let mut out = Vec::new();
        for raw in complete.split(|&b| b == b'\n') {
            let text = String::from_utf8_lossy(raw);
            let line = text.trim();
            if line.is_empty() {
                continue;
            }
            out.push(self.parse_line(line)?);
        }
        Ok(out)
    }

    /// End of stream: parse a trailing unterminated line, if any.
    pub fn finish(&mut self) -> Result<Option<TaggedEvent>, JsonError> {
        let raw = std::mem::take(&mut self.buf);
        let text = String::from_utf8_lossy(&raw);
        let line = text.trim();
        if line.is_empty() {
            return Ok(None);
        }
        self.parse_line(line).map(Some)
    }

    fn parse_line(&mut self, line: &str) -> Result<TaggedEvent, JsonError> {
        // The zero-allocation decoder (`codec::decode_event_line`) is the
        // reason a live tail keeps up with ingest: no Json DOM per line.
        let d = super::codec::decode_event_line(line)?;
        if d.has_job {
            self.saw_tagged = true;
        } else {
            self.saw_untagged = true;
        }
        if self.saw_tagged && self.saw_untagged {
            return Err(JsonError {
                offset: 0,
                message: "mixed tagged and untagged event lines: tag every line with \
                          \"job\" or none"
                    .to_string(),
            });
        }
        self.lines += 1;
        if d.has_job {
            Ok(TaggedEvent { job_id: d.require_job()?, event: d.event })
        } else {
            Ok(TaggedEvent { job_id: 0, event: d.event })
        }
    }

    /// Start over on a fresh stream (log rotation / reconnect).
    pub fn reset(&mut self) {
        self.buf.clear();
        self.saw_tagged = false;
        self.saw_untagged = false;
        self.lines = 0;
    }

    /// Bytes held for the current partial line.
    pub fn buffered(&self) -> usize {
        self.buf.len()
    }

    /// Complete lines parsed since creation or the last [`NdjsonTail::reset`].
    pub fn lines(&self) -> usize {
        self.lines
    }

    /// Which tag mode the stream locked into: `Some(true)` once a tagged
    /// line parsed, `Some(false)` once an untagged one did, `None` before
    /// any event. `bigroots convert` uses this to mirror the source's tag
    /// mode into the binary stream header.
    pub fn tag_mode(&self) -> Option<bool> {
        if self.saw_tagged {
            Some(true)
        } else if self.saw_untagged {
            Some(false)
        } else {
            None
        }
    }
}

/// Split an interleaved stream into per-job event sequences, preserving
/// each job's internal order. Jobs are returned sorted by id.
pub fn demux_jobs(events: &[TaggedEvent]) -> Vec<(u64, Vec<Event>)> {
    let mut per_job: Vec<(u64, Vec<Event>)> = Vec::new();
    for e in events {
        match per_job.iter().position(|(id, _)| *id == e.job_id) {
            Some(idx) => per_job[idx].1.push(e.event.clone()),
            None => per_job.push((e.job_id, vec![e.event.clone()])),
        }
    }
    per_job.sort_by_key(|(id, _)| *id);
    per_job
}

/// Rebuild a full [`JobTrace`] from an event stream — the inverse of
/// [`trace_to_events`]. Used by the streaming coordinator when asked to
/// persist what it saw.
pub fn events_to_trace(events: &[Event]) -> Result<JobTrace, String> {
    let mut job_name = String::new();
    let mut workload = String::new();
    let mut cluster: Option<ClusterInfo> = None;
    let mut stages: Vec<StageRecord> = Vec::new();
    let mut tasks: Vec<TaskRecord> = Vec::new();
    let mut samples: Vec<(usize, f64, f64, f64, f64)> = Vec::new();
    let mut injections: Vec<InjectionRecord> = Vec::new();

    for e in events {
        match e {
            Event::JobStart { job_name: jn, workload: w, cluster: c } => {
                job_name = jn.clone();
                workload = w.clone();
                cluster = Some(c.clone());
            }
            Event::StageSubmitted { stage_id, name, .. } => {
                if !stages.iter().any(|s| s.stage_id == *stage_id) {
                    stages.push(StageRecord {
                        stage_id: *stage_id,
                        name: name.clone(),
                        tasks: Vec::new(),
                    });
                }
            }
            Event::TaskEnd(t) => tasks.push(t.clone()),
            Event::ResourceSample { node, time, cpu, disk, net_bytes } => {
                samples.push((*node, *time, *cpu, *disk, *net_bytes));
            }
            Event::Injection(i) => injections.push(i.clone()),
            Event::TaskStart { .. } | Event::JobEnd { .. } => {}
        }
    }
    let cluster = cluster.ok_or("missing job_start event")?;
    // Attach tasks to stages.
    tasks.sort_by_key(|t| t.task_id);
    for t in &tasks {
        let stage = stages
            .iter_mut()
            .find(|s| s.stage_id == t.stage_id)
            .ok_or_else(|| format!("task {} references unknown stage {}", t.task_id, t.stage_id))?;
        stage.tasks.push(t.task_id);
    }
    // Rebuild node series on a 1-second grid.
    let period = 1.0;
    let mut node_series: Vec<NodeSeries> =
        (0..cluster.nodes).map(|n| NodeSeries::empty(n, period)).collect();
    samples.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)));
    for (node, _time, cpu, disk, net) in samples {
        if node >= node_series.len() {
            return Err(format!("sample for unknown node {node}"));
        }
        node_series[node].cpu.push(cpu);
        node_series[node].disk.push(disk);
        node_series[node].net_bytes.push(net);
    }
    let trace = JobTrace { job_name, workload, cluster, stages, tasks, node_series, injections };
    trace.validate()?;
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> JobTrace {
        // Reuse the codec test fixture shape.
        let j = super::super::codec::encode(&fixture());
        super::super::codec::decode(&j).unwrap()
    }

    fn fixture() -> JobTrace {
        JobTrace {
            job_name: "j".into(),
            workload: "w".into(),
            cluster: ClusterInfo { nodes: 2, cores_per_node: 4, executors_per_node: 1 },
            stages: vec![StageRecord { stage_id: 0, name: "s".into(), tasks: vec![0, 1] }],
            tasks: vec![
                TaskRecord {
                    task_id: 0,
                    stage_id: 0,
                    node: 0,
                    executor: 0,
                    start: 0.0,
                    finish: 1.0,
                    locality: Locality::NodeLocal,
                    bytes_read: 10.0,
                    shuffle_read_bytes: 1.0,
                    shuffle_write_bytes: 2.0,
                    memory_bytes_spilled: 0.0,
                    disk_bytes_spilled: 0.0,
                    jvm_gc_time: 0.1,
                    serialize_time: 0.01,
                    deserialize_time: 0.02,
                },
                TaskRecord {
                    task_id: 1,
                    stage_id: 0,
                    node: 1,
                    executor: 0,
                    start: 0.5,
                    finish: 2.5,
                    locality: Locality::Any,
                    bytes_read: 20.0,
                    shuffle_read_bytes: 3.0,
                    shuffle_write_bytes: 4.0,
                    memory_bytes_spilled: 5.0,
                    disk_bytes_spilled: 6.0,
                    jvm_gc_time: 0.2,
                    serialize_time: 0.03,
                    deserialize_time: 0.04,
                },
            ],
            node_series: vec![
                NodeSeries { node: 0, period: 1.0, cpu: vec![0.1, 0.2], disk: vec![0.3, 0.4], net_bytes: vec![5.0, 6.0] },
                NodeSeries { node: 1, period: 1.0, cpu: vec![0.5, 0.6], disk: vec![0.7, 0.8], net_bytes: vec![7.0, 8.0] },
            ],
            injections: vec![InjectionRecord { node: 0, kind: AnomalyKind::Cpu, t_start: 0.2, t_end: 0.9 }],
        }
    }

    #[test]
    fn event_encode_decode_roundtrip() {
        let t = sample_trace();
        for e in trace_to_events(&t) {
            let back = Event::decode(&e.encode()).unwrap();
            assert_eq!(e, back);
        }
    }

    #[test]
    fn trace_events_trace_roundtrip() {
        let t = sample_trace();
        let events = trace_to_events(&t);
        let back = events_to_trace(&events).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn ndjson_roundtrip() {
        let t = sample_trace();
        let events = trace_to_events(&t);
        let text: String =
            events.iter().map(|e| e.encode().to_string() + "\n").collect();
        let parsed = parse_events(&text).unwrap();
        assert_eq!(events, parsed);
    }

    #[test]
    fn events_are_time_ordered() {
        let t = sample_trace();
        let events = trace_to_events(&t);
        assert!(matches!(events.first(), Some(Event::JobStart { .. })));
        assert!(matches!(events.last(), Some(Event::JobEnd { .. })));
        // TaskEnd for task 0 (finish=1.0) precedes TaskEnd for task 1 (2.5).
        let pos0 = events
            .iter()
            .position(|e| matches!(e, Event::TaskEnd(t) if t.task_id == 0))
            .unwrap();
        let pos1 = events
            .iter()
            .position(|e| matches!(e, Event::TaskEnd(t) if t.task_id == 1))
            .unwrap();
        assert!(pos0 < pos1);
    }

    #[test]
    fn missing_job_start_is_error() {
        let t = sample_trace();
        let events: Vec<Event> = trace_to_events(&t)
            .into_iter()
            .filter(|e| !matches!(e, Event::JobStart { .. }))
            .collect();
        assert!(events_to_trace(&events).is_err());
    }

    #[test]
    fn unknown_event_rejected() {
        let j = Json::parse(r#"{"event":"wat"}"#).unwrap();
        assert!(Event::decode(&j).is_err());
    }

    #[test]
    fn tagged_event_roundtrip() {
        let t = sample_trace();
        for e in trace_to_events(&t) {
            let tagged = TaggedEvent { job_id: 7, event: e };
            let back = TaggedEvent::decode(&tagged.encode()).unwrap();
            assert_eq!(tagged, back);
        }
    }

    #[test]
    fn interleave_preserves_per_job_order_and_demuxes() {
        let a = sample_trace();
        let mut b = sample_trace();
        b.job_name = "j2".into();
        let merged = interleave_jobs(&[(1, &a), (2, &b)]);
        assert_eq!(merged.len(), trace_to_events(&a).len() + trace_to_events(&b).len());
        let per_job = demux_jobs(&merged);
        assert_eq!(per_job.len(), 2);
        assert_eq!(per_job[0].0, 1);
        assert_eq!(per_job[0].1, trace_to_events(&a));
        assert_eq!(per_job[1].1, trace_to_events(&b));
        // Each per-job stream rebuilds its trace.
        assert_eq!(events_to_trace(&per_job[0].1).unwrap(), a);
        assert_eq!(events_to_trace(&per_job[1].1).unwrap(), b);
    }

    #[test]
    fn tagged_ndjson_roundtrip_and_untagged_default() {
        let t = sample_trace();
        let merged = interleave_jobs(&[(3, &t), (9, &t)]);
        let text: String = merged.iter().map(|e| e.encode().to_string() + "\n").collect();
        let parsed = parse_tagged_events(&text).unwrap();
        assert_eq!(merged, parsed);
        // An untagged single-job log parses with job id 0.
        let plain: String =
            trace_to_events(&t).iter().map(|e| e.encode().to_string() + "\n").collect();
        let parsed = parse_tagged_events(&plain).unwrap();
        assert!(parsed.iter().all(|e| e.job_id == 0));
        assert_eq!(parsed.len(), trace_to_events(&t).len());
    }

    #[test]
    fn mixed_tagged_and_untagged_log_rejected() {
        let t = sample_trace();
        let tagged = interleave_jobs(&[(0, &t)]);
        let mut text: String =
            tagged.iter().map(|e| e.encode().to_string() + "\n").collect();
        // Append one untagged line: ambiguous with the real job 0 above.
        text.push_str(&trace_to_events(&t)[0].encode().to_string());
        text.push('\n');
        assert!(parse_tagged_events(&text).is_err());
    }

    #[test]
    fn ndjson_tail_byte_by_byte_equals_batch_parse() {
        let t = sample_trace();
        let merged = interleave_jobs(&[(3, &t), (9, &t)]);
        let text: String = merged.iter().map(|e| e.encode().to_string() + "\n").collect();
        let mut tail = NdjsonTail::new();
        let mut got = Vec::new();
        for b in text.as_bytes() {
            got.extend(tail.feed(std::slice::from_ref(b)).unwrap());
        }
        assert_eq!(tail.finish().unwrap(), None);
        assert_eq!(got, merged);
        assert_eq!(tail.lines(), merged.len());
        assert_eq!(tail.buffered(), 0);
    }

    #[test]
    fn ndjson_tail_flushes_unterminated_final_line() {
        let t = sample_trace();
        let events = trace_to_events(&t);
        let mut text: String =
            events.iter().map(|e| e.encode().to_string() + "\n").collect();
        text.pop(); // drop the final newline
        let mut tail = NdjsonTail::new();
        let mut got = tail.feed(text.as_bytes()).unwrap();
        assert_eq!(got.len(), events.len() - 1);
        assert!(tail.buffered() > 0);
        got.extend(tail.finish().unwrap());
        let want: Vec<TaggedEvent> =
            events.into_iter().map(|event| TaggedEvent { job_id: 0, event }).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn ndjson_tail_rejects_mixed_until_reset() {
        let t = sample_trace();
        let tagged_line = interleave_jobs(&[(0, &t)])[0].encode().to_string() + "\n";
        let untagged_line = trace_to_events(&t)[0].encode().to_string() + "\n";
        let mut tail = NdjsonTail::new();
        assert_eq!(tail.feed(tagged_line.as_bytes()).unwrap().len(), 1);
        assert!(tail.feed(untagged_line.as_bytes()).is_err());
        // A rotation resets the tag mode: the untagged stream now parses.
        tail.reset();
        let got = tail.feed(untagged_line.as_bytes()).unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].job_id, 0);
    }

    #[test]
    fn event_time_accessor() {
        let t = sample_trace();
        for e in trace_to_events(&t) {
            match &e {
                Event::JobStart { .. } | Event::StageSubmitted { .. } => {
                    assert_eq!(e.time(), None)
                }
                Event::TaskEnd(task) => assert_eq!(e.time(), Some(task.finish)),
                Event::JobEnd { time } => assert_eq!(e.time(), Some(*time)),
                _ => assert!(e.time().is_some()),
            }
        }
    }
}
