//! JSON codec for [`JobTrace`] — the offline-log workflow of the paper:
//! the simulator (or a converter from real Spark event logs) writes a trace
//! file, the analyzer reads it back. Round-trip is exact for all fields
//! (f64 values serialize with shortest-roundtrip formatting).

use super::model::*;
use crate::util::json::{Json, JsonError};

const FORMAT_VERSION: u64 = 1;

/// Encode a trace to a JSON value.
pub fn encode(trace: &JobTrace) -> Json {
    let mut root = Json::obj();
    root.set("version", FORMAT_VERSION.into());
    root.set("job_name", trace.job_name.as_str().into());
    root.set("workload", trace.workload.as_str().into());
    let mut cluster = Json::obj();
    cluster.set("nodes", trace.cluster.nodes.into());
    cluster.set("cores_per_node", trace.cluster.cores_per_node.into());
    cluster.set("executors_per_node", trace.cluster.executors_per_node.into());
    root.set("cluster", cluster);

    root.set(
        "stages",
        Json::Arr(
            trace
                .stages
                .iter()
                .map(|s| {
                    let mut o = Json::obj();
                    o.set("stage_id", s.stage_id.into());
                    o.set("name", s.name.as_str().into());
                    o.set("tasks", s.tasks.clone().into());
                    o
                })
                .collect(),
        ),
    );

    root.set(
        "tasks",
        Json::Arr(
            trace
                .tasks
                .iter()
                .map(|t| {
                    let mut o = Json::obj();
                    o.set("task_id", t.task_id.into());
                    o.set("stage_id", t.stage_id.into());
                    o.set("node", t.node.into());
                    o.set("executor", t.executor.into());
                    o.set("start", t.start.into());
                    o.set("finish", t.finish.into());
                    o.set("locality", t.locality.as_str().into());
                    o.set("bytes_read", t.bytes_read.into());
                    o.set("shuffle_read_bytes", t.shuffle_read_bytes.into());
                    o.set("shuffle_write_bytes", t.shuffle_write_bytes.into());
                    o.set("memory_bytes_spilled", t.memory_bytes_spilled.into());
                    o.set("disk_bytes_spilled", t.disk_bytes_spilled.into());
                    o.set("jvm_gc_time", t.jvm_gc_time.into());
                    o.set("serialize_time", t.serialize_time.into());
                    o.set("deserialize_time", t.deserialize_time.into());
                    o
                })
                .collect(),
        ),
    );

    root.set(
        "node_series",
        Json::Arr(
            trace
                .node_series
                .iter()
                .map(|s| {
                    let mut o = Json::obj();
                    o.set("node", s.node.into());
                    o.set("period", s.period.into());
                    o.set("cpu", s.cpu.clone().into());
                    o.set("disk", s.disk.clone().into());
                    o.set("net_bytes", s.net_bytes.clone().into());
                    o
                })
                .collect(),
        ),
    );

    root.set(
        "injections",
        Json::Arr(
            trace
                .injections
                .iter()
                .map(|i| {
                    let mut o = Json::obj();
                    o.set("node", i.node.into());
                    o.set("kind", i.kind.as_str().into());
                    o.set("t_start", i.t_start.into());
                    o.set("t_end", i.t_end.into());
                    o
                })
                .collect(),
        ),
    );
    root
}

fn bad(msg: &str) -> JsonError {
    JsonError { offset: 0, message: msg.to_string() }
}

fn f64_arr(j: &Json, key: &str) -> Result<Vec<f64>, JsonError> {
    j.req_arr(key)?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| bad(&format!("{key}: non-number element"))))
        .collect()
}

/// Decode a trace from a JSON value, validating structure.
pub fn decode(j: &Json) -> Result<JobTrace, JsonError> {
    let version = j.req_u64("version")?;
    if version != FORMAT_VERSION {
        return Err(bad(&format!("unsupported trace version {version}")));
    }
    let cluster_j = j.get("cluster");
    let cluster = ClusterInfo {
        nodes: cluster_j.req_usize("nodes")?,
        cores_per_node: cluster_j.req_usize("cores_per_node")?,
        executors_per_node: cluster_j.req_usize("executors_per_node")?,
    };

    let stages = j
        .req_arr("stages")?
        .iter()
        .map(|s| {
            Ok(StageRecord {
                stage_id: s.req_u64("stage_id")?,
                name: s.req_str("name")?.to_string(),
                tasks: s
                    .req_arr("tasks")?
                    .iter()
                    .map(|t| t.as_u64().ok_or_else(|| bad("stage.tasks: non-integer")))
                    .collect::<Result<_, _>>()?,
            })
        })
        .collect::<Result<Vec<_>, JsonError>>()?;

    let tasks = j
        .req_arr("tasks")?
        .iter()
        .map(|t| {
            Ok(TaskRecord {
                task_id: t.req_u64("task_id")?,
                stage_id: t.req_u64("stage_id")?,
                node: t.req_usize("node")?,
                executor: t.req_usize("executor")?,
                start: t.req_f64("start")?,
                finish: t.req_f64("finish")?,
                locality: Locality::from_str(t.req_str("locality")?)
                    .ok_or_else(|| bad("bad locality"))?,
                bytes_read: t.req_f64("bytes_read")?,
                shuffle_read_bytes: t.req_f64("shuffle_read_bytes")?,
                shuffle_write_bytes: t.req_f64("shuffle_write_bytes")?,
                memory_bytes_spilled: t.req_f64("memory_bytes_spilled")?,
                disk_bytes_spilled: t.req_f64("disk_bytes_spilled")?,
                jvm_gc_time: t.req_f64("jvm_gc_time")?,
                serialize_time: t.req_f64("serialize_time")?,
                deserialize_time: t.req_f64("deserialize_time")?,
            })
        })
        .collect::<Result<Vec<_>, JsonError>>()?;

    let node_series = j
        .req_arr("node_series")?
        .iter()
        .map(|s| {
            Ok(NodeSeries {
                node: s.req_usize("node")?,
                period: s.req_f64("period")?,
                cpu: f64_arr(s, "cpu")?,
                disk: f64_arr(s, "disk")?,
                net_bytes: f64_arr(s, "net_bytes")?,
            })
        })
        .collect::<Result<Vec<_>, JsonError>>()?;

    let injections = j
        .req_arr("injections")?
        .iter()
        .map(|i| {
            Ok(InjectionRecord {
                node: i.req_usize("node")?,
                kind: AnomalyKind::from_str(i.req_str("kind")?)
                    .ok_or_else(|| bad("bad anomaly kind"))?,
                t_start: i.req_f64("t_start")?,
                t_end: i.req_f64("t_end")?,
            })
        })
        .collect::<Result<Vec<_>, JsonError>>()?;

    let trace = JobTrace {
        job_name: j.req_str("job_name")?.to_string(),
        workload: j.req_str("workload")?.to_string(),
        cluster,
        stages,
        tasks,
        node_series,
        injections,
    };
    trace.validate().map_err(|e| bad(&e))?;
    Ok(trace)
}

/// Write a trace to a file (pretty JSON).
pub fn save(trace: &JobTrace, path: &str) -> anyhow::Result<()> {
    std::fs::write(path, encode(trace).to_pretty())?;
    Ok(())
}

/// Read a trace from a file.
pub fn load(path: &str) -> anyhow::Result<JobTrace> {
    let text = std::fs::read_to_string(path)?;
    let j = Json::parse(&text)?;
    Ok(decode(&j)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> JobTrace {
        JobTrace {
            job_name: "naivebayes-large".into(),
            workload: "NaiveBayes".into(),
            cluster: ClusterInfo { nodes: 2, cores_per_node: 16, executors_per_node: 2 },
            stages: vec![
                StageRecord { stage_id: 0, name: "map".into(), tasks: vec![0, 1] },
                StageRecord { stage_id: 1, name: "reduce".into(), tasks: vec![2] },
            ],
            tasks: vec![
                TaskRecord {
                    task_id: 0,
                    stage_id: 0,
                    node: 0,
                    executor: 1,
                    start: 0.0,
                    finish: 2.25,
                    locality: Locality::ProcessLocal,
                    bytes_read: 1048576.0,
                    shuffle_read_bytes: 0.0,
                    shuffle_write_bytes: 2048.5,
                    memory_bytes_spilled: 0.0,
                    disk_bytes_spilled: 0.0,
                    jvm_gc_time: 0.125,
                    serialize_time: 0.011,
                    deserialize_time: 0.041,
                },
                TaskRecord {
                    task_id: 1,
                    stage_id: 0,
                    node: 1,
                    executor: 0,
                    start: 0.1,
                    finish: 5.5,
                    locality: Locality::Any,
                    bytes_read: 2097152.0,
                    shuffle_read_bytes: 0.0,
                    shuffle_write_bytes: 4096.0,
                    memory_bytes_spilled: 1024.0,
                    disk_bytes_spilled: 512.0,
                    jvm_gc_time: 1.5,
                    serialize_time: 0.02,
                    deserialize_time: 0.03,
                },
                TaskRecord {
                    task_id: 2,
                    stage_id: 1,
                    node: 0,
                    executor: 0,
                    start: 6.0,
                    finish: 8.0,
                    locality: Locality::NodeLocal,
                    bytes_read: 0.0,
                    shuffle_read_bytes: 6144.5,
                    shuffle_write_bytes: 0.0,
                    memory_bytes_spilled: 0.0,
                    disk_bytes_spilled: 0.0,
                    jvm_gc_time: 0.0,
                    serialize_time: 0.001,
                    deserialize_time: 0.002,
                },
            ],
            node_series: vec![
                NodeSeries {
                    node: 0,
                    period: 1.0,
                    cpu: vec![0.25, 0.5, 0.75],
                    disk: vec![0.0, 0.125, 0.5],
                    net_bytes: vec![1000.0, 2000.5, 0.0],
                },
                NodeSeries {
                    node: 1,
                    period: 1.0,
                    cpu: vec![0.9, 0.95, 1.0],
                    disk: vec![0.1, 0.1, 0.1],
                    net_bytes: vec![0.0, 0.0, 0.0],
                },
            ],
            injections: vec![InjectionRecord {
                node: 1,
                kind: AnomalyKind::Io,
                t_start: 1.25,
                t_end: 4.75,
            }],
        }
    }

    #[test]
    fn roundtrip_exact() {
        let t = sample();
        let j = encode(&t);
        let back = decode(&j).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn roundtrip_through_text() {
        let t = sample();
        let text = encode(&t).to_pretty();
        let back = decode(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn file_roundtrip() {
        let t = sample();
        let path = std::env::temp_dir().join("bigroots_codec_test.json");
        let path = path.to_str().unwrap();
        save(&t, path).unwrap();
        let back = load(path).unwrap();
        assert_eq!(t, back);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_wrong_version() {
        let mut j = encode(&sample());
        j.set("version", 999u64.into());
        assert!(decode(&j).is_err());
    }

    #[test]
    fn rejects_bad_locality_and_kind() {
        let t = sample();
        let text = encode(&t).to_string().replace("PROCESS_LOCAL", "WAT");
        assert!(decode(&Json::parse(&text).unwrap()).is_err());
        let text = encode(&t).to_string().replace("\"IO\"", "\"XYZ\"");
        assert!(decode(&Json::parse(&text).unwrap()).is_err());
    }

    #[test]
    fn rejects_structurally_invalid() {
        // Validation runs after decoding: a task on an unknown node fails.
        let mut t = sample();
        t.tasks[0].node = 5;
        let j = encode(&t);
        assert!(decode(&j).is_err());
    }

    #[test]
    fn missing_field_is_error() {
        let j = Json::parse(r#"{"version":1,"job_name":"x"}"#).unwrap();
        assert!(decode(&j).is_err());
    }
}
