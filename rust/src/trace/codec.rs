//! JSON codec for [`JobTrace`] — the offline-log workflow of the paper:
//! the simulator (or a converter from real Spark event logs) writes a trace
//! file, the analyzer reads it back. Round-trip is exact for all fields
//! (f64 values serialize with shortest-roundtrip formatting).
//!
//! Also home of the **zero-allocation NDJSON event decoder**
//! ([`decode_event_line`]): event-log lines are flat JSON objects, and the
//! live ingest path decodes millions of them, so building a
//! `BTreeMap<String, Json>` DOM per line (one allocation per key *and*
//! value) dominated decode cost. The fast path scans the line's borrowed
//! bytes once, parses scalars in place, and constructs the
//! [`Event`](crate::trace::eventlog::Event) directly — the only heap
//! traffic is the event's own owned strings. The generic [`Json`] parser
//! stays for trace files, configs and fixtures; decode parity between the
//! two paths is property-tested in `rust/tests/hotpath_parity.rs`.

use std::borrow::Cow;

use super::eventlog::Event;
use super::model::*;
use crate::util::json::{Json, JsonError};

const FORMAT_VERSION: u64 = 1;

/// Encode a trace to a JSON value.
pub fn encode(trace: &JobTrace) -> Json {
    let mut root = Json::obj();
    root.set("version", FORMAT_VERSION.into());
    root.set("job_name", trace.job_name.as_str().into());
    root.set("workload", trace.workload.as_str().into());
    let mut cluster = Json::obj();
    cluster.set("nodes", trace.cluster.nodes.into());
    cluster.set("cores_per_node", trace.cluster.cores_per_node.into());
    cluster.set("executors_per_node", trace.cluster.executors_per_node.into());
    root.set("cluster", cluster);

    root.set(
        "stages",
        Json::Arr(
            trace
                .stages
                .iter()
                .map(|s| {
                    let mut o = Json::obj();
                    o.set("stage_id", s.stage_id.into());
                    o.set("name", s.name.as_str().into());
                    o.set("tasks", s.tasks.clone().into());
                    o
                })
                .collect(),
        ),
    );

    root.set(
        "tasks",
        Json::Arr(
            trace
                .tasks
                .iter()
                .map(|t| {
                    let mut o = Json::obj();
                    o.set("task_id", t.task_id.into());
                    o.set("stage_id", t.stage_id.into());
                    o.set("node", t.node.into());
                    o.set("executor", t.executor.into());
                    o.set("start", t.start.into());
                    o.set("finish", t.finish.into());
                    o.set("locality", t.locality.as_str().into());
                    o.set("bytes_read", t.bytes_read.into());
                    o.set("shuffle_read_bytes", t.shuffle_read_bytes.into());
                    o.set("shuffle_write_bytes", t.shuffle_write_bytes.into());
                    o.set("memory_bytes_spilled", t.memory_bytes_spilled.into());
                    o.set("disk_bytes_spilled", t.disk_bytes_spilled.into());
                    o.set("jvm_gc_time", t.jvm_gc_time.into());
                    o.set("serialize_time", t.serialize_time.into());
                    o.set("deserialize_time", t.deserialize_time.into());
                    o
                })
                .collect(),
        ),
    );

    root.set(
        "node_series",
        Json::Arr(
            trace
                .node_series
                .iter()
                .map(|s| {
                    let mut o = Json::obj();
                    o.set("node", s.node.into());
                    o.set("period", s.period.into());
                    o.set("cpu", s.cpu.clone().into());
                    o.set("disk", s.disk.clone().into());
                    o.set("net_bytes", s.net_bytes.clone().into());
                    o
                })
                .collect(),
        ),
    );

    root.set(
        "injections",
        Json::Arr(
            trace
                .injections
                .iter()
                .map(|i| {
                    let mut o = Json::obj();
                    o.set("node", i.node.into());
                    o.set("kind", i.kind.as_str().into());
                    o.set("t_start", i.t_start.into());
                    o.set("t_end", i.t_end.into());
                    o
                })
                .collect(),
        ),
    );
    root
}

fn bad(msg: &str) -> JsonError {
    JsonError { offset: 0, message: msg.to_string() }
}

fn f64_arr(j: &Json, key: &str) -> Result<Vec<f64>, JsonError> {
    j.req_arr(key)?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| bad(&format!("{key}: non-number element"))))
        .collect()
}

/// Decode a trace from a JSON value, validating structure.
pub fn decode(j: &Json) -> Result<JobTrace, JsonError> {
    let version = j.req_u64("version")?;
    if version != FORMAT_VERSION {
        return Err(bad(&format!("unsupported trace version {version}")));
    }
    let cluster_j = j.get("cluster");
    let cluster = ClusterInfo {
        nodes: cluster_j.req_usize("nodes")?,
        cores_per_node: cluster_j.req_usize("cores_per_node")?,
        executors_per_node: cluster_j.req_usize("executors_per_node")?,
    };

    let stages = j
        .req_arr("stages")?
        .iter()
        .map(|s| {
            Ok(StageRecord {
                stage_id: s.req_u64("stage_id")?,
                name: s.req_str("name")?.to_string(),
                tasks: s
                    .req_arr("tasks")?
                    .iter()
                    .map(|t| t.as_u64().ok_or_else(|| bad("stage.tasks: non-integer")))
                    .collect::<Result<_, _>>()?,
            })
        })
        .collect::<Result<Vec<_>, JsonError>>()?;

    let tasks = j
        .req_arr("tasks")?
        .iter()
        .map(|t| {
            Ok(TaskRecord {
                task_id: t.req_u64("task_id")?,
                stage_id: t.req_u64("stage_id")?,
                node: t.req_usize("node")?,
                executor: t.req_usize("executor")?,
                start: t.req_f64("start")?,
                finish: t.req_f64("finish")?,
                locality: Locality::from_str(t.req_str("locality")?)
                    .ok_or_else(|| bad("bad locality"))?,
                bytes_read: t.req_f64("bytes_read")?,
                shuffle_read_bytes: t.req_f64("shuffle_read_bytes")?,
                shuffle_write_bytes: t.req_f64("shuffle_write_bytes")?,
                memory_bytes_spilled: t.req_f64("memory_bytes_spilled")?,
                disk_bytes_spilled: t.req_f64("disk_bytes_spilled")?,
                jvm_gc_time: t.req_f64("jvm_gc_time")?,
                serialize_time: t.req_f64("serialize_time")?,
                deserialize_time: t.req_f64("deserialize_time")?,
            })
        })
        .collect::<Result<Vec<_>, JsonError>>()?;

    let node_series = j
        .req_arr("node_series")?
        .iter()
        .map(|s| {
            Ok(NodeSeries {
                node: s.req_usize("node")?,
                period: s.req_f64("period")?,
                cpu: f64_arr(s, "cpu")?,
                disk: f64_arr(s, "disk")?,
                net_bytes: f64_arr(s, "net_bytes")?,
            })
        })
        .collect::<Result<Vec<_>, JsonError>>()?;

    let injections = j
        .req_arr("injections")?
        .iter()
        .map(|i| {
            Ok(InjectionRecord {
                node: i.req_usize("node")?,
                kind: AnomalyKind::from_str(i.req_str("kind")?)
                    .ok_or_else(|| bad("bad anomaly kind"))?,
                t_start: i.req_f64("t_start")?,
                t_end: i.req_f64("t_end")?,
            })
        })
        .collect::<Result<Vec<_>, JsonError>>()?;

    let trace = JobTrace {
        job_name: j.req_str("job_name")?.to_string(),
        workload: j.req_str("workload")?.to_string(),
        cluster,
        stages,
        tasks,
        node_series,
        injections,
    };
    trace.validate().map_err(|e| bad(&e))?;
    Ok(trace)
}

// ---------------------------------------------------------------------------
// Zero-allocation NDJSON event decoding

/// One decoded event-log line. `has_job` distinguishes "no `"job"` field"
/// from "`"job"` present but not an unsigned integer" (`job == None` in
/// both cases) — the tagged/untagged stream-mode logic needs the former,
/// strict tagged decoding errors on the latter.
#[derive(Debug, Clone, PartialEq)]
pub struct DecodedLine {
    pub has_job: bool,
    pub job: Option<u64>,
    pub event: Event,
}

impl DecodedLine {
    /// The job tag for strict tagged consumers: an error when the line's
    /// `"job"` field was present but not an unsigned integer (callers
    /// check [`DecodedLine::has_job`] first to handle untagged lines).
    pub fn require_job(&self) -> Result<u64, JsonError> {
        self.job.ok_or_else(|| field_bad("job", "unsigned integer"))
    }
}

/// Decode one NDJSON event line without building a [`Json`] DOM.
///
/// Accepts exactly the lines the generic path
/// (`Json::parse` + `Event::decode`) accepts: a flat JSON object with the
/// event's scalar fields, unknown fields ignored (nested values are
/// scanned and skipped), duplicate keys last-wins, surrounding whitespace
/// tolerated.
pub fn decode_event_line(line: &str) -> Result<DecodedLine, JsonError> {
    let mut s = Scan { src: line, b: line.as_bytes(), pos: 0 };
    s.skip_ws();
    s.expect(b'{')?;
    let mut f = Fields::default();
    s.skip_ws();
    if s.peek() == Some(b'}') {
        s.pos += 1;
    } else {
        loop {
            s.skip_ws();
            let key = s.string_token()?;
            s.skip_ws();
            s.expect(b':')?;
            s.skip_ws();
            match &*key {
                "event" => f.event = s.str_field()?,
                "job" => f.job = s.num_field()?,
                "job_name" => f.job_name = s.str_field()?,
                "workload" => f.workload = s.str_field()?,
                "nodes" => f.nodes = s.num_field()?,
                "cores_per_node" => f.cores_per_node = s.num_field()?,
                "executors_per_node" => f.executors_per_node = s.num_field()?,
                "stage_id" => f.stage_id = s.num_field()?,
                "name" => f.name = s.str_field()?,
                "num_tasks" => f.num_tasks = s.num_field()?,
                "task_id" => f.task_id = s.num_field()?,
                "node" => f.node = s.num_field()?,
                "executor" => f.executor = s.num_field()?,
                "time" => f.time = s.num_field()?,
                "locality" => f.locality = s.str_field()?,
                "start" => f.start = s.num_field()?,
                "finish" => f.finish = s.num_field()?,
                "bytes_read" => f.bytes_read = s.num_field()?,
                "shuffle_read_bytes" => f.shuffle_read_bytes = s.num_field()?,
                "shuffle_write_bytes" => f.shuffle_write_bytes = s.num_field()?,
                "memory_bytes_spilled" => f.memory_bytes_spilled = s.num_field()?,
                "disk_bytes_spilled" => f.disk_bytes_spilled = s.num_field()?,
                "jvm_gc_time" => f.jvm_gc_time = s.num_field()?,
                "serialize_time" => f.serialize_time = s.num_field()?,
                "deserialize_time" => f.deserialize_time = s.num_field()?,
                "cpu" => f.cpu = s.num_field()?,
                "disk" => f.disk = s.num_field()?,
                "net_bytes" => f.net_bytes = s.num_field()?,
                "kind" => f.kind = s.str_field()?,
                "t_start" => f.t_start = s.num_field()?,
                "t_end" => f.t_end = s.num_field()?,
                _ => s.skip_value()?,
            }
            s.skip_ws();
            match s.peek() {
                Some(b',') => s.pos += 1,
                Some(b'}') => {
                    s.pos += 1;
                    break;
                }
                _ => return Err(s.err("expected ',' or '}'")),
            }
        }
    }
    s.skip_ws();
    if s.pos != s.b.len() {
        return Err(s.err("trailing data"));
    }
    f.build()
}

/// A numeric field's state: absent, a number, or present with a
/// non-number value (only an error if the dispatched event needs it —
/// matching how the DOM path ignores unused fields).
#[derive(Clone, Copy, Default)]
enum Num {
    #[default]
    Absent,
    Val(f64),
    Bad,
}

impl Num {
    fn f64(self, key: &str) -> Result<f64, JsonError> {
        match self {
            Num::Val(v) => Ok(v),
            _ => Err(field_bad(key, "number")),
        }
    }

    fn u64(self, key: &str) -> Result<u64, JsonError> {
        match self {
            // Same acceptance as `Json::as_u64` (bit-for-bit: same
            // comparison, same saturating cast).
            Num::Val(x) if x >= 0.0 && x.fract() == 0.0 && x <= u64::MAX as f64 => Ok(x as u64),
            _ => Err(field_bad(key, "unsigned integer")),
        }
    }

    fn usize(self, key: &str) -> Result<usize, JsonError> {
        Ok(self.u64(key)? as usize)
    }
}

/// A string field's state (see [`Num`]).
#[derive(Clone, Default)]
enum SVal<'a> {
    #[default]
    Absent,
    Str(Cow<'a, str>),
    Bad,
}

impl<'a> SVal<'a> {
    fn str(&self, key: &str) -> Result<&str, JsonError> {
        match self {
            SVal::Str(s) => Ok(s),
            _ => Err(field_bad(key, "string")),
        }
    }
}

fn field_bad(key: &str, ty: &str) -> JsonError {
    JsonError { offset: 0, message: format!("field '{key}': expected {ty}") }
}

/// Every scalar field any event line can carry.
#[derive(Default)]
struct Fields<'a> {
    event: SVal<'a>,
    job: Num,
    job_name: SVal<'a>,
    workload: SVal<'a>,
    nodes: Num,
    cores_per_node: Num,
    executors_per_node: Num,
    stage_id: Num,
    name: SVal<'a>,
    num_tasks: Num,
    task_id: Num,
    node: Num,
    executor: Num,
    time: Num,
    locality: SVal<'a>,
    start: Num,
    finish: Num,
    bytes_read: Num,
    shuffle_read_bytes: Num,
    shuffle_write_bytes: Num,
    memory_bytes_spilled: Num,
    disk_bytes_spilled: Num,
    jvm_gc_time: Num,
    serialize_time: Num,
    deserialize_time: Num,
    cpu: Num,
    disk: Num,
    net_bytes: Num,
    kind: SVal<'a>,
    t_start: Num,
    t_end: Num,
}

impl<'a> Fields<'a> {
    fn locality(&self, key: &str) -> Result<Locality, JsonError> {
        Locality::from_str(self.locality.str(key)?)
            .ok_or_else(|| JsonError { offset: 0, message: "bad locality".to_string() })
    }

    fn build(self) -> Result<DecodedLine, JsonError> {
        let bad = |m: &str| JsonError { offset: 0, message: m.to_string() };
        let event = match self.event.str("event")? {
            "job_start" => Event::JobStart {
                job_name: self.job_name.str("job_name")?.to_string(),
                workload: self.workload.str("workload")?.to_string(),
                cluster: ClusterInfo {
                    nodes: self.nodes.usize("nodes")?,
                    cores_per_node: self.cores_per_node.usize("cores_per_node")?,
                    executors_per_node: self.executors_per_node.usize("executors_per_node")?,
                },
            },
            "stage_submitted" => Event::StageSubmitted {
                stage_id: self.stage_id.u64("stage_id")?,
                name: self.name.str("name")?.to_string(),
                num_tasks: self.num_tasks.usize("num_tasks")?,
            },
            "task_start" => Event::TaskStart {
                task_id: self.task_id.u64("task_id")?,
                stage_id: self.stage_id.u64("stage_id")?,
                node: self.node.usize("node")?,
                executor: self.executor.usize("executor")?,
                time: self.time.f64("time")?,
                locality: self.locality("locality")?,
            },
            "task_end" => Event::TaskEnd(TaskRecord {
                task_id: self.task_id.u64("task_id")?,
                stage_id: self.stage_id.u64("stage_id")?,
                node: self.node.usize("node")?,
                executor: self.executor.usize("executor")?,
                start: self.start.f64("start")?,
                finish: self.finish.f64("finish")?,
                locality: self.locality("locality")?,
                bytes_read: self.bytes_read.f64("bytes_read")?,
                shuffle_read_bytes: self.shuffle_read_bytes.f64("shuffle_read_bytes")?,
                shuffle_write_bytes: self.shuffle_write_bytes.f64("shuffle_write_bytes")?,
                memory_bytes_spilled: self.memory_bytes_spilled.f64("memory_bytes_spilled")?,
                disk_bytes_spilled: self.disk_bytes_spilled.f64("disk_bytes_spilled")?,
                jvm_gc_time: self.jvm_gc_time.f64("jvm_gc_time")?,
                serialize_time: self.serialize_time.f64("serialize_time")?,
                deserialize_time: self.deserialize_time.f64("deserialize_time")?,
            }),
            "resource_sample" => Event::ResourceSample {
                node: self.node.usize("node")?,
                time: self.time.f64("time")?,
                cpu: self.cpu.f64("cpu")?,
                disk: self.disk.f64("disk")?,
                net_bytes: self.net_bytes.f64("net_bytes")?,
            },
            "injection" => Event::Injection(InjectionRecord {
                node: self.node.usize("node")?,
                kind: AnomalyKind::from_str(self.kind.str("kind")?)
                    .ok_or_else(|| bad("bad anomaly kind"))?,
                t_start: self.t_start.f64("t_start")?,
                t_end: self.t_end.f64("t_end")?,
            }),
            "job_end" => Event::JobEnd { time: self.time.f64("time")? },
            other => return Err(bad(&format!("unknown event '{other}'"))),
        };
        let (has_job, job) = match self.job {
            Num::Absent => (false, None),
            j => (true, j.u64("job").ok()),
        };
        Ok(DecodedLine { has_job, job, event })
    }
}

/// The borrowed-token scanner. Mirrors the grammar of
/// [`crate::util::json`]'s parser so accept/reject behavior matches.
struct Scan<'a> {
    src: &'a str,
    b: &'a [u8],
    pos: usize,
}

impl<'a> Scan<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    /// A string token. Borrows the source slice when the string has no
    /// escapes (every machine-generated event line); unescapes into an
    /// owned buffer otherwise.
    fn string_token(&mut self) -> Result<Cow<'a, str>, JsonError> {
        self.expect(b'"')?;
        let start = self.pos;
        // Fast path: find the closing quote with no backslash in between.
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    let s = &self.src[start..self.pos];
                    self.pos += 1;
                    return Ok(Cow::Borrowed(s));
                }
                Some(b'\\') => break,
                Some(_) => self.pos += 1,
            }
        }
        // Slow path: escapes present — build an owned string.
        let mut out = String::with_capacity(self.pos - start + 16);
        out.push_str(&self.src[start..self.pos]);
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(Cow::Owned(out));
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            let ch = if (0xd800..0xdc00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    if (0xdc00..0xe000).contains(&lo) {
                                        char::from_u32(
                                            0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00),
                                        )
                                    } else {
                                        // Unpaired low half: reject without
                                        // the DOM path's debug-mode overflow.
                                        None
                                    }
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| self.err("bad unicode escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy one UTF-8 char (input is &str, so boundaries
                    // are valid; chars().next() never fails here).
                    let c = self.src[self.pos..].chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("short unicode escape"));
        }
        // Byte slice, not str slice: a multi-byte char here must error
        // like the DOM parser, not panic on a non-boundary str index.
        let hx = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hx, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number_token(&mut self) -> Result<f64, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        self.src[start..self.pos].parse::<f64>().map_err(|_| self.err("invalid number"))
    }

    /// A field value expected to be a number. Anything else is scanned
    /// past and remembered as [`Num::Bad`].
    fn num_field(&mut self) -> Result<Num, JsonError> {
        match self.peek() {
            Some(c) if c == b'-' || c.is_ascii_digit() => Ok(Num::Val(self.number_token()?)),
            _ => {
                self.skip_value()?;
                Ok(Num::Bad)
            }
        }
    }

    /// A field value expected to be a string (see [`Scan::num_field`]).
    fn str_field(&mut self) -> Result<SVal<'a>, JsonError> {
        match self.peek() {
            Some(b'"') => Ok(SVal::Str(self.string_token()?)),
            _ => {
                self.skip_value()?;
                Ok(SVal::Bad)
            }
        }
    }

    fn literal(&mut self, word: &str) -> Result<(), JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(())
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    /// Scan past one JSON value of any shape, validating its syntax —
    /// unknown fields must not change accept/reject behavior versus the
    /// DOM parser.
    fn skip_value(&mut self) -> Result<(), JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null"),
            Some(b't') => self.literal("true"),
            Some(b'f') => self.literal("false"),
            Some(b'"') => self.string_token().map(|_| ()),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number_token().map(|_| ()),
            Some(b'[') => {
                self.pos += 1;
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(());
                }
                loop {
                    self.skip_ws();
                    self.skip_value()?;
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(());
                        }
                        _ => return Err(self.err("expected ',' or ']'")),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(());
                }
                loop {
                    self.skip_ws();
                    self.string_token()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    self.skip_ws();
                    self.skip_value()?;
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(());
                        }
                        _ => return Err(self.err("expected ',' or '}'")),
                    }
                }
            }
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }
}

/// Write a trace to a file (pretty JSON).
pub fn save(trace: &JobTrace, path: &str) -> anyhow::Result<()> {
    std::fs::write(path, encode(trace).to_pretty())?;
    Ok(())
}

/// Read a trace from a file.
pub fn load(path: &str) -> anyhow::Result<JobTrace> {
    let text = std::fs::read_to_string(path)?;
    let j = Json::parse(&text)?;
    Ok(decode(&j)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> JobTrace {
        JobTrace {
            job_name: "naivebayes-large".into(),
            workload: "NaiveBayes".into(),
            cluster: ClusterInfo { nodes: 2, cores_per_node: 16, executors_per_node: 2 },
            stages: vec![
                StageRecord { stage_id: 0, name: "map".into(), tasks: vec![0, 1] },
                StageRecord { stage_id: 1, name: "reduce".into(), tasks: vec![2] },
            ],
            tasks: vec![
                TaskRecord {
                    task_id: 0,
                    stage_id: 0,
                    node: 0,
                    executor: 1,
                    start: 0.0,
                    finish: 2.25,
                    locality: Locality::ProcessLocal,
                    bytes_read: 1048576.0,
                    shuffle_read_bytes: 0.0,
                    shuffle_write_bytes: 2048.5,
                    memory_bytes_spilled: 0.0,
                    disk_bytes_spilled: 0.0,
                    jvm_gc_time: 0.125,
                    serialize_time: 0.011,
                    deserialize_time: 0.041,
                },
                TaskRecord {
                    task_id: 1,
                    stage_id: 0,
                    node: 1,
                    executor: 0,
                    start: 0.1,
                    finish: 5.5,
                    locality: Locality::Any,
                    bytes_read: 2097152.0,
                    shuffle_read_bytes: 0.0,
                    shuffle_write_bytes: 4096.0,
                    memory_bytes_spilled: 1024.0,
                    disk_bytes_spilled: 512.0,
                    jvm_gc_time: 1.5,
                    serialize_time: 0.02,
                    deserialize_time: 0.03,
                },
                TaskRecord {
                    task_id: 2,
                    stage_id: 1,
                    node: 0,
                    executor: 0,
                    start: 6.0,
                    finish: 8.0,
                    locality: Locality::NodeLocal,
                    bytes_read: 0.0,
                    shuffle_read_bytes: 6144.5,
                    shuffle_write_bytes: 0.0,
                    memory_bytes_spilled: 0.0,
                    disk_bytes_spilled: 0.0,
                    jvm_gc_time: 0.0,
                    serialize_time: 0.001,
                    deserialize_time: 0.002,
                },
            ],
            node_series: vec![
                NodeSeries {
                    node: 0,
                    period: 1.0,
                    cpu: vec![0.25, 0.5, 0.75],
                    disk: vec![0.0, 0.125, 0.5],
                    net_bytes: vec![1000.0, 2000.5, 0.0],
                },
                NodeSeries {
                    node: 1,
                    period: 1.0,
                    cpu: vec![0.9, 0.95, 1.0],
                    disk: vec![0.1, 0.1, 0.1],
                    net_bytes: vec![0.0, 0.0, 0.0],
                },
            ],
            injections: vec![InjectionRecord {
                node: 1,
                kind: AnomalyKind::Io,
                t_start: 1.25,
                t_end: 4.75,
            }],
        }
    }

    #[test]
    fn roundtrip_exact() {
        let t = sample();
        let j = encode(&t);
        let back = decode(&j).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn roundtrip_through_text() {
        let t = sample();
        let text = encode(&t).to_pretty();
        let back = decode(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn file_roundtrip() {
        let t = sample();
        let path = std::env::temp_dir().join("bigroots_codec_test.json");
        let path = path.to_str().unwrap();
        save(&t, path).unwrap();
        let back = load(path).unwrap();
        assert_eq!(t, back);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_wrong_version() {
        let mut j = encode(&sample());
        j.set("version", 999u64.into());
        assert!(decode(&j).is_err());
    }

    #[test]
    fn rejects_bad_locality_and_kind() {
        let t = sample();
        let text = encode(&t).to_string().replace("PROCESS_LOCAL", "WAT");
        assert!(decode(&Json::parse(&text).unwrap()).is_err());
        let text = encode(&t).to_string().replace("\"IO\"", "\"XYZ\"");
        assert!(decode(&Json::parse(&text).unwrap()).is_err());
    }

    #[test]
    fn rejects_structurally_invalid() {
        // Validation runs after decoding: a task on an unknown node fails.
        let mut t = sample();
        t.tasks[0].node = 5;
        let j = encode(&t);
        assert!(decode(&j).is_err());
    }

    #[test]
    fn missing_field_is_error() {
        let j = Json::parse(r#"{"version":1,"job_name":"x"}"#).unwrap();
        assert!(decode(&j).is_err());
    }

    // ---- zero-allocation event-line decoder -------------------------------

    use crate::trace::eventlog::{trace_to_events, Event, TaggedEvent};

    /// The DOM reference path the fast decoder must match. Same semantics
    /// as the oracle in `rust/tests/hotpath_parity.rs`: a malformed job
    /// tag yields `job == None` (strictness is the tagged consumer's job,
    /// via [`DecodedLine::require_job`]), so keep the two in sync.
    fn dom_decode(line: &str) -> Result<(bool, Option<u64>, Event), ()> {
        let j = Json::parse(line).map_err(|_| ())?;
        let has_job = j.as_obj().map(|m| m.contains_key("job")).unwrap_or(false);
        let event = Event::decode(&j).map_err(|_| ())?;
        let job = if has_job { j.get("job").as_u64() } else { None };
        Ok((has_job, job, event))
    }

    #[test]
    fn fast_decode_matches_dom_on_every_event_kind() {
        let t = sample();
        for e in trace_to_events(&t) {
            let line = e.encode().to_string();
            let fast = decode_event_line(&line).unwrap();
            assert!(!fast.has_job);
            assert_eq!(fast.event, e, "untagged: {line}");
            // Tagged form of the same line.
            let tagged = TaggedEvent { job_id: 7, event: e.clone() }.encode().to_string();
            let fast = decode_event_line(&tagged).unwrap();
            assert!(fast.has_job);
            assert_eq!(fast.job, Some(7));
            assert_eq!(fast.event, e, "tagged: {tagged}");
        }
    }

    #[test]
    fn fast_decode_tolerates_whitespace_and_unknown_fields() {
        let line = r#"  { "event" : "job_end" , "time" : 4.5 ,
            "extra_string" : "seén" , "extra_nested" : { "a" : [ 1 , true , null , {} ] } }  "#;
        let d = decode_event_line(line).unwrap();
        assert_eq!(d.event, Event::JobEnd { time: 4.5 });
        assert!(!d.has_job);
    }

    #[test]
    fn fast_decode_handles_escaped_strings() {
        let name = "job \"q\"\t\\ € 😀";
        let e = Event::JobStart {
            job_name: name.to_string(),
            workload: "w\nx".to_string(),
            cluster: ClusterInfo { nodes: 1, cores_per_node: 1, executors_per_node: 1 },
        };
        let line = e.encode().to_string();
        assert_eq!(decode_event_line(&line).unwrap().event, e);
        // Explicit \u escape forms, incl. a surrogate pair.
        let line = r#"{"event":"job_start","job_name":"\u0041\ud83d\ude00","workload":"w","nodes":1,"cores_per_node":1,"executors_per_node":1}"#;
        match decode_event_line(line).unwrap().event {
            Event::JobStart { job_name, .. } => assert_eq!(job_name, "A😀"),
            other => panic!("wrong event {other:?}"),
        }
        // A lone high surrogate is rejected, like the DOM parser.
        let line = r#"{"event":"job_end","time":1.0,"x":"\ud83d"}"#;
        assert!(decode_event_line(line).is_err());
    }

    #[test]
    fn fast_decode_duplicate_keys_last_wins() {
        let line = r#"{"event":"job_end","time":1.0,"time":9.5}"#;
        assert_eq!(decode_event_line(line).unwrap().event, Event::JobEnd { time: 9.5 });
        // DOM agrees (BTreeMap insert overwrites).
        let (_, _, dom) = dom_decode(line).unwrap();
        assert_eq!(dom, Event::JobEnd { time: 9.5 });
    }

    #[test]
    fn fast_decode_rejects_what_dom_rejects() {
        for line in [
            "",                                             // empty
            "{",                                            // truncated
            r#"{"event":"job_end"}"#,                       // missing field
            r#"{"event":"job_end","time":"late"}"#,         // wrong type
            r#"{"event":"wat","time":1.0}"#,                // unknown event
            r#"{"event":"job_end","time":1.0} trailing"#,   // trailing data
            r#"{"event":"job_end","time":1.0,}"#,           // bad comma
            r#"{"event":"job_end","time":1.0,"x":nul}"#,    // bad literal
            r#"{"event":"job_end","time":1.0,"x":"\q"}"#,   // bad escape
            r#"{"event":"task_start","task_id":0,"stage_id":0,"node":0,"executor":0,"time":1.0,"locality":"WAT"}"#,
            r#"{"event":"job_end","time":-1e999x}"#,        // malformed number tail
            r#"{"event":"job_end","time":1.0,"x":"\u0é9"}"#, // multi-byte in hex escape
        ] {
            assert!(decode_event_line(line).is_err(), "should reject: {line}");
            assert!(dom_decode(line).is_err(), "dom should reject: {line}");
        }
    }

    #[test]
    fn fast_decode_negative_or_fractional_ids_rejected() {
        // `as_u64` semantics: ids must be non-negative integers.
        for line in [
            r#"{"event":"stage_submitted","stage_id":-1,"name":"s","num_tasks":2}"#,
            r#"{"event":"stage_submitted","stage_id":1.5,"name":"s","num_tasks":2}"#,
        ] {
            assert!(decode_event_line(line).is_err(), "{line}");
            assert!(dom_decode(line).is_err(), "{line}");
        }
    }

    #[test]
    fn fast_decode_bad_job_tag() {
        // A bad "job" value is only an error for *tagged* consumers; the
        // event itself still decodes (the DOM path behaves the same).
        let line = r#"{"event":"job_end","time":1.0,"job":"zero"}"#;
        let d = decode_event_line(line).unwrap();
        assert!(d.has_job);
        assert_eq!(d.job, None);
        assert_eq!(d.event, Event::JobEnd { time: 1.0 });
    }
}
