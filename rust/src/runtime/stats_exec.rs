//! The XLA stats backend: executes the AOT-compiled stage-stats artifact
//! (L1 Pallas kernels composed by the L2 jax graph) from the analysis hot
//! path, implementing the same [`StatsBackend`] contract as the native
//! rust path. Parity between the two is covered in
//! `rust/tests/backend_parity.rs`.
//!
//! Padding & bucketing: artifacts are compiled for task-axis sizes
//! [`buckets`] (128/512/2048 by default); a stage with `n` tasks runs on
//! the smallest bucket ≥ n, rows ≥ n masked out. Stages larger than the
//! biggest bucket, or with more distinct nodes than `max_nodes`, fall back
//! to the native backend (correctness first — and such stages are rare:
//! the paper's cluster has 5 slaves).
//!
//! f32 note: the artifact computes in f32. The network column (bytes per
//! interval, ~1e8) is scaled to MB at the boundary and unscaled on the way
//! out, keeping sums-of-squares comfortably inside f32 range.

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

use super::client::{CompiledModule, PjrtRuntime};
use crate::analysis::features::{FeatureKind, StageFeatures};
use crate::analysis::stats::{compute_native, StageStats, StatsBackend, GRID_Q};
use crate::util::json::Json;

/// Scale applied to the Network feature column before f32 conversion.
const NET_SCALE: f64 = 1e-6;

/// Loaded manifest of the artifacts directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub num_features: usize,
    pub grid_q: usize,
    pub max_nodes: usize,
    pub edge_window: usize,
    pub buckets: Vec<usize>,
}

impl Manifest {
    pub fn load(dir: &str) -> Result<Manifest> {
        let text = std::fs::read_to_string(format!("{dir}/manifest.json"))
            .with_context(|| format!("reading {dir}/manifest.json"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let buckets = j
            .req_arr("buckets")
            .map_err(|e| anyhow!("{e}"))?
            .iter()
            .map(|b| b.as_usize().ok_or_else(|| anyhow!("bad bucket")))
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            num_features: j.req_usize("num_features").map_err(|e| anyhow!("{e}"))?,
            grid_q: j.req_usize("grid_q").map_err(|e| anyhow!("{e}"))?,
            max_nodes: j.req_usize("max_nodes").map_err(|e| anyhow!("{e}"))?,
            edge_window: j.req_usize("edge_window").map_err(|e| anyhow!("{e}"))?,
            buckets,
        })
    }
}

/// The XLA-executing backend.
pub struct XlaBackend {
    runtime: PjrtRuntime,
    dir: String,
    manifest: Manifest,
    /// Bucket size → compiled stage_stats module (compiled lazily, once).
    modules: HashMap<usize, CompiledModule>,
    /// Stages that exceeded every bucket (served natively).
    pub fallback_count: usize,
    /// Stages served by the XLA path.
    pub xla_count: usize,
    /// Reused input scratch (§Perf: avoids 4 allocations per stage call).
    scratch: Scratch,
}

#[derive(Default)]
struct Scratch {
    x: Vec<f32>,
    x_sorted: Vec<f32>,
    dur: Vec<f32>,
    mask: Vec<f32>,
    onehot: Vec<f32>,
    col: Vec<f32>,
}

impl XlaBackend {
    /// Open an artifacts directory (fails if the manifest is missing or
    /// inconsistent with the crate's feature layout).
    pub fn open(dir: &str) -> Result<XlaBackend> {
        let manifest = Manifest::load(dir)?;
        if manifest.num_features != FeatureKind::COUNT {
            return Err(anyhow!(
                "artifact feature count {} != crate {}; re-run `make artifacts`",
                manifest.num_features,
                FeatureKind::COUNT
            ));
        }
        if manifest.grid_q != GRID_Q {
            return Err(anyhow!(
                "artifact quantile grid {} != crate {}; re-run `make artifacts`",
                manifest.grid_q,
                GRID_Q
            ));
        }
        let runtime = PjrtRuntime::cpu()?;
        Ok(XlaBackend {
            runtime,
            dir: dir.to_string(),
            manifest,
            modules: HashMap::new(),
            fallback_count: 0,
            xla_count: 0,
            scratch: Scratch::default(),
        })
    }

    /// The default artifacts location relative to the repo root.
    pub fn default_dir() -> String {
        std::env::var("BIGROOTS_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn bucket_for(&self, n: usize) -> Option<usize> {
        self.manifest.buckets.iter().copied().filter(|&b| b >= n).min()
    }

    fn module(&mut self, bucket: usize) -> Result<&CompiledModule> {
        if !self.modules.contains_key(&bucket) {
            let path = format!("{}/stage_stats_t{}.hlo.txt", self.dir, bucket);
            let m = self.runtime.load_hlo_text(&path)?;
            self.modules.insert(bucket, m);
        }
        Ok(self.modules.get(&bucket).unwrap())
    }

    /// Execute the artifact for one stage. Returns None when the stage does
    /// not fit any bucket / node limit (caller falls back to native).
    fn try_xla(&mut self, sf: &StageFeatures) -> Result<Option<StageStats>> {
        let n = sf.num_tasks();
        let f = FeatureKind::COUNT;
        let Some(bucket) = self.bucket_for(n) else {
            return Ok(None);
        };
        // Node slots in first-appearance order (same as the native path).
        let mut nodes: Vec<usize> = Vec::new();
        let mut slot_of_row: Vec<usize> = Vec::with_capacity(n);
        for &nd in &sf.nodes {
            let slot = match nodes.iter().position(|&x| x == nd) {
                Some(s) => s,
                None => {
                    nodes.push(nd);
                    nodes.len() - 1
                }
            };
            slot_of_row.push(slot);
        }
        if nodes.len() > self.manifest.max_nodes {
            return Ok(None);
        }
        let max_nodes = self.manifest.max_nodes;

        // Pack padded f32 inputs into reused scratch buffers.
        let net_col = FeatureKind::Network.index();
        let sc = &mut self.scratch;
        sc.x.clear();
        sc.x.resize(bucket * f, 0.0);
        sc.dur.clear();
        sc.dur.resize(bucket, 0.0);
        sc.mask.clear();
        sc.mask.resize(bucket, 0.0);
        sc.onehot.clear();
        sc.onehot.resize(max_nodes * bucket, 0.0);
        for row in 0..n {
            for k in 0..f {
                let mut v = sf.matrix[row * f + k];
                if k == net_col {
                    v *= NET_SCALE;
                }
                sc.x[row * f + k] = v as f32;
            }
        }
        for row in 0..n {
            sc.dur[row] = sf.durations[row] as f32;
            sc.mask[row] = 1.0;
        }
        for row in 0..n {
            sc.onehot[slot_of_row[row] * bucket + row] = 1.0;
        }
        // Presorted columns (§Perf iteration 4: XLA-CPU's Sort op costs
        // ~4.4 ms at T=2048; sorting here costs ~0.25 ms). Padding rows
        // carry the column max so the quantile matmul stays finite.
        sc.x_sorted.clear();
        sc.x_sorted.resize(bucket * f, 0.0);
        sc.col.clear();
        sc.col.resize(n, 0.0);
        for k in 0..f {
            for row in 0..n {
                sc.col[row] = sc.x[row * f + k];
            }
            sc.col.sort_by(|a, b| a.total_cmp(b));
            for row in 0..n {
                sc.x_sorted[row * f + k] = sc.col[row];
            }
            let fill = if n > 0 { sc.col[n - 1] } else { 0.0 };
            for row in n..bucket {
                sc.x_sorted[row * f + k] = fill;
            }
        }
        let (x, x_sorted, dur, mask, onehot) =
            (&sc.x, &sc.x_sorted, &sc.dur, &sc.mask, &sc.onehot);

        let outputs = {
            // Split borrows: scratch is read-only here, modules is mutated.
            let inputs: [(&[f32], &[i64]); 5] = [
                (x.as_slice(), &[bucket as i64, f as i64]),
                (x_sorted.as_slice(), &[bucket as i64, f as i64]),
                (dur.as_slice(), &[bucket as i64]),
                (mask.as_slice(), &[bucket as i64]),
                (onehot.as_slice(), &[max_nodes as i64, bucket as i64]),
            ];
            let dims: Vec<Vec<i64>> = inputs.iter().map(|(_, d)| d.to_vec()).collect();
            let datas: Vec<*const f32> = inputs.iter().map(|(d, _)| d.as_ptr()).collect();
            let lens: Vec<usize> = inputs.iter().map(|(d, _)| d.len()).collect();
            // SAFETY: scratch buffers outlive the call; module() only
            // touches `modules`/`runtime`/`dir`, never `scratch`.
            let x_s = unsafe { std::slice::from_raw_parts(datas[0], lens[0]) };
            let xs_s = unsafe { std::slice::from_raw_parts(datas[1], lens[1]) };
            let dur_s = unsafe { std::slice::from_raw_parts(datas[2], lens[2]) };
            let mask_s = unsafe { std::slice::from_raw_parts(datas[3], lens[3]) };
            let onehot_s = unsafe { std::slice::from_raw_parts(datas[4], lens[4]) };
            let module = self.module(bucket)?;
            module.run_f32(&[
                (x_s, &dims[0]),
                (xs_s, &dims[1]),
                (dur_s, &dims[2]),
                (mask_s, &dims[3]),
                (onehot_s, &dims[4]),
            ])?
        };
        let [col, dur_stats, node_sum_raw, node_count_raw, quantiles_raw, pearson]: [Vec<f32>;
            6] = outputs
            .try_into()
            .map_err(|v: Vec<Vec<f32>>| anyhow!("expected 6 outputs, got {}", v.len()))?;

        // Unpack into StageStats (f64), unscaling the network column.
        let unscale = |k: usize, v: f64| if k == net_col { v / NET_SCALE } else { v };
        let count = dur_stats[2].round() as usize;
        if count != n {
            return Err(anyhow!("artifact mask count {} != stage tasks {}", count, n));
        }
        let nf = n.max(1) as f64;
        let mut col_sum = vec![0f64; f];
        let mut col_mean = vec![0f64; f];
        let mut col_std = vec![0f64; f];
        for k in 0..f {
            let s = col[k] as f64;
            let sq = col[f + k] as f64;
            let mean = s / nf;
            let var = (sq / nf - mean * mean).max(0.0);
            col_sum[k] = unscale(k, s);
            col_mean[k] = unscale(k, mean);
            col_std[k] = unscale(k, var.sqrt());
        }
        let mut quantiles = vec![0f64; GRID_Q * f];
        for q in 0..GRID_Q {
            for k in 0..f {
                quantiles[q * f + k] = unscale(k, quantiles_raw[q * f + k] as f64);
            }
        }
        let mut node_sum = vec![0f64; nodes.len() * f];
        for (slot, _) in nodes.iter().enumerate() {
            for k in 0..f {
                node_sum[slot * f + k] = unscale(k, node_sum_raw[slot * f + k] as f64);
            }
        }
        let node_count: Vec<usize> =
            (0..nodes.len()).map(|s| node_count_raw[s].round() as usize).collect();

        Ok(Some(StageStats {
            count: n,
            col_sum,
            col_mean,
            col_std,
            pearson: pearson.iter().map(|&p| p as f64).collect(),
            quantiles,
            nodes,
            node_sum,
            node_count,
        }))
    }
}

impl StatsBackend for XlaBackend {
    fn stage_stats(&mut self, sf: &StageFeatures) -> StageStats {
        match self.try_xla(sf) {
            Ok(Some(stats)) => {
                self.xla_count += 1;
                stats
            }
            Ok(None) => {
                self.fallback_count += 1;
                compute_native(sf)
            }
            Err(e) => {
                // An execution error is a bug worth surfacing loudly in
                // tests, but production analysis degrades to native.
                debug_assert!(false, "XLA backend error: {e:#}");
                self.fallback_count += 1;
                compute_native(sf)
            }
        }
    }

    fn name(&self) -> &'static str {
        "xla-pjrt"
    }
}

/// Open the best available backend: XLA when artifacts exist, else native.
pub fn auto_backend() -> Box<dyn StatsBackend> {
    let dir = XlaBackend::default_dir();
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        match XlaBackend::open(&dir) {
            Ok(b) => return Box::new(b),
            Err(e) => {
                crate::obs::log::log(
                    crate::obs::log::Level::Warn,
                    "runtime.xla",
                    "XLA backend unavailable; using native",
                    &[("error", format!("{e:#}"))],
                );
            }
        }
    }
    Box::new(crate::analysis::stats::NativeBackend::new())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_missing_dir_errors() {
        assert!(Manifest::load("/nonexistent").is_err());
        assert!(XlaBackend::open("/nonexistent").is_err());
    }

    #[test]
    fn manifest_validation_rejects_bad_layout() {
        let dir = std::env::temp_dir().join("bigroots_bad_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"num_features":3,"grid_q":21,"max_nodes":8,"edge_window":4,"buckets":[128]}"#,
        )
        .unwrap();
        let err = match XlaBackend::open(dir.to_str().unwrap()) {
            Err(e) => e,
            Ok(_) => panic!("bad manifest must be rejected"),
        };
        assert!(format!("{err:#}").contains("feature count"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    // Full execution parity tests live in rust/tests/backend_parity.rs
    // (they need `make artifacts` to have run).
}
