//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `make artifacts` (python/compile/aot.py) and executes them on the
//! analysis hot path. Python never runs here — the artifacts are
//! self-contained XLA programs.
//!
//! - [`client`] — PJRT CPU client + HLO-text loader + f32 executor
//! - [`stats_exec`] — [`XlaBackend`]: the stage-stats artifact behind the
//!   [`crate::analysis::StatsBackend`] trait, with padding/bucketing and
//!   native fallback

pub mod client;
pub mod stats_exec;

pub use client::{CompiledModule, PjrtRuntime};
pub use stats_exec::{auto_backend, Manifest, XlaBackend};
