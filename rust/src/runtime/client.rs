//! Thin wrapper around the `xla` crate's PJRT client: load an AOT-compiled
//! HLO-text artifact, compile it once, execute it with f32 literals.
//!
//! HLO *text* is the interchange format (see python/compile/aot.py and
//! /opt/xla-example/README.md): jax ≥ 0.5's serialized protos use 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids.
//!
//! The real implementation needs the `xla` crate, which is not in the
//! offline registry; it is compiled only with the `pjrt` cargo feature.
//! Without it, the stub below presents the same API but fails to open a
//! client, so [`crate::runtime::auto_backend`] degrades to the native
//! stats backend and everything else keeps working.

use anyhow::Result;
#[cfg(feature = "pjrt")]
use anyhow::Context;
#[cfg(not(feature = "pjrt"))]
use anyhow::anyhow;

/// A compiled artifact ready to execute.
#[cfg(feature = "pjrt")]
pub struct CompiledModule {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    pub name: String,
}

/// The PJRT client plus a cache of compiled modules.
#[cfg(feature = "pjrt")]
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load HLO text from `path` and compile it.
    pub fn load_hlo_text(&self, path: &str) -> Result<CompiledModule> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path}"))?;
        Ok(CompiledModule { exe, client: self.client.clone(), name: path.to_string() })
    }
}

#[cfg(feature = "pjrt")]
impl CompiledModule {
    /// Execute with f32 inputs; each input is (data, dims). The module was
    /// lowered with `return_tuple=True`, so the single output literal is a
    /// tuple which we decompose; each element is returned as a flat f32 vec.
    ///
    /// Hot path (§Perf): inputs go straight from host slices to device
    /// buffers (`buffer_from_host_buffer` + `execute_b`) instead of through
    /// `Literal::vec1(..).reshape(..)`, which costs two extra copies and
    /// two allocations per argument per call.
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let buffers: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|(data, dims)| -> Result<xla::PjRtBuffer> {
                let dims_usize: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
                Ok(self
                    .client
                    .buffer_from_host_buffer::<f32>(data, &dims_usize, None)?)
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute_b::<xla::PjRtBuffer>(&buffers)?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = out.to_tuple().context("decomposing result tuple")?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().context("reading f32 output"))
            .collect()
    }
}

/// Stub: a compiled artifact (never constructed without the `pjrt` feature).
#[cfg(not(feature = "pjrt"))]
pub struct CompiledModule {
    pub name: String,
}

/// Stub PJRT client — [`PjrtRuntime::cpu`] always fails, so callers fall
/// back to the native backend.
#[cfg(not(feature = "pjrt"))]
pub struct PjrtRuntime {
    _private: (),
}

#[cfg(not(feature = "pjrt"))]
impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        Err(anyhow!("built without the `pjrt` feature; XLA execution unavailable"))
    }

    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    pub fn load_hlo_text(&self, path: &str) -> Result<CompiledModule> {
        Err(anyhow!("built without the `pjrt` feature; cannot load {path}"))
    }
}

#[cfg(not(feature = "pjrt"))]
impl CompiledModule {
    pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        Err(anyhow!("built without the `pjrt` feature; cannot execute {}", self.name))
    }
}

#[cfg(test)]
mod tests {
    // PJRT-dependent tests live in rust/tests/runtime_integration.rs so
    // `cargo test --lib` stays hermetic (no artifacts needed). This module
    // only checks error paths that need no artifacts.
    use super::*;

    #[cfg(feature = "pjrt")]
    #[test]
    fn missing_artifact_is_error() {
        let rt = PjrtRuntime::cpu().expect("CPU PJRT client");
        assert!(rt.load_hlo_text("/nonexistent/file.hlo.txt").is_err());
        assert!(!rt.platform().is_empty());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_client_reports_unavailable() {
        let err = PjrtRuntime::cpu().err().expect("stub must not construct");
        assert!(format!("{err:#}").contains("pjrt"));
    }
}
