//! Job → shard assignment by rendezvous (highest-random-weight) hashing.
//!
//! The PR-2/PR-3 services assigned `job_id % shards`, which a skewed
//! tenant id scheme defeats outright: a cluster whose submitter allocates
//! ids in strides (`tenant * 1000 + n`, or "all even") piles every job
//! onto a few shards while the rest idle. Rendezvous hashing scores each
//! (job, shard) pair with a mixed 64-bit hash and routes the job to the
//! highest score, so any id population spreads ~uniformly, assignment is
//! stable (same job → same shard, always), and growing the shard count
//! only *moves* the jobs the new shard wins — everything else stays put
//! (tested below).

/// SplitMix64 finalizer — a full-avalanche 64-bit mixer.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// The shard that wins `job_id` among `shards` candidates. `shards == 0`
/// is treated as 1. O(shards) per call — shard counts are small (a
/// handful of worker threads), so this stays a few nanoseconds and needs
/// no per-job routing table.
pub fn shard_of(job_id: u64, shards: usize) -> usize {
    if shards <= 1 {
        return 0;
    }
    let seed = mix(job_id);
    let mut best = 0usize;
    let mut best_score = mix(seed); // s = 0: seed ^ 0

    for s in 1..shards {
        let score = mix(seed ^ s as u64);
        if score > best_score {
            best_score = score;
            best = s;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stable_and_in_range() {
        for shards in [1usize, 2, 3, 8, 16] {
            for id in 0..200u64 {
                let s = shard_of(id, shards);
                assert!(s < shards);
                assert_eq!(s, shard_of(id, shards), "assignment must be stable");
            }
        }
        assert_eq!(shard_of(42, 0), 0);
        assert_eq!(shard_of(42, 1), 0);
    }

    #[test]
    fn skewed_tenant_ids_still_spread() {
        // Adversarial populations for `id % shards`: strided, all-even,
        // high-bits-only. Rendezvous must spread each of them.
        let shards = 8usize;
        let populations: [Vec<u64>; 3] = [
            (0..1000u64).map(|i| i * shards as u64).collect(), // id % 8 == 0 for all
            (0..1000u64).map(|i| i * 2).collect(),
            (0..1000u64).map(|i| i << 32).collect(),
        ];
        for ids in &populations {
            let mut counts = vec![0usize; shards];
            for &id in ids {
                counts[shard_of(id, shards)] += 1;
            }
            let expect = ids.len() / shards;
            for (s, &c) in counts.iter().enumerate() {
                assert!(
                    c > expect / 2 && c < expect * 2,
                    "shard {s} got {c} of {} (expect ~{expect}): {counts:?}",
                    ids.len()
                );
            }
        }
    }

    #[test]
    fn adding_a_shard_only_moves_jobs_to_the_new_shard() {
        // The rendezvous property modulo arithmetic lacks: growing the
        // fleet never shuffles jobs between existing shards.
        for shards in [1usize, 2, 4, 7] {
            for id in 0..500u64 {
                let before = shard_of(id, shards);
                let after = shard_of(id, shards + 1);
                assert!(
                    after == before || after == shards,
                    "id {id}: {before} -> {after} when adding shard {shards}"
                );
            }
        }
    }
}
