//! A bounded blocking channel (no `crossbeam` in the offline registry):
//! the shard-ingest backbone of the live analysis server. `send` blocks
//! while the queue is at capacity — that *is* the per-shard backpressure
//! contract: a fast producer is throttled to the pace of the slowest shard
//! it routes to, so queue memory stays bounded on unbounded streams.
//!
//! Semantics mirror `std::sync::mpsc` where they overlap:
//!
//! - any number of senders (clone), one receiver;
//! - dropping every sender closes the channel — `recv` drains what is
//!   buffered, then returns `None`;
//! - dropping the receiver poisons the channel — `send` returns the
//!   rejected item back to the caller instead of blocking forever.
//!
//! Two batched-ingest refinements on top of the classic shape:
//!
//! - **Weighted capacity.** Every item carries a weight
//!   ([`BoundedSender::send`] weighs 1; [`BoundedSender::push_batch`]
//!   weighs its event count), and `cap` bounds the buffered weight — so a
//!   queue of `EventBatch`es is bounded in *events*, not batch handles,
//!   and memory stays proportional to `cap` no matter the batch size mix.
//!   One batch is always admitted into an empty queue even when it
//!   outweighs `cap` (progress guarantee: an oversize batch can never
//!   deadlock).
//! - **Targeted signaling.** Waiter counts live in the shared state, so a
//!   push signals `not_empty` only when the receiver is actually parked
//!   and a pop signals `not_full` only when a sender is — the common
//!   uncontended push/pop is one lock acquisition and zero syscalls,
//!   instead of an unconditional notify per operation.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

struct State<T> {
    /// Buffered items with their weights.
    buf: VecDeque<(T, usize)>,
    /// Total buffered weight (Σ item weights) — what `cap` bounds.
    weight: usize,
    /// No sender left — drain and stop.
    senders: usize,
    /// Receiver gone — sends are futile.
    receiver_alive: bool,
    /// Senders parked on `not_full` (targeted wakeups).
    send_waiters: usize,
    /// Receivers parked on `not_empty` (0 or 1; the type is SPSC on the
    /// pop side, but the count keeps the signaling logic uniform).
    recv_waiters: usize,
}

struct Shared<T> {
    state: Mutex<State<T>>,
    cap: usize,
    not_full: Condvar,
    not_empty: Condvar,
}

/// Outcome of a [`BoundedReceiver::pop_timeout`].
#[derive(Debug, PartialEq, Eq)]
pub enum PopTimeout<T> {
    /// An item arrived (or was already buffered).
    Item(T),
    /// Nothing arrived within the window; the channel is still open. The
    /// live shard workers use this tick to run lifecycle `force_scan`
    /// without depending on the serve loop's pump cadence.
    TimedOut,
    /// Every sender dropped and the buffer is drained.
    Closed,
}

/// Create a bounded channel with room for `cap` total weight (min 1).
/// With the plain `send`/`try_send` API every item weighs 1, so `cap` is
/// an item count, exactly as before; batched producers account capacity
/// in events via [`BoundedSender::push_batch`].
pub fn bounded<T>(cap: usize) -> (BoundedSender<T>, BoundedReceiver<T>) {
    let shared = Arc::new(Shared {
        state: Mutex::new(State {
            buf: VecDeque::new(),
            weight: 0,
            senders: 1,
            receiver_alive: true,
            send_waiters: 0,
            recv_waiters: 0,
        }),
        cap: cap.max(1),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
    });
    (BoundedSender { shared: Arc::clone(&shared) }, BoundedReceiver { shared })
}

/// Producer half. Cloning adds a sender; the channel closes when the last
/// sender drops.
pub struct BoundedSender<T> {
    shared: Arc<Shared<T>>,
}

impl<T> BoundedSender<T> {
    /// Enqueue `item` at weight 1, blocking while the queue is full.
    /// Returns the item back if the receiver is gone.
    pub fn send(&self, item: T) -> Result<(), T> {
        self.push_batch(item, 1)
    }

    /// Enqueue one batch whose capacity cost is `events` (floored at 1 so
    /// zero-weight ticks still occupy a slot and cannot accumulate
    /// unboundedly). Blocks while the buffered weight is at `cap`, except
    /// that a batch is always admitted into an *empty* queue — a batch
    /// heavier than `cap` makes progress instead of deadlocking. One lock
    /// acquisition and at most one condvar signal per batch, however many
    /// events it carries.
    pub fn push_batch(&self, item: T, events: usize) -> Result<(), T> {
        let w = events.max(1);
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if !st.receiver_alive {
                return Err(item);
            }
            if st.weight + w <= self.shared.cap || st.buf.is_empty() {
                st.buf.push_back((item, w));
                st.weight += w;
                if st.recv_waiters > 0 {
                    self.shared.not_empty.notify_one();
                }
                return Ok(());
            }
            st.send_waiters += 1;
            st = self.shared.not_full.wait(st).unwrap();
            st.send_waiters -= 1;
        }
    }

    /// Enqueue `item` (weight 1) only if there is room right now — never
    /// blocks. `Err` returns the item back, whether the queue was full or
    /// the receiver is gone. The live server's idle tick uses this: a
    /// tick is advisory, and a shard busy enough to have a full queue is
    /// already running its scans through the normal feed path.
    pub fn try_send(&self, item: T) -> Result<(), T> {
        self.try_push_batch(item, 1)
    }

    /// Non-blocking [`BoundedSender::push_batch`].
    pub fn try_push_batch(&self, item: T, events: usize) -> Result<(), T> {
        let w = events.max(1);
        let mut st = self.shared.state.lock().unwrap();
        if !st.receiver_alive || (st.weight + w > self.shared.cap && !st.buf.is_empty()) {
            return Err(item);
        }
        st.buf.push_back((item, w));
        st.weight += w;
        if st.recv_waiters > 0 {
            self.shared.not_empty.notify_one();
        }
        Ok(())
    }

    /// Items currently buffered (diagnostic; racy by nature).
    pub fn len(&self) -> usize {
        self.shared.state.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total buffered weight — events, for a queue of batches.
    pub fn weight(&self) -> usize {
        self.shared.state.lock().unwrap().weight
    }
}

impl<T> Clone for BoundedSender<T> {
    fn clone(&self) -> Self {
        self.shared.state.lock().unwrap().senders += 1;
        BoundedSender { shared: Arc::clone(&self.shared) }
    }
}

impl<T> Drop for BoundedSender<T> {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 && st.recv_waiters > 0 {
            // Wake a receiver blocked on an empty queue so it can observe
            // the close and return None.
            self.shared.not_empty.notify_all();
        }
    }
}

/// Consumer half (single receiver).
pub struct BoundedReceiver<T> {
    shared: Arc<Shared<T>>,
}

impl<T> BoundedReceiver<T> {
    /// Release `w` weight after a pop and wake one parked sender if any —
    /// the pop-side half of targeted signaling. Callers hold the lock.
    fn on_pop(&self, st: &mut State<T>, w: usize) {
        st.weight -= w;
        if st.send_waiters > 0 {
            self.shared.not_full.notify_one();
        }
    }

    /// Dequeue one item, blocking while the queue is empty. Returns `None`
    /// once every sender has dropped and the buffer is drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some((item, w)) = st.buf.pop_front() {
                self.on_pop(&mut st, w);
                return Some(item);
            }
            if st.senders == 0 {
                return None;
            }
            st.recv_waiters += 1;
            st = self.shared.not_empty.wait(st).unwrap();
            st.recv_waiters -= 1;
        }
    }

    /// [`BoundedReceiver::recv`] under the batch name, for symmetry with
    /// [`BoundedSender::push_batch`].
    pub fn pop_batch(&self) -> Option<T> {
        self.recv()
    }

    /// Dequeue one item, blocking at most `timeout`. The tri-state result
    /// distinguishes "nothing yet" from "channel closed", so a shard
    /// worker can run its periodic lifecycle scan on [`PopTimeout::TimedOut`]
    /// and still exit promptly on [`PopTimeout::Closed`].
    pub fn pop_timeout(&self, timeout: Duration) -> PopTimeout<T> {
        let deadline = Instant::now() + timeout;
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some((item, w)) = st.buf.pop_front() {
                self.on_pop(&mut st, w);
                return PopTimeout::Item(item);
            }
            if st.senders == 0 {
                return PopTimeout::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopTimeout::TimedOut;
            }
            st.recv_waiters += 1;
            let (guard, _res) = self.shared.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
            st.recv_waiters -= 1;
            // Loop re-checks the buffer: a wakeup racing the deadline
            // still drains an item that actually arrived.
        }
    }

    /// Dequeue one item if immediately available.
    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.shared.state.lock().unwrap();
        match st.buf.pop_front() {
            Some((item, w)) => {
                self.on_pop(&mut st, w);
                Some(item)
            }
            None => None,
        }
    }

    pub fn len(&self) -> usize {
        self.shared.state.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total buffered weight — events, for a queue of batches.
    pub fn weight(&self) -> usize {
        self.shared.state.lock().unwrap().weight
    }
}

impl<T> Drop for BoundedReceiver<T> {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        st.receiver_alive = false;
        st.buf.clear();
        st.weight = 0;
        // Unblock senders waiting for room; they'll see the poisoned flag.
        self.shared.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc as StdArc;

    #[test]
    fn fifo_order_preserved() {
        let (tx, rx) = bounded::<u64>(100);
        for i in 0..50 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let mut got = Vec::new();
        while let Some(x) = rx.recv() {
            got.push(x);
        }
        assert_eq!(got, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn send_blocks_at_capacity() {
        let (tx, rx) = bounded::<u64>(2);
        let max_seen = StdArc::new(AtomicUsize::new(0));
        let max_clone = StdArc::clone(&max_seen);
        let producer = std::thread::spawn(move || {
            for i in 0..200 {
                let depth = tx.len();
                max_clone.fetch_max(depth, Ordering::SeqCst);
                tx.send(i).unwrap();
            }
        });
        let mut count = 0;
        while let Some(_x) = rx.recv() {
            count += 1;
            std::thread::yield_now();
        }
        producer.join().unwrap();
        assert_eq!(count, 200);
        // The producer never observed more than `cap` buffered items.
        assert!(max_seen.load(Ordering::SeqCst) <= 2);
    }

    #[test]
    fn recv_returns_none_after_close() {
        let (tx, rx) = bounded::<u8>(4);
        tx.send(1).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), None);
        assert_eq!(rx.try_recv(), None);
    }

    #[test]
    fn send_errors_after_receiver_drop() {
        let (tx, rx) = bounded::<u8>(1);
        drop(rx);
        assert_eq!(tx.send(9), Err(9));
    }

    #[test]
    fn cloned_senders_all_close() {
        let (tx, rx) = bounded::<u8>(8);
        let tx2 = tx.clone();
        let a = std::thread::spawn(move || tx.send(1).unwrap());
        let b = std::thread::spawn(move || tx2.send(2).unwrap());
        a.join().unwrap();
        b.join().unwrap();
        let mut got = vec![rx.recv().unwrap(), rx.recv().unwrap()];
        got.sort_unstable();
        assert_eq!(got, vec![1, 2]);
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn receiver_blocks_until_item_arrives() {
        let (tx, rx) = bounded::<u8>(1);
        let h = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            tx.send(7).unwrap();
        });
        assert_eq!(rx.recv(), Some(7));
        h.join().unwrap();
    }

    #[test]
    fn batch_weight_bounds_capacity_in_events() {
        // cap 10 events: two 4-event batches fit, the third blocks until
        // a pop releases weight.
        let (tx, rx) = bounded::<Vec<u64>>(10);
        tx.push_batch(vec![0; 4], 4).unwrap();
        tx.push_batch(vec![1; 4], 4).unwrap();
        assert_eq!(tx.weight(), 8);
        assert_eq!(
            tx.try_push_batch(vec![2; 4], 4),
            Err(vec![2; 4]),
            "third batch exceeds the event budget"
        );
        let blocked = std::thread::spawn(move || {
            tx.push_batch(vec![2; 4], 4).unwrap();
            tx.weight()
        });
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.pop_batch(), Some(vec![0; 4]));
        let w = blocked.join().unwrap();
        assert!(w <= 10, "blocked push admitted within the budget, got weight {w}");
        assert_eq!(rx.pop_batch(), Some(vec![1; 4]));
        assert_eq!(rx.pop_batch(), Some(vec![2; 4]));
    }

    #[test]
    fn oversize_batch_enters_an_empty_queue() {
        // A batch heavier than the whole cap must not deadlock: it is
        // admitted alone, and the queue refuses more until it drains.
        let (tx, rx) = bounded::<Vec<u64>>(4);
        tx.push_batch(vec![9; 100], 100).unwrap();
        assert_eq!(tx.try_push_batch(vec![1], 1), Err(vec![1]));
        assert_eq!(rx.pop_batch(), Some(vec![9; 100]));
        tx.push_batch(vec![1], 1).unwrap();
        assert_eq!(rx.pop_batch(), Some(vec![1]));
    }

    #[test]
    fn pop_timeout_times_out_then_delivers_then_closes() {
        let (tx, rx) = bounded::<u8>(2);
        let t0 = Instant::now();
        assert_eq!(rx.pop_timeout(Duration::from_millis(25)), PopTimeout::TimedOut);
        assert!(t0.elapsed() >= Duration::from_millis(25));
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            tx.send(5).unwrap();
            // tx drops here → channel closes.
        });
        assert_eq!(rx.pop_timeout(Duration::from_secs(5)), PopTimeout::Item(5));
        h.join().unwrap();
        assert_eq!(rx.pop_timeout(Duration::from_millis(1)), PopTimeout::Closed);
    }

    #[test]
    fn targeted_signaling_counts_no_parked_waiters_when_uncontended() {
        // Uncontended pushes and pops must leave both waiter counts at
        // zero — the structural invariant behind "no notify per op".
        let (tx, rx) = bounded::<u64>(64);
        for i in 0..32 {
            tx.send(i).unwrap();
        }
        for _ in 0..32 {
            rx.try_recv().unwrap();
        }
        let st = rx.shared.state.lock().unwrap();
        assert_eq!(st.send_waiters, 0);
        assert_eq!(st.recv_waiters, 0);
        assert_eq!(st.weight, 0);
    }

    #[test]
    fn contended_producers_and_consumer_drain_everything() {
        // Stress the targeted wakeups: several producers block and unblock
        // against one slow consumer; every item must arrive exactly once.
        let (tx, rx) = bounded::<u64>(3);
        let mut handles = Vec::new();
        for p in 0..4u64 {
            let txc = tx.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100 {
                    txc.send(p * 1000 + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut got = Vec::new();
        loop {
            match rx.pop_timeout(Duration::from_millis(200)) {
                PopTimeout::Item(x) => got.push(x),
                PopTimeout::TimedOut => continue,
                PopTimeout::Closed => break,
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        got.sort_unstable();
        let mut want: Vec<u64> =
            (0..4u64).flat_map(|p| (0..100).map(move |i| p * 1000 + i)).collect();
        want.sort_unstable();
        assert_eq!(got, want);
    }
}
