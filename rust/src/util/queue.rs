//! A bounded blocking channel (no `crossbeam` in the offline registry):
//! the shard-ingest backbone of the live analysis server. `send` blocks
//! while the queue is at capacity — that *is* the per-shard backpressure
//! contract: a fast producer is throttled to the pace of the slowest shard
//! it routes to, so queue memory stays bounded on unbounded streams.
//!
//! Semantics mirror `std::sync::mpsc` where they overlap:
//!
//! - any number of senders (clone), one receiver;
//! - dropping every sender closes the channel — `recv` drains what is
//!   buffered, then returns `None`;
//! - dropping the receiver poisons the channel — `send` returns the
//!   rejected item back to the caller instead of blocking forever.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct State<T> {
    buf: VecDeque<T>,
    /// No sender left — drain and stop.
    senders: usize,
    /// Receiver gone — sends are futile.
    receiver_alive: bool,
}

struct Shared<T> {
    state: Mutex<State<T>>,
    cap: usize,
    not_full: Condvar,
    not_empty: Condvar,
}

/// Create a bounded channel with room for `cap` items (min 1).
pub fn bounded<T>(cap: usize) -> (BoundedSender<T>, BoundedReceiver<T>) {
    let shared = Arc::new(Shared {
        state: Mutex::new(State {
            buf: VecDeque::new(),
            senders: 1,
            receiver_alive: true,
        }),
        cap: cap.max(1),
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
    });
    (BoundedSender { shared: Arc::clone(&shared) }, BoundedReceiver { shared })
}

/// Producer half. Cloning adds a sender; the channel closes when the last
/// sender drops.
pub struct BoundedSender<T> {
    shared: Arc<Shared<T>>,
}

impl<T> BoundedSender<T> {
    /// Enqueue `item`, blocking while the queue is full. Returns the item
    /// back if the receiver is gone.
    pub fn send(&self, item: T) -> Result<(), T> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if !st.receiver_alive {
                return Err(item);
            }
            if st.buf.len() < self.shared.cap {
                st.buf.push_back(item);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            st = self.shared.not_full.wait(st).unwrap();
        }
    }

    /// Enqueue `item` only if there is room right now — never blocks.
    /// `Err` returns the item back, whether the queue was full or the
    /// receiver is gone. The live server's idle tick uses this: a tick is
    /// advisory, and a shard busy enough to have a full queue is already
    /// running its scans through the normal feed path.
    pub fn try_send(&self, item: T) -> Result<(), T> {
        let mut st = self.shared.state.lock().unwrap();
        if !st.receiver_alive || st.buf.len() >= self.shared.cap {
            return Err(item);
        }
        st.buf.push_back(item);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    /// Items currently buffered (diagnostic; racy by nature).
    pub fn len(&self) -> usize {
        self.shared.state.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for BoundedSender<T> {
    fn clone(&self) -> Self {
        self.shared.state.lock().unwrap().senders += 1;
        BoundedSender { shared: Arc::clone(&self.shared) }
    }
}

impl<T> Drop for BoundedSender<T> {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        st.senders -= 1;
        if st.senders == 0 {
            // Wake a receiver blocked on an empty queue so it can observe
            // the close and return None.
            self.shared.not_empty.notify_all();
        }
    }
}

/// Consumer half (single receiver).
pub struct BoundedReceiver<T> {
    shared: Arc<Shared<T>>,
}

impl<T> BoundedReceiver<T> {
    /// Dequeue one item, blocking while the queue is empty. Returns `None`
    /// once every sender has dropped and the buffer is drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if let Some(item) = st.buf.pop_front() {
                self.shared.not_full.notify_one();
                return Some(item);
            }
            if st.senders == 0 {
                return None;
            }
            st = self.shared.not_empty.wait(st).unwrap();
        }
    }

    /// Dequeue one item if immediately available.
    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.shared.state.lock().unwrap();
        let item = st.buf.pop_front();
        if item.is_some() {
            self.shared.not_full.notify_one();
        }
        item
    }

    pub fn len(&self) -> usize {
        self.shared.state.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Drop for BoundedReceiver<T> {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        st.receiver_alive = false;
        st.buf.clear();
        // Unblock senders waiting for room; they'll see the poisoned flag.
        self.shared.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc as StdArc;

    #[test]
    fn fifo_order_preserved() {
        let (tx, rx) = bounded::<u64>(100);
        for i in 0..50 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let mut got = Vec::new();
        while let Some(x) = rx.recv() {
            got.push(x);
        }
        assert_eq!(got, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn send_blocks_at_capacity() {
        let (tx, rx) = bounded::<u64>(2);
        let max_seen = StdArc::new(AtomicUsize::new(0));
        let max_clone = StdArc::clone(&max_seen);
        let producer = std::thread::spawn(move || {
            for i in 0..200 {
                let depth = tx.len();
                max_clone.fetch_max(depth, Ordering::SeqCst);
                tx.send(i).unwrap();
            }
        });
        let mut count = 0;
        while let Some(_x) = rx.recv() {
            count += 1;
            std::thread::yield_now();
        }
        producer.join().unwrap();
        assert_eq!(count, 200);
        // The producer never observed more than `cap` buffered items.
        assert!(max_seen.load(Ordering::SeqCst) <= 2);
    }

    #[test]
    fn recv_returns_none_after_close() {
        let (tx, rx) = bounded::<u8>(4);
        tx.send(1).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), None);
        assert_eq!(rx.try_recv(), None);
    }

    #[test]
    fn send_errors_after_receiver_drop() {
        let (tx, rx) = bounded::<u8>(1);
        drop(rx);
        assert_eq!(tx.send(9), Err(9));
    }

    #[test]
    fn cloned_senders_all_close() {
        let (tx, rx) = bounded::<u8>(8);
        let tx2 = tx.clone();
        let a = std::thread::spawn(move || tx.send(1).unwrap());
        let b = std::thread::spawn(move || tx2.send(2).unwrap());
        a.join().unwrap();
        b.join().unwrap();
        let mut got = vec![rx.recv().unwrap(), rx.recv().unwrap()];
        got.sort_unstable();
        assert_eq!(got, vec![1, 2]);
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn receiver_blocks_until_item_arrives() {
        let (tx, rx) = bounded::<u8>(1);
        let h = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(20));
            tx.send(7).unwrap();
        });
        assert_eq!(rx.recv(), Some(7));
        h.join().unwrap();
    }
}
