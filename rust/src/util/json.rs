//! Minimal JSON value model, parser and serializer.
//!
//! The offline registry has no `serde`/`serde_json`, so the trace codec
//! (`trace::codec`) is built on this hand-rolled implementation. It supports
//! the full JSON grammar (RFC 8259) minus exotic number edge cases beyond
//! f64, which is all the trace format needs.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so serialization is deterministic,
/// which keeps golden-file tests and trace diffs stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ----
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ---- accessors (None on type mismatch) ----
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u64::MAX as f64 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; Null for missing / non-object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Insert into an object (panics if self is not an object — construction
    /// bug, not data error).
    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
                self
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    // ---- field decoding with errors (for the trace codec) ----
    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key).as_f64().ok_or_else(|| field_err(key, "number"))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64, JsonError> {
        self.get(key).as_u64().ok_or_else(|| field_err(key, "unsigned integer"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        Ok(self.req_u64(key)? as usize)
    }

    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key).as_str().ok_or_else(|| field_err(key, "string"))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.get(key).as_arr().ok_or_else(|| field_err(key, "array"))
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).as_f64().unwrap_or(default)
    }

    // ---- serialization ----
    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::with_capacity(256);
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty-printed with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::with_capacity(1024);
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document from a string.
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

fn field_err(key: &str, ty: &str) -> JsonError {
    JsonError { offset: 0, message: format!("field '{key}': expected {ty}") }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if !x.is_finite() {
        // JSON has no Inf/NaN; the trace never produces them, but be safe.
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        // Shortest roundtrip representation rust gives us.
        out.push_str(&format!("{x}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let ch = if (0xd800..0xdc00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    if (0xdc00..0xe000).contains(&lo) {
                                        char::from_u32(
                                            0x10000 + ((cp - 0xd800) << 10) + (lo - 0xdc00),
                                        )
                                    } else {
                                        // Unpaired low half: reject (the
                                        // unchecked subtraction used to
                                        // overflow in debug builds).
                                        None
                                    }
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| self.err("bad unicode escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = &self.bytes[self.pos..];
                    let st = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = st.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("short unicode escape"));
        }
        let hx = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hx, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

// Convenience From impls so trace encoding reads naturally.
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"nested":{"k":[]},"z":-0.125}"#;
        let v = Json::parse(src).unwrap();
        let compact = v.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), v);
        let pretty = v.to_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}f €".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape_and_surrogates() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap(),
            Json::Str("é".into())
        );
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
        // A high surrogate followed by a non-low-surrogate escape is an
        // error, not a debug-mode overflow panic.
        assert!(Json::parse(r#""\ud800A""#).is_err());
        assert!(Json::parse(r#""\ud800""#).is_err());
    }

    #[test]
    fn errors_have_offsets() {
        let e = Json::parse("[1, 2,, 3]").unwrap_err();
        assert!(e.offset > 0);
        assert!(Json::parse("{\"a\":}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"a\" : [ 1 , 2 ] }\r\n").unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 2);
    }

    #[test]
    fn accessors_and_builders() {
        let mut o = Json::obj();
        o.set("x", 5u64.into()).set("y", "hi".into()).set("b", true.into());
        assert_eq!(o.req_u64("x").unwrap(), 5);
        assert_eq!(o.req_str("y").unwrap(), "hi");
        assert!(o.req_f64("missing").is_err());
        assert_eq!(o.opt_f64("missing", 9.5), 9.5);
        assert_eq!(o.get("b").as_bool(), Some(true));
    }

    #[test]
    fn large_int_precision() {
        // 2^53-safe integers survive roundtrip exactly.
        let v = Json::Num(9007199254740992.0);
        assert_eq!(Json::parse(&v.to_string()).unwrap().as_f64(), v.as_f64());
    }

    #[test]
    fn deeply_nested_array() {
        let mut s = String::new();
        for _ in 0..100 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..100 {
            s.push(']');
        }
        assert!(Json::parse(&s).is_ok());
    }
}
