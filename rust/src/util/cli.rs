//! Tiny declarative CLI argument parser (no `clap` in the offline registry).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! subcommands, with generated `--help` text. Used by `main.rs`, the examples
//! and every bench binary (all benches accept `--quick` / `--out`).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declared option.
#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    takes_value: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// Command definition: options + flags + help, optionally with subcommands.
pub struct Command {
    name: String,
    about: String,
    opts: Vec<OptSpec>,
    subcommands: Vec<Command>,
}

impl Command {
    pub fn new(name: &str, about: &str) -> Self {
        Command {
            name: name.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
            subcommands: Vec::new(),
        }
    }

    /// Add a `--name <value>` option with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            takes_value: true,
        });
        self
    }

    /// Add a required `--name <value>` option (no default).
    pub fn opt_req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            takes_value: true,
        });
        self
    }

    /// Add a boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            takes_value: false,
        });
        self
    }

    pub fn subcommand(mut self, cmd: Command) -> Self {
        self.subcommands.push(cmd);
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.name, self.about);
        if !self.subcommands.is_empty() {
            let _ = writeln!(s, "SUBCOMMANDS:");
            for sc in &self.subcommands {
                let _ = writeln!(s, "  {:<22} {}", sc.name, sc.about);
            }
            let _ = writeln!(s);
        }
        if !self.opts.is_empty() {
            let _ = writeln!(s, "OPTIONS:");
            for o in &self.opts {
                let left = if o.takes_value {
                    match &o.default {
                        Some(d) => format!("--{} <v> [{}]", o.name, d),
                        None => format!("--{} <v> (required)", o.name),
                    }
                } else {
                    format!("--{}", o.name)
                };
                let _ = writeln!(s, "  {:<28} {}", left, o.help);
            }
        }
        let _ = writeln!(s, "  {:<28} {}", "--help", "print this help");
        s
    }

    /// Parse argv (without the program name). Returns
    /// `(subcommand_name_or_empty, Args)` or a user-facing error string.
    pub fn parse(&self, argv: &[String]) -> Result<(String, Args), String> {
        // Subcommand dispatch: first non-flag token that names one.
        if !self.subcommands.is_empty() {
            if let Some(first) = argv.first() {
                if first == "--help" || first == "-h" {
                    return Err(self.help_text());
                }
                if let Some(sc) = self.subcommands.iter().find(|c| &c.name == first) {
                    let (_, args) = sc.parse(&argv[1..])?;
                    return Ok((sc.name.clone(), args));
                }
                return Err(format!(
                    "unknown subcommand '{}'\n\n{}",
                    first,
                    self.help_text()
                ));
            }
            return Err(self.help_text());
        }
        let mut args = Args::default();
        // Apply defaults first.
        for o in &self.opts {
            if let Some(d) = &o.default {
                args.values.insert(o.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(self.help_text());
            }
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option '--{key}'\n\n{}", self.help_text()))?;
                if spec.takes_value {
                    let val = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("option '--{key}' needs a value"))?
                        }
                    };
                    args.values.insert(key, val);
                } else {
                    if inline.is_some() {
                        return Err(format!("flag '--{key}' takes no value"));
                    }
                    args.flags.insert(key, true);
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        // Check required options.
        for o in &self.opts {
            if o.takes_value && o.default.is_none() && !args.values.contains_key(&o.name) {
                return Err(format!("missing required option '--{}'", o.name));
            }
        }
        Ok((String::new(), args))
    }

    /// Parse std::env::args(); on error/help, print and exit.
    pub fn parse_env(&self) -> (String, Args) {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&argv) {
            Ok(r) => r,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(if msg.contains("OPTIONS:") { 0 } else { 2 });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let cmd = Command::new("t", "test").opt("seed", "42", "rng seed").flag("quick", "fast");
        let (_, a) = cmd.parse(&argv(&[])).unwrap();
        assert_eq!(a.get_u64("seed", 0), 42);
        assert!(!a.flag("quick"));
        let (_, a) = cmd.parse(&argv(&["--seed", "7", "--quick"])).unwrap();
        assert_eq!(a.get_u64("seed", 0), 7);
        assert!(a.flag("quick"));
    }

    #[test]
    fn equals_syntax_and_positional() {
        let cmd = Command::new("t", "test").opt("out", "-", "path");
        let (_, a) = cmd.parse(&argv(&["--out=x.json", "pos1", "pos2"])).unwrap();
        assert_eq!(a.get("out"), Some("x.json"));
        assert_eq!(a.positional(), &["pos1".to_string(), "pos2".to_string()]);
    }

    #[test]
    fn required_option_enforced() {
        let cmd = Command::new("t", "test").opt_req("input", "trace path");
        assert!(cmd.parse(&argv(&[])).is_err());
        let (_, a) = cmd.parse(&argv(&["--input", "f"])).unwrap();
        assert_eq!(a.get("input"), Some("f"));
    }

    #[test]
    fn unknown_option_rejected() {
        let cmd = Command::new("t", "test");
        assert!(cmd.parse(&argv(&["--nope"])).is_err());
    }

    #[test]
    fn subcommand_dispatch() {
        let cmd = Command::new("root", "r")
            .subcommand(Command::new("simulate", "run sim").opt("seed", "1", "seed"))
            .subcommand(Command::new("analyze", "run analysis").opt_req("input", "path"));
        let (name, a) = cmd.parse(&argv(&["simulate", "--seed", "9"])).unwrap();
        assert_eq!(name, "simulate");
        assert_eq!(a.get_u64("seed", 0), 9);
        assert!(cmd.parse(&argv(&["bogus"])).is_err());
        assert!(cmd.parse(&argv(&[])).is_err());
    }

    #[test]
    fn help_is_generated() {
        let cmd = Command::new("t", "test tool").opt("x", "1", "an x").flag("v", "verbose");
        let err = cmd.parse(&argv(&["--help"])).unwrap_err();
        assert!(err.contains("test tool"));
        assert!(err.contains("--x"));
        assert!(err.contains("verbose"));
    }

    #[test]
    fn numeric_helpers() {
        let cmd = Command::new("t", "test").opt("p", "0.5", "prob");
        let (_, a) = cmd.parse(&argv(&["--p", "0.25"])).unwrap();
        assert_eq!(a.get_f64("p", 0.0), 0.25);
        assert_eq!(a.get_f64("missing", 1.5), 1.5);
        assert_eq!(a.get_usize("p", 3), 3); // "0.25" not usize → default
    }
}
