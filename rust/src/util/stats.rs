//! Descriptive statistics used across the analyzer, the native stats
//! fallback and the benchmark harness: mean, variance, median, quantiles
//! (linear interpolation, matching numpy's default), Pearson correlation,
//! trapezoidal AUC.

/// Arithmetic mean; 0.0 for empty input (the analyzer treats empty peer sets
/// as "no evidence", which the rules handle explicitly).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Quantile with linear interpolation between order statistics, identical to
/// `numpy.quantile(xs, q)` — the L1 Pallas kernel and ref.py implement the
/// same definition so all three paths agree bit-for-bit (up to f32 rounding).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    quantile_sorted(&sorted, q)
}

/// Quantile on pre-sorted data (ascending).
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Median absolute deviation: `median(|x - median(xs)|)`. The robust
/// spread estimate behind verdict-trace effect sizes
/// ([`crate::analysis::explain`]) — unlike stddev it ignores the very
/// stragglers being scored. 0.0 for empty input, and for constant input
/// (callers must guard the degenerate denominator themselves).
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&devs)
}

/// Pearson correlation coefficient of two equal-length samples.
/// Returns 0.0 when either side is constant (undefined correlation) — the
/// PCC baseline treats "no variance" as "no linear relationship", which
/// matches how the paper's baseline behaves on constant features.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len(), "pearson: length mismatch");
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        cov += dx * dy;
        vx += dx * dx;
        vy += dy * dy;
    }
    if vx <= 0.0 || vy <= 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

/// Area under a curve given (x, y) points, by trapezoid rule after sorting
/// by x. Used for ROC AUC (x = FPR, y = TPR). Duplicated x values keep the
/// max y (the standard staircase-upper envelope used for ROC from a
/// threshold grid).
pub fn auc(points: &[(f64, f64)]) -> f64 {
    let mut pts: Vec<(f64, f64)> = points.to_vec();
    // Anchor at (0,0) and (1,1) like a standard ROC sweep.
    pts.push((0.0, 0.0));
    pts.push((1.0, 1.0));
    pts.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
    // Collapse duplicate x to max y.
    let mut env: Vec<(f64, f64)> = Vec::with_capacity(pts.len());
    for (x, y) in pts {
        match env.last_mut() {
            Some((lx, ly)) if (*lx - x).abs() < 1e-12 => *ly = ly.max(y),
            _ => env.push((x, y)),
        }
    }
    // Monotone upper envelope in y (ROC convex-ish staircase): running max.
    let mut run = 0.0f64;
    for p in env.iter_mut() {
        run = run.max(p.1);
        p.1 = run;
    }
    let mut area = 0.0;
    for w in env.windows(2) {
        let (x0, y0) = w[0];
        let (x1, y1) = w[1];
        area += (x1 - x0) * (y0 + y1) / 2.0;
    }
    area
}

/// Welford online mean/variance accumulator — used by the streaming
/// coordinator and the Table VII overhead sampler. Fields are
/// crate-visible so the fleet snapshot codec
/// ([`crate::live::persist`]) can round-trip the accumulator bit-exactly.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    pub(crate) n: u64,
    pub(crate) mean: f64,
    pub(crate) m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Streaming quantile estimator — the P² algorithm (Jain & Chlamtac,
/// CACM 1985): O(1) memory, no stored samples. Five markers track the
/// min, p/2, p, (1+p)/2 and max quantiles; each observation nudges the
/// interior markers toward their desired ranks with a piecewise-parabolic
/// height update. Exact for the first five observations. The fleet
/// baseline registry ([`crate::live::registry`]) keeps a handful of these
/// per feature to hold cross-job distributions on unbounded streams.
/// Fields are crate-visible so the fleet snapshot codec
/// ([`crate::live::persist`]) can round-trip the marker state bit-exactly.
#[derive(Debug, Clone)]
pub struct P2Quantile {
    pub(crate) p: f64,
    /// Marker heights q[0..5] (after init: ascending).
    pub(crate) q: [f64; 5],
    /// Actual marker positions, 1-based observation ranks.
    pub(crate) n: [f64; 5],
    /// Desired marker positions.
    pub(crate) np: [f64; 5],
    /// Per-observation desired-position increments.
    pub(crate) dn: [f64; 5],
    pub(crate) count: usize,
}

impl P2Quantile {
    pub fn new(p: f64) -> Self {
        let p = p.clamp(0.0, 1.0);
        P2Quantile {
            p,
            q: [0.0; 5],
            n: [1.0, 2.0, 3.0, 4.0, 5.0],
            np: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
            dn: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
            count: 0,
        }
    }

    /// The tracked quantile (0..=1).
    pub fn p(&self) -> f64 {
        self.p
    }

    /// Observations consumed.
    pub fn count(&self) -> usize {
        self.count
    }

    pub fn push(&mut self, x: f64) {
        if self.count < 5 {
            self.q[self.count] = x;
            self.count += 1;
            if self.count == 5 {
                self.q.sort_by(|a, b| a.total_cmp(b));
            }
            return;
        }
        self.count += 1;
        // Locate the marker cell containing x, widening the extremes.
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x < self.q[1] {
            0
        } else if x < self.q[2] {
            1
        } else if x < self.q[3] {
            2
        } else if x <= self.q[4] {
            3
        } else {
            self.q[4] = x;
            3
        };
        for i in (k + 1)..5 {
            self.n[i] += 1.0;
        }
        for i in 0..5 {
            self.np[i] += self.dn[i];
        }
        // Move interior markers toward their desired ranks (at most one
        // rank per observation, parabolic height with a linear fallback
        // when the parabola would break marker monotonicity).
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let d = if d >= 0.0 { 1.0 } else { -1.0 };
                let parab = self.parabolic(i, d);
                if self.q[i - 1] < parab && parab < self.q[i + 1] {
                    self.q[i] = parab;
                } else {
                    self.q[i] = self.linear(i, d);
                }
                self.n[i] += d;
            }
        }
    }

    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (q, n) = (&self.q, &self.n);
        q[i] + d / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.q[i] + d * (self.q[j] - self.q[i]) / (self.n[j] - self.n[i])
    }

    /// Current estimate: exact below five observations, the center marker
    /// after. 0.0 with no data (matching [`quantile`] on empty input).
    pub fn value(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if self.count < 5 {
            let mut v = self.q[..self.count].to_vec();
            v.sort_by(|a, b| a.total_cmp(b));
            return quantile_sorted(&v, self.p);
        }
        self.q[2]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn quantile_matches_numpy_convention() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
        assert!((quantile(&xs, 0.25) - 1.75).abs() < 1e-12);
        // unsorted input is sorted internally
        assert_eq!(quantile(&[4.0, 1.0, 3.0, 2.0], 0.5), 2.5);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[5.0, 1.0, 3.0]), 3.0);
        assert_eq!(median(&[1.0, 2.0, 3.0, 10.0]), 2.5);
    }

    #[test]
    fn mad_is_robust_spread() {
        // median 3, |devs| = [2,1,0,1,2] → mad 1.
        assert_eq!(mad(&[1.0, 2.0, 3.0, 4.0, 5.0]), 1.0);
        // Robust to one wild outlier: median 3, devs [2,1,0,1,997] → 1.
        assert_eq!(mad(&[1.0, 2.0, 3.0, 4.0, 1000.0]), 1.0);
        assert_eq!(mad(&[7.0, 7.0, 7.0]), 0.0);
        assert_eq!(mad(&[]), 0.0);
    }

    #[test]
    fn pearson_perfect_and_inverse() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let inv = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &inv) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_constant_is_zero() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
        assert_eq!(pearson(&[1.0], &[2.0]), 0.0);
    }

    #[test]
    fn pearson_uncorrelated_near_zero() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let ys = [1.0, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0];
        // Exact value here is -4/sqrt(42*8) ≈ -0.218 — weak correlation.
        assert!(pearson(&xs, &ys).abs() < 0.25);
    }

    #[test]
    fn auc_diagonal_is_half() {
        let pts = [(0.25, 0.25), (0.5, 0.5), (0.75, 0.75)];
        assert!((auc(&pts) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn auc_perfect_is_one() {
        let pts = [(0.0, 1.0)];
        assert!((auc(&pts) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn auc_empty_anchored() {
        assert!((auc(&[]) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn p2_exact_below_five_samples() {
        let mut p2 = P2Quantile::new(0.5);
        assert_eq!(p2.value(), 0.0);
        for x in [5.0, 1.0, 3.0] {
            p2.push(x);
        }
        assert_eq!(p2.value(), median(&[5.0, 1.0, 3.0]));
        assert_eq!(p2.count(), 3);
    }

    #[test]
    fn p2_tracks_uniform_quantiles() {
        // Deterministic pseudo-uniform values in [0, 100).
        let mut rng = crate::util::rng::Pcg64::seeded(99);
        let mut xs = Vec::new();
        let mut p50 = P2Quantile::new(0.5);
        let mut p90 = P2Quantile::new(0.9);
        let mut p95 = P2Quantile::new(0.95);
        for _ in 0..4000 {
            let x = rng.range_f64(0.0, 100.0);
            xs.push(x);
            p50.push(x);
            p90.push(x);
            p95.push(x);
        }
        assert!((p50.value() - quantile(&xs, 0.5)).abs() < 3.0, "p50 {}", p50.value());
        assert!((p90.value() - quantile(&xs, 0.9)).abs() < 3.0, "p90 {}", p90.value());
        assert!((p95.value() - quantile(&xs, 0.95)).abs() < 3.0, "p95 {}", p95.value());
    }

    #[test]
    fn p2_monotone_markers_on_skewed_data() {
        // Heavily skewed input must keep the estimate finite and within
        // the observed range.
        let mut p2 = P2Quantile::new(0.95);
        let mut rng = crate::util::rng::Pcg64::seeded(7);
        for _ in 0..2000 {
            let u = rng.f64();
            p2.push(u * u * u * 1000.0);
        }
        let v = p2.value();
        assert!(v.is_finite());
        assert!((0.0..=1000.0).contains(&v));
        assert!(v > 500.0, "p95 of cubed-uniform should be high, got {v}");
    }

    #[test]
    fn p2_constant_stream() {
        let mut p2 = P2Quantile::new(0.9);
        for _ in 0..100 {
            p2.push(4.25);
        }
        assert_eq!(p2.value(), 4.25);
        assert_eq!(p2.p(), 0.9);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.variance() - variance(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), 8);
    }
}
