//! Hand-rolled substrates: the offline crate registry lacks `serde`, `clap`,
//! `rand`, `rayon`/`tokio` and `criterion`, so the pieces the system needs
//! are implemented (and tested) here from scratch.

pub mod cli;
pub mod json;
pub mod queue;
pub mod rng;
pub mod shard;
pub mod stats;
pub mod table;
pub mod threadpool;
