//! ASCII table rendering for reports and bench output, matching the paper's
//! table layouts (Table III, V, VI, VII) so the bench output reads directly
//! against the paper.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Table { title: title.to_string(), ..Default::default() }
    }

    pub fn header(mut self, cols: &[&str]) -> Self {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self.aligns = vec![Align::Left; self.header.len()];
        self
    }

    pub fn aligns(mut self, aligns: &[Align]) -> Self {
        assert_eq!(aligns.len(), self.header.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        self.row(cells.iter().map(|s| s.to_string()).collect())
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                let cell = &cells[i];
                match self.aligns[i] {
                    Align::Left => s.push_str(&format!(" {:<w$} |", cell, w = widths[i])),
                    Align::Right => s.push_str(&format!(" {:>w$} |", cell, w = widths[i])),
                }
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Emit as CSV (for plotting figure data externally).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed decimals, trimming "-0.00" to "0.00".
pub fn fnum(x: f64, decimals: usize) -> String {
    let s = format!("{:.*}", decimals, x);
    if s.starts_with('-') && s[1..].chars().all(|c| c == '0' || c == '.') {
        s[1..].to_string()
    } else {
        s
    }
}

/// Format a ratio as a percentage string.
pub fn pct(x: f64) -> String {
    format!("{}%", fnum(100.0 * x, 2))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo")
            .header(&["name", "value"])
            .aligns(&[Align::Left, Align::Right]);
        t.row_strs(&["alpha", "1"]);
        t.row_strs(&["b", "12345"]);
        let s = t.render();
        assert!(s.contains("Demo"));
        assert!(s.contains("| alpha |     1 |"));
        assert!(s.contains("| b     | 12345 |"));
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("").header(&["a", "b"]);
        t.row_strs(&["x,y", "q\"z"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"z\""));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("").header(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn fnum_and_pct() {
        assert_eq!(fnum(1.23456, 2), "1.23");
        assert_eq!(fnum(-0.0001, 2), "0.00");
        assert_eq!(pct(0.1234), "12.34%");
    }
}
