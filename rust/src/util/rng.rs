//! Deterministic pseudo-random number generation and distributions.
//!
//! The offline crate registry has no `rand` crate, so we implement a small,
//! well-tested PCG-XSH-RR 64/32 generator plus the distributions the cluster
//! simulator needs (uniform, normal, log-normal, exponential, Zipf, Pareto).
//! Everything is seeded and fully deterministic: the same seed reproduces the
//! same cluster trace bit-for-bit, which the experiment harness relies on.

/// PCG-XSH-RR 64/32: 64-bit state LCG with a 32-bit xorshift-rotate output.
///
/// Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
/// Statistically Good Algorithms for Random Number Generation" (2014).
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Different streams with
    /// the same seed are statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor using stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Derive an independent child generator (e.g. one per node / per task)
    /// without correlating with the parent's future output.
    pub fn fork(&mut self, salt: u64) -> Pcg64 {
        let s = self.next_u64() ^ salt.wrapping_mul(0x9e3779b97f4a7c15);
        Pcg64::new(s, salt | 1)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random bits / 2^53
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) using Lemire's unbiased method.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (polar form rejected for determinism
    /// simplicity; basic form uses exactly two uniforms per pair).
    pub fn normal(&mut self) -> f64 {
        // Cache the second value of each Box-Muller pair? Keep stateless for
        // reproducibility across forks; two uniforms per sample is fine here.
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Truncated normal: resample (up to 64 tries) until within [lo, hi],
    /// then clamp. Used for task-duration noise which must stay positive.
    pub fn normal_clamped(&mut self, mean: f64, std: f64, lo: f64, hi: f64) -> f64 {
        for _ in 0..64 {
            let x = self.normal_ms(mean, std);
            if x >= lo && x <= hi {
                return x;
            }
        }
        mean.clamp(lo, hi)
    }

    /// Log-normal with underlying normal(mu, sigma).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_ms(mu, sigma).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Pareto with scale x_m and shape alpha: heavy-tailed sizes.
    pub fn pareto(&mut self, x_m: f64, alpha: f64) -> f64 {
        x_m / self.f64().max(f64::MIN_POSITIVE).powf(1.0 / alpha)
    }

    /// Zipf-distributed rank in [0, n): rank k has weight (k+1)^-s.
    /// Uses inverse-CDF over precomputed weights for small n, rejection for
    /// large n (Devroye). The simulator uses this for key-skew (data skew in
    /// shuffle partitions — the mechanism behind Kmeans/NaiveBayes stragglers).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        assert!(n > 0);
        if n <= 1024 {
            // Exact inverse-CDF.
            let mut total = 0.0;
            for k in 0..n {
                total += 1.0 / ((k + 1) as f64).powf(s);
            }
            let mut target = self.f64() * total;
            for k in 0..n {
                target -= 1.0 / ((k + 1) as f64).powf(s);
                if target <= 0.0 {
                    return k;
                }
            }
            n - 1
        } else {
            // Rejection sampling (Devroye, Non-Uniform Random Variate
            // Generation, X.6.1), valid for s > 1 and decent for s near 1.
            let s = s.max(1.001);
            let b = 2f64.powf(s - 1.0);
            loop {
                let u = self.f64().max(f64::MIN_POSITIVE);
                let v = self.f64();
                let x = u.powf(-1.0 / (s - 1.0)).floor();
                let t = (1.0 + 1.0 / x).powf(s - 1.0);
                if x <= n as f64 && v * x * (t - 1.0) / (b - 1.0) <= t / b {
                    return (x as u64) - 1;
                }
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element index.
    pub fn pick(&mut self, len: usize) -> usize {
        self.below(len as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn fork_is_independent_of_parent_future() {
        let mut a = Pcg64::seeded(7);
        let mut child = a.fork(1);
        let c1: Vec<u64> = (0..10).map(|_| child.next_u64()).collect();
        // Re-derive the same fork from a fresh parent: identical child stream.
        let mut a2 = Pcg64::seeded(7);
        let mut child2 = a2.fork(1);
        let c2: Vec<u64> = (0..10).map(|_| child2.next_u64()).collect();
        assert_eq!(c1, c2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seeded(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_small_range() {
        let mut r = Pcg64::seeded(11);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            let p = c as f64 / n as f64;
            assert!((p - 0.2).abs() < 0.01, "p={p}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg64::seeded(6);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn zipf_rank_frequencies_decrease() {
        let mut r = Pcg64::seeded(8);
        let mut counts = [0usize; 10];
        for _ in 0..50_000 {
            counts[r.zipf(10, 1.2) as usize] += 1;
        }
        // Rank 0 strictly most frequent; generally decreasing.
        assert!(counts[0] > counts[1]);
        assert!(counts[1] > counts[4]);
        assert!(counts[4] > counts[9]);
    }

    #[test]
    fn zipf_large_n_in_range() {
        let mut r = Pcg64::seeded(9);
        for _ in 0..10_000 {
            let k = r.zipf(100_000, 1.3);
            assert!(k < 100_000);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(10);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn normal_clamped_respects_bounds() {
        let mut r = Pcg64::seeded(12);
        for _ in 0..1000 {
            let x = r.normal_clamped(1.0, 5.0, 0.1, 2.0);
            assert!((0.1..=2.0).contains(&x));
        }
    }

    #[test]
    fn pareto_heavy_tail() {
        let mut r = Pcg64::seeded(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.pareto(1.0, 2.0)).collect();
        assert!(xs.iter().all(|&x| x >= 1.0));
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean={mean}"); // E = a*xm/(a-1) = 2
    }
}
