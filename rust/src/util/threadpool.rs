//! A small fixed-size thread pool (no `tokio`/`rayon` offline). Used by the
//! experiment harness to run repeated simulations in parallel (Fig. 7's ten
//! repetitions, Fig. 8's threshold grid) and by the streaming coordinator.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Decrements a counter on drop — survives job panics (the unwind drops it).
struct Decrement(Arc<AtomicUsize>);

impl Drop for Decrement {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Fixed-size worker pool. Jobs are closures; results flow back through
/// whatever channel the caller closes over (see [`ThreadPool::map`]).
///
/// The pool tracks its queue depth ([`ThreadPool::in_flight`]) — the number
/// of jobs submitted but not yet finished — which the streaming
/// [`crate::coordinator::service::AnalysisService`] uses for backpressure
/// and metrics.
pub struct ThreadPool {
    tx: Sender<Msg>,
    shared_rx: Arc<Mutex<Receiver<Msg>>>,
    workers: Vec<JoinHandle<()>>,
    in_flight: Arc<AtomicUsize>,
    size: usize,
}

impl ThreadPool {
    /// Create a pool with `n` workers (min 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = channel::<Msg>();
        let shared_rx = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(n);
        for _ in 0..n {
            let rx = Arc::clone(&shared_rx);
            workers.push(std::thread::spawn(move || loop {
                let msg = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match msg {
                    Ok(Msg::Run(job)) => {
                        // Isolate panics so one bad job doesn't poison the pool;
                        // map() detects missing results and repanics in the caller.
                        let _ = catch_unwind(AssertUnwindSafe(job));
                    }
                    Ok(Msg::Shutdown) | Err(_) => break,
                }
            }));
        }
        ThreadPool { tx, shared_rx, workers, in_flight: Arc::new(AtomicUsize::new(0)), size: n }
    }

    /// Pool sized to available parallelism.
    pub fn default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n)
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Jobs submitted but not yet finished (queued + running). This is the
    /// pool's queue-depth signal for backpressure decisions.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Submit a fire-and-forget job.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        let guard = Decrement(Arc::clone(&self.in_flight));
        self.tx
            .send(Msg::Run(Box::new(move || {
                let _guard = guard;
                f();
            })))
            .expect("pool shut down");
    }

    /// Run `f` over all items in parallel, preserving input order in the
    /// returned Vec. Panics if any job panicked.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.spawn(move || {
                let r = f(item);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            match rrx.recv() {
                Ok((i, r)) => slots[i] = Some(r),
                Err(_) => break, // a job panicked; detected below
            }
        }
        slots
            .into_iter()
            .map(|s| s.expect("a pooled job panicked"))
            .collect()
    }

    fn join(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let _ = &self.shared_rx; // keep receiver alive until workers joined
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.join();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100u64).collect(), |x| x * x);
        assert_eq!(out, (0..100u64).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn spawn_runs_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join on drop
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn single_worker_is_serial_but_complete() {
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn in_flight_returns_to_zero() {
        let pool = ThreadPool::new(2);
        assert_eq!(pool.size(), 2);
        assert_eq!(pool.in_flight(), 0);
        let out = pool.map((0..32u64).collect(), |x| x + 1);
        assert_eq!(out.len(), 32);
        // map() waits for every result, but the guard decrement can race
        // the result send by a hair; wait briefly.
        for _ in 0..500 {
            if pool.in_flight() == 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(pool.in_flight(), 0);
    }

    #[test]
    fn in_flight_counts_panicking_jobs_down() {
        let pool = ThreadPool::new(1);
        pool.spawn(|| panic!("boom"));
        for _ in 0..500 {
            if pool.in_flight() == 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(pool.in_flight(), 0);
        // Pool still usable after a panicked job.
        assert_eq!(pool.map(vec![1, 2], |x| x * 2), vec![2, 4]);
    }

    #[test]
    #[should_panic(expected = "pooled job panicked")]
    fn panicking_job_detected() {
        let pool = ThreadPool::new(2);
        let _ = pool.map(vec![0, 1, 2], |x| {
            if x == 1 {
                panic!("boom");
            }
            x
        });
    }
}
