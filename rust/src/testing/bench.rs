//! Criterion-like micro/macro benchmark harness (no `criterion` in the
//! offline registry). Each `cargo bench` target is a `harness = false`
//! binary built on this module: warmup, fixed sample count, mean / p50 /
//! p95 / p99 and throughput reporting, plus a `--quick` mode used in CI.

use std::time::{Duration, Instant};

/// One benchmark's collected samples (seconds per iteration).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>,
    /// Optional items-per-iteration for throughput reporting.
    pub items_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn mean(&self) -> f64 {
        crate::util::stats::mean(&self.samples)
    }

    pub fn p(&self, q: f64) -> f64 {
        crate::util::stats::quantile(&self.samples, q)
    }

    pub fn stddev(&self) -> f64 {
        crate::util::stats::stddev(&self.samples)
    }

    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / self.mean())
    }

    pub fn summary_line(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e6 => format!("  {:>8.2} Mitem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:>8.2} Kitem/s", t / 1e3),
            Some(t) => format!("  {:>8.2} item/s", t),
            None => String::new(),
        };
        format!(
            "{:<44} mean {:>10}  p50 {:>10}  p99 {:>10}  (±{:>9}){}",
            self.name,
            fmt_dur(self.mean()),
            fmt_dur(self.p(0.5)),
            fmt_dur(self.p(0.99)),
            fmt_dur(self.stddev()),
            tp
        )
    }
}

/// Pretty duration from seconds.
pub fn fmt_dur(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{:.3} s", secs)
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup: Duration,
    pub samples: usize,
    pub quick: bool,
    results: Vec<BenchResult>,
}

impl Bench {
    /// Standard config; `quick=true` (from `--quick` or `BIGROOTS_BENCH_QUICK=1`)
    /// trims warmup and sample counts so the full suite runs in seconds.
    pub fn new() -> Self {
        let quick = std::env::args().any(|a| a == "--quick")
            || std::env::var("BIGROOTS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
        Bench {
            warmup: if quick { Duration::from_millis(50) } else { Duration::from_millis(300) },
            samples: if quick { 10 } else { 30 },
            quick,
            results: Vec::new(),
        }
    }

    /// Time `f` repeatedly; `items` is the per-iteration workload size used
    /// for throughput lines (pass 0 to omit).
    pub fn run<F: FnMut()>(&mut self, name: &str, items: f64, mut f: F) -> &BenchResult {
        // Warmup until the budget is consumed (at least one call).
        let start = Instant::now();
        loop {
            f();
            if start.elapsed() >= self.warmup {
                break;
            }
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let res = BenchResult {
            name: name.to_string(),
            samples,
            items_per_iter: if items > 0.0 { Some(items) } else { None },
        };
        println!("{}", res.summary_line());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Record an externally-measured scalar (e.g. an accuracy metric or a
    /// one-shot wall time) so it appears in the report stream.
    pub fn record(&mut self, name: &str, value_secs: f64) {
        let res = BenchResult {
            name: name.to_string(),
            samples: vec![value_secs],
            items_per_iter: None,
        };
        println!("{}", res.summary_line());
        self.results.push(res);
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Merge this run's results into a machine-readable JSON file so the
    /// perf trajectory is tracked across PRs. The file maps `section` →
    /// bench name → `{mean_secs, p50_secs, p99_secs, items_per_sec?}`.
    /// The merge is row-level: other sections are preserved untouched, and
    /// within `section` only the benches this run actually executed are
    /// overwritten — a partial rerun (e.g. one bench binary under
    /// `--quick`) never deletes its siblings' rows (e.g. in
    /// `BENCH_multi_job.json` at the repo root).
    pub fn write_json(&self, path: &str, section: &str) -> std::io::Result<()> {
        use crate::util::json::Json;
        let mut root = std::fs::read_to_string(path)
            .ok()
            .and_then(|t| Json::parse(&t).ok())
            .filter(|j| j.as_obj().is_some())
            .unwrap_or_else(Json::obj);
        let mut sec = match root.get(section) {
            prior if prior.as_obj().is_some() => prior.clone(),
            _ => Json::obj(),
        };
        for r in &self.results {
            let mut o = Json::obj();
            o.set("mean_secs", r.mean().into());
            o.set("p50_secs", r.p(0.5).into());
            o.set("p99_secs", r.p(0.99).into());
            o.set("samples", r.samples.len().into());
            if let Some(t) = r.throughput() {
                o.set("items_per_sec", t.into());
            }
            sec.set(&r.name, o);
        }
        sec.set("quick", self.quick.into());
        root.set(section, sec);
        std::fs::write(path, root.to_pretty() + "\n")
    }
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

/// Prevent the optimizer from discarding a computed value (stable-Rust
/// equivalent of `std::hint::black_box` for older toolchains; we just call
/// the real one — kept as a seam for tests).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_stats() {
        let mut b = Bench {
            warmup: Duration::from_millis(1),
            samples: 5,
            quick: true,
            results: Vec::new(),
        };
        let mut acc = 0u64;
        b.run("spin", 100.0, || {
            for i in 0..100u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        let r = &b.results()[0];
        assert_eq!(r.samples.len(), 5);
        assert!(r.mean() > 0.0);
        assert!(r.throughput().unwrap() > 0.0);
        assert!(r.summary_line().contains("spin"));
    }

    #[test]
    fn fmt_dur_scales() {
        assert!(fmt_dur(2.0).ends_with(" s"));
        assert!(fmt_dur(2e-3).ends_with(" ms"));
        assert!(fmt_dur(2e-6).ends_with(" µs"));
        assert!(fmt_dur(2e-9).ends_with(" ns"));
    }

    #[test]
    fn write_json_merges_sections() {
        let path = format!(
            "{}/bigroots_bench_json_{}.json",
            std::env::temp_dir().display(),
            std::process::id()
        );
        let _ = std::fs::remove_file(&path);
        let mut a = Bench {
            warmup: Duration::from_millis(1),
            samples: 3,
            quick: true,
            results: Vec::new(),
        };
        a.run("alpha", 10.0, || {
            std::hint::black_box(1 + 1);
        });
        a.write_json(&path, "first").unwrap();
        let mut b = Bench {
            warmup: Duration::from_millis(1),
            samples: 3,
            quick: true,
            results: Vec::new(),
        };
        b.run("beta", 0.0, || {
            std::hint::black_box(2 + 2);
        });
        b.write_json(&path, "second").unwrap();
        // A partial rerun of the *same* section must merge at row level:
        // "gamma" lands beside "alpha", which it did not re-run.
        let mut c = Bench {
            warmup: Duration::from_millis(1),
            samples: 3,
            quick: true,
            results: Vec::new(),
        };
        c.run("gamma", 0.0, || {
            std::hint::black_box(3 + 3);
        });
        c.write_json(&path, "first").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        let obj = j.as_obj().unwrap();
        assert!(obj.contains_key("first"), "earlier section preserved");
        assert!(obj.contains_key("second"));
        assert!(j.get("first").get("alpha").get("items_per_sec").as_f64().is_some());
        assert!(j.get("second").get("beta").get("mean_secs").as_f64().is_some());
        assert!(
            j.get("first").get("gamma").get("mean_secs").as_f64().is_some(),
            "partial rerun adds its row"
        );
        assert!(
            j.get("first").get("alpha").get("mean_secs").as_f64().is_some(),
            "partial rerun of a section keeps rows it did not re-run"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn record_scalar() {
        let mut b = Bench {
            warmup: Duration::from_millis(1),
            samples: 1,
            quick: true,
            results: Vec::new(),
        };
        b.record("metric", 0.5);
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].mean(), 0.5);
    }
}
