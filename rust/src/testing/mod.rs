//! Test & benchmark infrastructure: a criterion-like bench harness and a
//! mini property-based testing framework (see module docs).

pub mod bench;
pub mod proptest;
