//! Mini property-based testing framework (no `proptest`/`quickcheck` in the
//! offline registry). Provides value generators over a seeded [`Pcg64`],
//! a runner that executes a property over many random cases, and greedy
//! input shrinking for failing cases.
//!
//! Used by the L3 tests for coordinator invariants: scheduler routing,
//! straggler-detection monotonicity, rule idempotence, codec roundtrips.

use crate::util::rng::Pcg64;

/// A generator produces a random value and can propose "smaller" variants
/// of a failing value for shrinking.
pub trait Gen {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Pcg64) -> Self::Value;
    /// Candidate shrinks, roughly ordered most-aggressive first.
    fn shrink(&self, value: &Self::Value) -> Vec<Self::Value> {
        let _ = value;
        Vec::new()
    }
}

/// Uniform u64 in [lo, hi].
pub struct U64Range(pub u64, pub u64);

impl Gen for U64Range {
    type Value = u64;
    fn generate(&self, rng: &mut Pcg64) -> u64 {
        rng.range_u64(self.0, self.1)
    }
    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.0 {
            out.push(self.0);
            out.push(self.0 + (*v - self.0) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out
    }
}

/// Uniform f64 in [lo, hi).
pub struct F64Range(pub f64, pub f64);

impl Gen for F64Range {
    type Value = f64;
    fn generate(&self, rng: &mut Pcg64) -> f64 {
        rng.range_f64(self.0, self.1)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mut out = Vec::new();
        let anchor = self.0;
        if (*v - anchor).abs() > 1e-9 {
            out.push(anchor);
            out.push(anchor + (*v - anchor) / 2.0);
        }
        out
    }
}

/// Vec of T with length in [min_len, max_len].
pub struct VecOf<G: Gen> {
    pub inner: G,
    pub min_len: usize,
    pub max_len: usize,
}

impl<G: Gen> Gen for VecOf<G> {
    type Value = Vec<G::Value>;
    fn generate(&self, rng: &mut Pcg64) -> Vec<G::Value> {
        let len = rng.range_u64(self.min_len as u64, self.max_len as u64) as usize;
        (0..len).map(|_| self.inner.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<G::Value>) -> Vec<Vec<G::Value>> {
        let mut out = Vec::new();
        // Shrink length first: halves, then drop one element at a time.
        if v.len() > self.min_len {
            let half = self.min_len.max(v.len() / 2);
            out.push(v[..half].to_vec());
            let mut minus1 = v.clone();
            minus1.pop();
            out.push(minus1);
        }
        // Then shrink individual elements (first few positions only — keeps
        // the shrink tree small).
        for i in 0..v.len().min(4) {
            for cand in self.inner.shrink(&v[i]) {
                let mut w = v.clone();
                w[i] = cand;
                out.push(w);
            }
        }
        out
    }
}

/// Pair generator.
pub struct PairOf<A: Gen, B: Gen>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairOf<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Pcg64) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Triple generator — used by the multi-job service properties, whose
/// cases are (seed, job-count, task-count)-shaped.
pub struct TripleOf<A: Gen, B: Gen, C: Gen>(pub A, pub B, pub C);

impl<A: Gen, B: Gen, C: Gen> Gen for TripleOf<A, B, C> {
    type Value = (A::Value, B::Value, C::Value);
    fn generate(&self, rng: &mut Pcg64) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng), self.2.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone(), v.2.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b, v.2.clone())));
        out.extend(self.2.shrink(&v.2).into_iter().map(|c| (v.0.clone(), v.1.clone(), c)));
        out
    }
}

/// Result of a property check.
#[derive(Debug)]
pub enum PropResult<V> {
    Ok { cases: usize },
    Failed { original: V, shrunk: V, message: String, cases: usize },
}

/// Run `prop` over `cases` generated inputs. On failure, greedily shrink.
/// Properties return `Result<(), String>` so failures carry a message.
pub fn check<G, F>(seed: u64, cases: usize, gen: &G, mut prop: F) -> PropResult<G::Value>
where
    G: Gen,
    F: FnMut(&G::Value) -> Result<(), String>,
{
    let mut rng = Pcg64::new(seed, 0x70726f70); // "prop"
    for case in 0..cases {
        let value = gen.generate(&mut rng);
        if let Err(msg) = prop(&value) {
            // Greedy shrink: repeatedly take the first failing candidate.
            let mut current = value.clone();
            let mut current_msg = msg;
            let mut budget = 200usize;
            'outer: while budget > 0 {
                for cand in gen.shrink(&current) {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        current = cand;
                        current_msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            return PropResult::Failed {
                original: value,
                shrunk: current,
                message: current_msg,
                cases: case + 1,
            };
        }
    }
    PropResult::Ok { cases }
}

/// Assert a property holds; panics with the shrunk counterexample otherwise.
/// This is the entry point tests use:
///
/// ```ignore
/// assert_prop(42, 200, &VecOf { inner: F64Range(0.0, 1e6), min_len: 0, max_len: 64 },
///     |xs| if ok(xs) { Ok(()) } else { Err("bad".into()) });
/// ```
pub fn assert_prop<G, F>(seed: u64, cases: usize, gen: &G, prop: F)
where
    G: Gen,
    F: FnMut(&G::Value) -> Result<(), String>,
{
    match check(seed, cases, gen, prop) {
        PropResult::Ok { .. } => {}
        PropResult::Failed { original, shrunk, message, cases } => {
            panic!(
                "property failed after {cases} cases: {message}\n  original: {original:?}\n  shrunk:   {shrunk:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let r = check(1, 50, &U64Range(0, 100), |&x| {
            if x <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
        match r {
            PropResult::Ok { cases } => assert_eq!(cases, 50),
            _ => panic!("should pass"),
        }
    }

    #[test]
    fn failing_property_shrinks_to_boundary() {
        // Fails for x >= 10; shrinking should land exactly on 10.
        let r = check(7, 500, &U64Range(0, 1000), |&x| {
            if x < 10 {
                Ok(())
            } else {
                Err(format!("{x} >= 10"))
            }
        });
        match r {
            PropResult::Failed { shrunk, .. } => assert_eq!(shrunk, 10),
            _ => panic!("should fail"),
        }
    }

    #[test]
    fn vec_shrinking_reduces_length() {
        let gen = VecOf { inner: U64Range(0, 9), min_len: 0, max_len: 50 };
        // Property: no vec contains a 7. Shrunk counterexample should be a
        // short vector still containing a 7.
        let r = check(3, 500, &gen, |v| {
            if v.contains(&7) {
                Err("has 7".into())
            } else {
                Ok(())
            }
        });
        match r {
            PropResult::Failed { shrunk, .. } => {
                assert!(shrunk.contains(&7));
                assert!(shrunk.len() <= 8, "shrunk too long: {shrunk:?}");
            }
            _ => panic!("should fail (7 appears w.h.p. in 500 cases)"),
        }
    }

    #[test]
    fn pair_generator_shrinks_both_sides() {
        let gen = PairOf(U64Range(0, 100), F64Range(0.0, 1.0));
        let r = check(5, 300, &gen, |(a, b)| {
            if *a >= 50 && *b >= 0.0 {
                Err("a big".into())
            } else {
                Ok(())
            }
        });
        match r {
            PropResult::Failed { shrunk, .. } => assert_eq!(shrunk.0, 50),
            _ => panic!("should fail"),
        }
    }

    #[test]
    fn triple_generator_shrinks_each_side() {
        let gen = TripleOf(U64Range(0, 100), U64Range(0, 100), F64Range(0.0, 1.0));
        let r = check(11, 300, &gen, |(a, b, _c)| {
            if *a >= 40 && *b >= 10 {
                Err("both big".into())
            } else {
                Ok(())
            }
        });
        match r {
            PropResult::Failed { shrunk, .. } => {
                assert_eq!(shrunk.0, 40);
                assert_eq!(shrunk.1, 10);
            }
            _ => panic!("should fail"),
        }
    }

    #[test]
    fn deterministic_for_same_seed() {
        let gen = U64Range(0, 1_000_000);
        let mut seen1 = Vec::new();
        let mut seen2 = Vec::new();
        let _ = check(99, 20, &gen, |&x| {
            seen1.push(x);
            Ok(())
        });
        let _ = check(99, 20, &gen, |&x| {
            seen2.push(x);
            Ok(())
        });
        assert_eq!(seen1, seen2);
    }
}
