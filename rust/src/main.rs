//! `bigroots` — the command-line launcher.
//!
//! Subcommands cover the full paper workflow:
//!
//! ```text
//! bigroots simulate   — run a workload on the simulated cluster → trace.json
//! bigroots analyze    — offline root-cause analysis of a trace file
//! bigroots whatif     — counterfactual ranking: completion time saved per removed cause
//! bigroots stream     — streaming analysis of an event log (ndjson)
//! bigroots convert    — NDJSON ↔ compact binary wire format (trace/wire.rs)
//! bigroots explain    — replay a flight-recorder dump, verify the verdict reproduces

//! bigroots verify     — Table III single-AG verification (BigRoots vs PCC)
//! bigroots multi      — Tables IV+V multi-node anomaly schedule
//! bigroots hibench    — Table VI case study over the 11 workloads
//! bigroots roc        — Fig. 8 threshold sweep + AUC comparison
//! bigroots run        — run a declarative experiment config (JSON)
//! ```

use bigroots::analysis::features::FeatureKind;
use bigroots::analysis::roc::resource_features;
use bigroots::coordinator::experiments::{self, AgSetting};
use bigroots::coordinator::{ExperimentConfig, Pipeline};
use bigroots::sim::{workloads, Engine};
use bigroots::trace::{codec, eventlog, AnomalyKind};
use bigroots::util::cli::Command;
use bigroots::util::table::{fnum, pct, Align, Table};

fn main() {
    let cmd = Command::new("bigroots", "root-cause analysis of stragglers in big data systems")
        .subcommand(
            Command::new("simulate", "simulate a workload, write a trace file")
                .opt("workload", "NaiveBayes", "workload name (see `hibench` for the list)")
                .opt("scale", "1.0", "task-count scale factor")
                .opt("seed", "42", "rng seed")
                .opt("inject", "none", "anomaly: none | cpu | io | network | mixed | table4")
                .opt("node", "1", "injection target node")
                .opt("out", "trace.json", "output trace path")
                .flag("events", "also write an event log next to the trace"),
        )
        .subcommand(
            Command::new("analyze", "offline analysis of a trace file")
                .opt_req("input", "trace file (from `simulate` or a converter)")
                .opt("backend", "auto", "stats backend: auto | native | xla")
                .flag("pcc", "also run the PCC baseline")
                .flag("verbose", "print every straggler with its causes"),
        )
        .subcommand(
            Command::new(
                "whatif",
                "counterfactual what-if: rank detected causes by estimated completion-time saved",
            )
            .opt("input", "", "trace file to analyze (omit to simulate --workload instead)")
            .opt(
                "format",
                "auto",
                "--input format: auto (sniffed) | trace (trace.json) | ndjson (event log) \
                 | binary (.bew event capture)",
            )
            .opt("workload", "NaiveBayes", "workload to simulate when no --input is given")
            .opt("scale", "1.0", "task-count scale factor (simulated trace)")
            .opt("seed", "42", "rng seed (simulated trace)")
            .opt("inject", "cpu", "anomaly for the simulated trace: none | cpu | io | network")
            .opt("node", "1", "injection target node (simulated trace)")
            .opt("backend", "auto", "stats backend: auto | native | xla")
            .opt(
                "snapshot",
                "",
                "fleet-baseline snapshot (from `serve --snapshot-path`) supplying \
                 fleet-median neutralization targets",
            ),
        )
        .subcommand(
            Command::new("stream", "streaming analysis of an ndjson event log")
                .opt_req("input", "event log path"),
        )
        .subcommand(
            Command::new(
                "convert",
                "convert an event capture between NDJSON and the compact binary wire \
                 format (streaming; reports the compression ratio)",
            )
            .opt_req("input", "source capture: NDJSON event log or binary (.bew)")
            .opt_req("out", "destination path")
            .opt("to", "auto", "target format: auto (the opposite of the input) | binary | ndjson"),
        )
        .subcommand(
            Command::new(
                "explain",
                "replay a flight-recorder dump offline and verify the recorded verdict \
                 reproduces bit-identically",
            )
            .opt_req(
                "replay",
                "flight dump path (written by `explain <id> dump <path>` on the serve \
                 control socket; NDJSON, or binary when dumped to a .bew path)",
            )
            .opt("format", "auto", "dump container: auto (sniffed) | ndjson | binary")
            .flag("verbose", "print the full provenance document, not just the verdict line"),
        )
        .subcommand(
            Command::new("serve", "long-running multi-tenant analysis server (live/ subsystem)")
                .opt("tail", "", "follow a growing job-tagged ndjson event log (live mode)")
                .opt("listen", "", "accept line-delimited events over TCP, e.g. 127.0.0.1:7070")
                .flag("stdin", "read the event stream from stdin (live mode)")
                .opt("input", "", "replay a job-tagged event capture (omit to simulate --jobs)")
                .opt(
                    "format",
                    "auto",
                    "--tail/--input encoding: auto (sniffed; .bew implies binary) | \
                     ndjson | binary — binary --input replays through the zero-copy \
                     mmap source",
                )
                .opt("jobs", "8", "jobs to simulate when no input/tail/listen is given")
                .opt("scale", "0.3", "workload scale for simulated jobs")
                .opt("seed", "42", "base seed for simulated jobs")
                .opt("shards", "4", "shard worker threads (parallel demux + analysis)")
                .opt("queue-cap", "8", "per-shard queue capacity in batches (backpressure bound)")
                .opt("ingest-batch", "64", "events per shard-queue send")
                .opt("batch-events", "0", "events per columnar ingest batch (0 = use ingest-batch)")
                .opt("decode-threads", "1", "parallel decode threads for an mmap capture replay (0 = one per core, 1 = sequential)")
                .opt("evict-after", "5", "event-time quiescence (s) after job_end before eviction")
                .opt("stats-cache", "256", "shared stage-stats cache capacity (0 disables)")
                .opt("cache-stripes", "8", "lock stripes in the shared stage-stats cache")
                .opt("route-large", "0", "route stages with >= this many tasks to the large-stage backend (0 = native only)")
                .opt("snapshot-every", "5", "seconds between fleet-baseline snapshots (live mode)")
                .opt(
                    "control-port",
                    "",
                    "line-delimited JSON control/query socket (fleet-report | jobs [filters] | \
                     job <id> | explain <id> [dump <path>] | what-if <id> | metrics | \
                     metrics-prom | self-report | snapshot | shutdown), e.g. 127.0.0.1:7172",
                )
                .opt(
                    "flight-capacity",
                    "16384",
                    "per-shard flight-recorder ring capacity in raw events (0 disables \
                     verdict window capture)",
                )
                .opt(
                    "metrics-port",
                    "",
                    "HTTP endpoint serving the Prometheus text exposition (scrape with \
                     curl or a Prometheus server), e.g. 127.0.0.1:9191",
                )
                .opt("log-level", "info", "diagnostics level: error | warn | info | debug | trace")
                .flag("log-json", "emit diagnostics as NDJSON lines instead of human-readable")
                .flag(
                    "self-analyze",
                    "feed the server's own per-shard batch timings through BigRoots and \
                     print which shard/phase is the straggler on the snapshot cadence",
                )
                .flag("no-obs", "disable span recording (overhead-measurement baseline)")
                .opt(
                    "snapshot-path",
                    "",
                    "fleet-baseline snapshot file: restored on boot if present, written on \
                     the snapshot cadence and at shutdown (atomic rename)",
                )
                .opt(
                    "idle-timeout",
                    "10",
                    "stop after this many idle seconds (0 = run forever; with --listen, \
                     also keeps the socket open across client generations)",
                )
                .flag("metrics", "print per-shard metrics"),
        )
        .subcommand(
            Command::new("verify", "Table III: single-AG verification vs PCC")
                .opt("reps", "10", "repetitions per AG kind")
                .opt("scale", "1.0", "workload scale")
                .opt("seed", "42", "base seed"),
        )
        .subcommand(
            Command::new("multi", "Tables IV+V: multi-node anomaly schedule")
                .opt("scale", "1.0", "workload scale")
                .opt("seed", "42", "seed"),
        )
        .subcommand(
            Command::new("hibench", "Table VI: the 11-workload case study")
                .opt("scale", "1.0", "workload scale")
                .opt("seed", "42", "seed"),
        )
        .subcommand(
            Command::new("roc", "Fig. 8: ROC sweep + AUC, BigRoots vs PCC")
                .opt("setting", "cpu", "cpu | io | network | mixed")
                .opt("reps", "5", "repetitions")
                .opt("scale", "0.6", "workload scale")
                .opt("seed", "42", "base seed"),
        )
        .subcommand(
            Command::new("run", "run a declarative experiment config")
                .opt_req("config", "JSON config path (see coordinator::config)"),
        );

    let (sub, args) = cmd.parse_env();
    let code = match sub.as_str() {
        "simulate" => cmd_simulate(&args),
        "analyze" => cmd_analyze(&args),
        "whatif" => cmd_whatif(&args),
        "stream" => cmd_stream(&args),
        "convert" => cmd_convert(&args),
        "explain" => cmd_explain(&args),
        "serve" => cmd_serve(&args),
        "verify" => cmd_verify(&args),
        "multi" => cmd_multi(&args),
        "hibench" => cmd_hibench(&args),
        "roc" => cmd_roc(&args),
        "run" => cmd_run(&args),
        _ => unreachable!(),
    };
    std::process::exit(code);
}

fn parse_setting(s: &str) -> Option<AgSetting> {
    Some(match s.to_ascii_lowercase().as_str() {
        "none" => AgSetting::None,
        "cpu" => AgSetting::Single(AnomalyKind::Cpu),
        "io" => AgSetting::Single(AnomalyKind::Io),
        "network" | "net" => AgSetting::Single(AnomalyKind::Network),
        "mixed" => AgSetting::Mixed,
        _ => return None,
    })
}

fn cmd_simulate(args: &bigroots::util::cli::Args) -> i32 {
    let name = args.get_or("workload", "NaiveBayes");
    let scale = args.get_f64("scale", 1.0);
    let seed = args.get_u64("seed", 42);
    let Some(w) = workloads::by_name(&name, scale) else {
        eprintln!("unknown workload '{name}'");
        return 2;
    };
    let inject = args.get_or("inject", "none");
    let node = args.get_usize("node", 1);
    let horizon = 400.0 * scale.max(0.25);
    let plan = match inject.as_str() {
        "none" => bigroots::sim::InjectionPlan::none(),
        "cpu" => bigroots::sim::InjectionPlan::intermittent(AnomalyKind::Cpu, node, 15.0, 10.0, horizon),
        "io" => bigroots::sim::InjectionPlan::intermittent(AnomalyKind::Io, node, 15.0, 10.0, horizon),
        "network" | "net" => {
            bigroots::sim::InjectionPlan::intermittent(AnomalyKind::Network, node, 15.0, 10.0, horizon)
        }
        "mixed" => {
            let mut rng = bigroots::util::rng::Pcg64::seeded(seed ^ 0xA6);
            bigroots::sim::InjectionPlan::mixed(&mut rng, node, 15.0, 10.0, horizon)
        }
        "table4" => bigroots::sim::InjectionPlan::table4(|s| s - 1),
        other => {
            eprintln!("unknown injection '{other}'");
            return 2;
        }
    };
    let mut eng = Engine::new(bigroots::sim::SimConfig { seed, ..Default::default() });
    let trace = eng.run(&format!("{name}-{inject}"), w.name, &w.stages, &plan);
    let out = args.get_or("out", "trace.json");
    if let Err(e) = codec::save(&trace, &out) {
        eprintln!("write failed: {e:#}");
        return 1;
    }
    println!(
        "wrote {out}: {} tasks, {} stages, makespan {:.1}s, {} injections",
        trace.tasks.len(),
        trace.stages.len(),
        trace.makespan(),
        trace.injections.len()
    );
    if args.flag("events") {
        let epath = format!("{out}.events.ndjson");
        let events = eventlog::trace_to_events(&trace);
        if let Err(e) = eventlog::write_events(&events, &epath) {
            eprintln!("event log write failed: {e:#}");
            return 1;
        }
        println!("wrote {epath}: {} events", events.len());
    }
    0
}

fn make_pipeline(backend: &str) -> Result<Pipeline, String> {
    match backend {
        "auto" => Ok(Pipeline::auto()),
        "native" => Ok(Pipeline::native()),
        "xla" => {
            let dir = bigroots::runtime::XlaBackend::default_dir();
            let b = bigroots::runtime::XlaBackend::open(&dir)
                .map_err(|e| format!("XLA backend: {e:#}"))?;
            Ok(Pipeline::new(Box::new(b)))
        }
        other => Err(format!("unknown backend '{other}'")),
    }
}

fn cmd_analyze(args: &bigroots::util::cli::Args) -> i32 {
    let input = args.get("input").unwrap();
    let trace = match codec::load(input) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("loading {input}: {e:#}");
            return 1;
        }
    };
    let mut pipeline = match make_pipeline(&args.get_or("backend", "auto")) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if !args.flag("pcc") {
        pipeline.pcc = None;
    }
    let analysis = pipeline.analyze(&trace, "-");
    println!(
        "{} [{}] — {} tasks, {} stages, backend {}",
        trace.job_name,
        trace.workload,
        trace.tasks.len(),
        trace.stages.len(),
        pipeline.backend.name()
    );
    let mut t = Table::new("Per-stage summary")
        .header(&["stage", "tasks", "median (s)", "stragglers", "causes"])
        .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Left]);
    for (sf, a) in &analysis.per_stage {
        let hist = a
            .cause_histogram()
            .iter()
            .map(|(k, n)| format!("{}({})", k.name(), n))
            .collect::<Vec<_>>()
            .join(" ");
        t.row(vec![
            format!("{}", sf.stage_id),
            format!("{}", sf.num_tasks()),
            fnum(a.stragglers.median, 2),
            format!("{}", a.stragglers.rows.len()),
            if hist.is_empty() { "-".into() } else { hist },
        ]);
    }
    print!("{}", t.render());
    if args.flag("verbose") {
        for ann in &analysis.annotations {
            let causes: Vec<&str> = ann.causes.iter().map(|k| k.name()).collect();
            println!(
                "straggler task {} (stage {}, node {}) [{:.1}s..{:.1}s] scale {:.2}x → {}",
                ann.task_id,
                ann.stage_id,
                ann.node,
                ann.start,
                ann.finish,
                ann.scale,
                if causes.is_empty() { "unexplained".to_string() } else { causes.join(", ") }
            );
        }
    }
    if args.flag("pcc") {
        let pcc_causes: usize = analysis.pcc_per_stage.iter().map(|a| a.causes.len()).sum();
        println!("PCC baseline: {pcc_causes} causes (vs BigRoots {})", analysis.total_causes());
    }
    0
}

fn cmd_whatif(args: &bigroots::util::cli::Args) -> i32 {
    use bigroots::analysis::whatif::{self, WhatIfConfig};

    let input = args.get_or("input", "");
    let trace = if input.is_empty() {
        let name = args.get_or("workload", "NaiveBayes");
        let scale = args.get_f64("scale", 1.0);
        let seed = args.get_u64("seed", 42);
        let Some(w) = workloads::by_name(&name, scale) else {
            eprintln!("unknown workload '{name}'");
            return 2;
        };
        let inject = args.get_or("inject", "cpu");
        let node = args.get_usize("node", 1);
        let horizon = 400.0 * scale.max(0.25);
        let plan = match inject.as_str() {
            "none" => bigroots::sim::InjectionPlan::none(),
            "cpu" => bigroots::sim::InjectionPlan::intermittent(AnomalyKind::Cpu, node, 15.0, 10.0, horizon),
            "io" => bigroots::sim::InjectionPlan::intermittent(AnomalyKind::Io, node, 15.0, 10.0, horizon),
            "network" | "net" => {
                bigroots::sim::InjectionPlan::intermittent(AnomalyKind::Network, node, 15.0, 10.0, horizon)
            }
            other => {
                eprintln!("unknown injection '{other}'");
                return 2;
            }
        };
        let mut eng = Engine::new(bigroots::sim::SimConfig { seed, ..Default::default() });
        eng.run(&format!("{name}-{inject}"), w.name, &w.stages, &plan)
    } else {
        match load_input_trace(&input, &args.get_or("format", "auto")) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("loading {input}: {e}");
                return 1;
            }
        }
    };
    let mut pipeline = match make_pipeline(&args.get_or("backend", "auto")) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    pipeline.pcc = None;
    let analysis = pipeline.analyze(&trace, "-");
    // Optional fleet baseline for the neutralization targets: the same
    // snapshot file `serve --snapshot-path` writes.
    let snapshot = args.get_or("snapshot", "");
    let fleet = if snapshot.is_empty() {
        None
    } else {
        match bigroots::live::persist::load_snapshot(&snapshot) {
            Ok(reg) => Some(reg.report()),
            Err(e) => {
                eprintln!("loading snapshot {snapshot}: {e}");
                return 1;
            }
        }
    };
    let cfg = WhatIfConfig { seed: args.get_u64("seed", 42), ..Default::default() };
    let report = whatif::analyze_trace(&trace, &analysis.per_stage, fleet.as_ref(), &cfg);
    print!("{}", report.render());
    0
}

/// Load an offline input as a [`bigroots::trace::JobTrace`], whatever its
/// container: a `trace.json`, an NDJSON event log, or a binary wire
/// capture. Event logs must hold exactly one job's stream.
fn load_input_trace(input: &str, format: &str) -> Result<bigroots::trace::JobTrace, String> {
    use bigroots::trace::wire;

    let events_to_single_trace =
        |events: Vec<eventlog::TaggedEvent>| -> Result<bigroots::trace::JobTrace, String> {
            let mut jobs: Vec<u64> = events.iter().map(|e| e.job_id).collect();
            jobs.sort_unstable();
            jobs.dedup();
            if jobs.len() > 1 {
                return Err(format!(
                    "event log holds {} jobs ({:?}…) — whatif analyzes one; demux it or \
                     use `bigroots serve`",
                    jobs.len(),
                    &jobs[..jobs.len().min(4)]
                ));
            }
            let plain: Vec<_> = events.into_iter().map(|e| e.event).collect();
            eventlog::events_to_trace(&plain)
        };
    match format {
        "trace" => codec::load(input).map_err(|e| format!("{e:#}")),
        "ndjson" => {
            let text = std::fs::read_to_string(input).map_err(|e| e.to_string())?;
            let events = eventlog::parse_tagged_events(&text).map_err(|e| e.to_string())?;
            events_to_single_trace(events)
        }
        "binary" => {
            let bytes = std::fs::read(input).map_err(|e| e.to_string())?;
            let events = wire::decode_stream(&bytes).map_err(|e| e.to_string())?;
            events_to_single_trace(events)
        }
        "auto" => {
            let bytes = std::fs::read(input).map_err(|e| e.to_string())?;
            if wire::is_binary(&bytes) {
                let events = wire::decode_stream(&bytes).map_err(|e| e.to_string())?;
                return events_to_single_trace(events);
            }
            let text = String::from_utf8(bytes).map_err(|e| format!("not UTF-8: {e}"))?;
            // An event log's first line carries an "event" key; a trace
            // file is one big object with "tasks"/"stages".
            let first = text.lines().find(|l| !l.trim().is_empty()).unwrap_or("");
            let looks_like_events = bigroots::util::json::Json::parse(first.trim())
                .map(|j| j.get("event").as_str().is_some())
                .unwrap_or(false);
            if looks_like_events {
                let events = eventlog::parse_tagged_events(&text).map_err(|e| e.to_string())?;
                events_to_single_trace(events)
            } else {
                codec::load(input).map_err(|e| format!("{e:#}"))
            }
        }
        other => Err(format!("unknown format '{other}' (auto | trace | ndjson | binary)")),
    }
}

/// `bigroots convert` — stream an event capture from one encoding to the
/// other through the incremental readers (`NdjsonTail` / `BinaryTail`),
/// never holding the whole input in memory as events, and preserve the
/// source's tag mode (a job-tagged stream stays tagged, an untagged one
/// stays untagged — byte-identical double round-trips depend on it).
fn cmd_convert(args: &bigroots::util::cli::Args) -> i32 {
    use bigroots::trace::eventlog::{NdjsonTail, TaggedEvent};
    use bigroots::trace::wire::{self, BinaryTail};
    use std::io::{Read, Write};

    let input = args.get("input").unwrap();
    let out_path = args.get("out").unwrap();
    let to = args.get_or("to", "auto");

    let mut infile = match std::fs::File::open(input) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("open {input}: {e}");
            return 1;
        }
    };
    // Sniff the input encoding from the first chunk.
    let mut chunk = vec![0u8; 64 * 1024];
    let first_n = match infile.read(&mut chunk) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("reading {input}: {e}");
            return 1;
        }
    };
    let in_binary = wire::is_binary(&chunk[..first_n]);
    let out_binary = match to.as_str() {
        "auto" => !in_binary,
        "binary" => true,
        "ndjson" => false,
        other => {
            eprintln!("unknown target format '{other}' (auto | binary | ndjson)");
            return 2;
        }
    };

    enum InParser {
        Nd(NdjsonTail),
        Bin(BinaryTail),
    }
    let mut parser = if in_binary {
        InParser::Bin(BinaryTail::new())
    } else {
        InParser::Nd(NdjsonTail::new())
    };

    let outfile = match std::fs::File::create(out_path) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("create {out_path}: {e}");
            return 1;
        }
    };
    let mut out = std::io::BufWriter::new(outfile);

    // The binary stream header needs the tag mode, which NDJSON input
    // only reveals at its first event — so the header write is deferred
    // until then. `None` = not yet known.
    let mut tagged: Option<bool> = None;
    let mut events_total = 0usize;
    let mut bytes_in = 0u64;
    let mut bytes_out = 0u64;
    let mut frame_buf = Vec::new();

    let mut emit = |events: Vec<TaggedEvent>,
                    tagged: &mut Option<bool>,
                    out: &mut std::io::BufWriter<std::fs::File>,
                    src_tagged: bool|
     -> Result<u64, String> {
        let mut wrote = 0u64;
        if events.is_empty() {
            return Ok(wrote);
        }
        if tagged.is_none() {
            *tagged = Some(src_tagged);
            if out_binary {
                let h = wire::encode_header(src_tagged);
                out.write_all(&h).map_err(|e| e.to_string())?;
                wrote += h.len() as u64;
            }
        }
        let is_tagged = tagged.expect("set above");
        for e in &events {
            if out_binary {
                frame_buf.clear();
                wire::encode_frame_into(
                    &mut frame_buf,
                    if is_tagged { Some(e.job_id) } else { None },
                    &e.event,
                );
                out.write_all(&frame_buf).map_err(|er| er.to_string())?;
                wrote += frame_buf.len() as u64;
            } else {
                // Untagged streams re-encode without the "job" field, so
                // NDJSON→binary→NDJSON is byte-identical on canonical
                // input in both tag modes.
                let line = if is_tagged {
                    e.encode().to_string()
                } else {
                    e.event.encode().to_string()
                };
                out.write_all(line.as_bytes()).map_err(|er| er.to_string())?;
                out.write_all(b"\n").map_err(|er| er.to_string())?;
                wrote += line.len() as u64 + 1;
            }
        }
        Ok(wrote)
    };

    let mut n = first_n;
    loop {
        if n > 0 {
            bytes_in += n as u64;
            let fed = match &mut parser {
                InParser::Nd(p) => {
                    let evs = match p.feed(&chunk[..n]) {
                        Ok(evs) => evs,
                        Err(e) => {
                            eprintln!("parsing {input}: {e}");
                            return 1;
                        }
                    };
                    let src_tagged = p.tag_mode().unwrap_or(true);
                    (evs, src_tagged)
                }
                InParser::Bin(p) => {
                    let evs = match p.feed(&chunk[..n]) {
                        Ok(evs) => evs,
                        Err(e) => {
                            eprintln!("parsing {input}: {e}");
                            return 1;
                        }
                    };
                    let src_tagged = p.header().map(|h| h.tagged).unwrap_or(true);
                    (evs, src_tagged)
                }
            };
            events_total += fed.0.len();
            match emit(fed.0, &mut tagged, &mut out, fed.1) {
                Ok(w) => bytes_out += w,
                Err(e) => {
                    eprintln!("writing {out_path}: {e}");
                    return 1;
                }
            }
        }
        n = loop {
            match infile.read(&mut chunk) {
                Ok(m) => break m,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    eprintln!("reading {input}: {e}");
                    return 1;
                }
            }
        };
        if n == 0 {
            break;
        }
    }
    // Flush the readers: NDJSON may hold a trailing unterminated line; a
    // binary capture ending mid-frame is truncation.
    let trailing = match &mut parser {
        InParser::Nd(p) => match p.finish() {
            Ok(ev) => {
                let src_tagged = p.tag_mode().unwrap_or(true);
                ev.map(|e| (vec![e], src_tagged))
            }
            Err(e) => {
                eprintln!("parsing {input}: {e}");
                return 1;
            }
        },
        InParser::Bin(p) => match p.finish() {
            Ok(()) => None,
            Err(e) => {
                eprintln!("parsing {input}: {e}");
                return 1;
            }
        },
    };
    if let Some((evs, src_tagged)) = trailing {
        events_total += evs.len();
        match emit(evs, &mut tagged, &mut out, src_tagged) {
            Ok(w) => bytes_out += w,
            Err(e) => {
                eprintln!("writing {out_path}: {e}");
                return 1;
            }
        }
    }
    // An empty capture still gets a valid (tagged) binary header, so the
    // output is always readable by the replay sources.
    if tagged.is_none() && out_binary {
        let h = wire::encode_header(true);
        if let Err(e) = out.write_all(&h) {
            eprintln!("writing {out_path}: {e}");
            return 1;
        }
        bytes_out += h.len() as u64;
    }
    if let Err(e) = out.flush() {
        eprintln!("writing {out_path}: {e}");
        return 1;
    }
    let (in_fmt, out_fmt) = (
        if in_binary { "binary" } else { "ndjson" },
        if out_binary { "binary" } else { "ndjson" },
    );
    let ratio = if bytes_out > 0 { bytes_in as f64 / bytes_out as f64 } else { 0.0 };
    println!(
        "{input} ({in_fmt}, {bytes_in} bytes) → {out_path} ({out_fmt}, {bytes_out} bytes): \
         {events_total} events, {ratio:.2}× size ratio",
    );
    0
}

fn cmd_stream(args: &bigroots::util::cli::Args) -> i32 {
    let input = args.get("input").unwrap();
    let text = match std::fs::read_to_string(input) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("reading {input}: {e}");
            return 1;
        }
    };
    match bigroots::coordinator::streaming::analyze_stream_threaded(
        text,
        Box::new(bigroots::analysis::stats::NativeBackend::new()),
        Default::default(),
    ) {
        Ok(an) => {
            println!("consumed {} events, analyzed {} stages", an.events_seen, an.results.len());
            for a in &an.results {
                println!(
                    "stage {}: {} stragglers, {} causes",
                    a.stage_id,
                    a.stragglers.rows.len(),
                    a.causes.len()
                );
            }
            let inc = an.incomplete_stages();
            if !inc.is_empty() {
                println!("incomplete stages at stream end: {inc:?}");
            }
            0
        }
        Err(e) => {
            eprintln!("stream error: {e}");
            1
        }
    }
}

/// `bigroots explain --replay <dump>` — the offline half of the verdict
/// provenance loop: parse a flight-recorder dump, re-run the full
/// pipeline over the frozen raw events under the frozen config and fleet
/// baselines, and require the reproduced verdict to match the recorded
/// one byte for byte.
fn cmd_explain(args: &bigroots::util::cli::Args) -> i32 {
    use bigroots::analysis::explain::FlightDump;

    let path = args.get("replay").unwrap();
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("reading {path}: {e}");
            return 1;
        }
    };
    let parsed = match args.get_or("format", "auto").as_str() {
        "auto" => FlightDump::parse_any(&bytes),
        "binary" => FlightDump::parse_binary(&bytes),
        "ndjson" => match std::str::from_utf8(&bytes) {
            Ok(t) => FlightDump::parse(t),
            Err(e) => Err(format!("not UTF-8: {e}")),
        },
        other => {
            eprintln!("unknown format '{other}' (auto | ndjson | binary)");
            return 2;
        }
    };
    let dump = match parsed {
        Ok(d) => d,
        Err(e) => {
            eprintln!("parsing {path}: {e}");
            return 1;
        }
    };
    if !dump.complete {
        eprintln!(
            "warning: dump window is incomplete (ring evicted events before the verdict \
             froze it); replay may not reproduce the recorded verdict"
        );
    }
    let replayed = match dump.replay() {
        Ok(v) => v,
        Err(e) => {
            eprintln!("replay failed: {e}");
            return 1;
        }
    };
    let recorded = dump.verdict.to_string();
    let reproduced = replayed.to_string();
    if args.flag("verbose") {
        print!("{}", bigroots::analysis::report::render_explain(&replayed));
        println!("{reproduced}");
    }
    println!(
        "job {} incarnation {}: {} events, {} stages in verdict",
        dump.job_id,
        dump.incarnation,
        dump.events.len(),
        replayed.get("stages").as_arr().map(|a| a.len()).unwrap_or(0),
    );
    if recorded == reproduced {
        println!("replay verdict matches the recorded verdict bit-identically");
        0
    } else {
        eprintln!("REPLAY MISMATCH");
        eprintln!("recorded:   {recorded}");
        eprintln!("reproduced: {reproduced}");
        1
    }
}

fn cmd_serve(args: &bigroots::util::cli::Args) -> i32 {
    use bigroots::live::control::{self, ControlCommand, ControlServer};
    use bigroots::live::{
        persist, BinaryTailSource, CompletedJob, EventSource, LifecycleConfig, LiveConfig,
        LiveServer, MemorySource, MmapReplaySource, SourcePoll, StdinSource, TailSource,
        TcpSource,
    };
    use bigroots::obs;
    use bigroots::sim::multi;
    use bigroots::trace::eventlog::parse_tagged_events;
    use bigroots::trace::wire;
    use bigroots::util::json::Json;

    if let Err(e) = obs::log::set_level_str(&args.get_or("log-level", "info")) {
        eprintln!("{e}");
        return 2;
    }
    obs::log::set_json(args.flag("log-json"));
    // The span recorder is on for every serve run unless the operator asks
    // for the uninstrumented baseline; nothing else in the binary enables
    // it, so offline analysis stays at the one-atomic-load disabled cost.
    obs::set_enabled(!args.flag("no-obs"));
    let self_analyze = args.flag("self-analyze");

    let cfg = LiveConfig {
        shards: args.get_usize("shards", 4),
        queue_capacity: args.get_usize("queue-cap", 8),
        ingest_batch: match args.get_usize("batch-events", 0) {
            0 => args.get_usize("ingest-batch", 64),
            n => n,
        },
        lifecycle: LifecycleConfig {
            evict_after: args.get_f64("evict-after", 5.0),
            ..Default::default()
        },
        stats_cache_capacity: args.get_usize("stats-cache", 256),
        stats_cache_stripes: args.get_usize("cache-stripes", 8),
        route_large_tasks: args.get_usize("route-large", 0),
        flight_capacity: args.get_usize("flight-capacity", 16384),
        ..Default::default()
    };
    // The flight dump freezes the analyzer config the verdict ran under;
    // keep a copy before the server takes ownership.
    let analyzer_cfg = cfg.bigroots;

    // Pick the transport: tail / listen / stdin are live; --input replays
    // a file; with none of those, simulate an interleaved multi-job run.
    let tail = args.get_or("tail", "");
    let listen = args.get_or("listen", "");
    let format = args.get_or("format", "auto");
    if !matches!(format.as_str(), "auto" | "ndjson" | "binary") {
        eprintln!("unknown --format '{format}' (auto | ndjson | binary)");
        return 2;
    }
    // `auto`: the wire magic decides when the file already exists; the
    // `.bew` extension decides for a capture a writer has yet to create.
    let wants_binary = |path: &str| -> bool {
        match format.as_str() {
            "binary" => true,
            "ndjson" => false,
            _ => {
                use std::io::Read;
                let mut magic = [0u8; 4];
                match std::fs::File::open(path).map(|mut f| f.read_exact(&mut magic)) {
                    Ok(Ok(())) => wire::is_binary(&magic),
                    _ => path.ends_with(".bew"),
                }
            }
        }
    };
    let mut source: Box<dyn EventSource> = if !tail.is_empty() {
        if wants_binary(&tail) {
            Box::new(BinaryTailSource::new(&tail))
        } else {
            Box::new(TailSource::new(&tail))
        }
    } else if !listen.is_empty() {
        // --idle-timeout 0 means "run forever": keep the socket open
        // across client generations instead of ending after the last
        // client disconnects.
        let bound = if args.get_f64("idle-timeout", 10.0) == 0.0 {
            TcpSource::bind_persistent(&listen)
        } else {
            TcpSource::bind(&listen)
        };
        match bound {
            Ok(s) => {
                println!("listening on {}", s.local_addr());
                Box::new(s)
            }
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        }
    } else if args.flag("stdin") {
        Box::new(StdinSource::new())
    } else {
        let input = args.get_or("input", "");
        if !input.is_empty() && wants_binary(&input) {
            // Binary capture: replay straight off the mapped pages —
            // frames decode with zero copy, no text parse anywhere.
            // --decode-threads > 1 splits the capture into frame-aligned
            // partitions decoded on the thread pool (same event order).
            let decode_threads = match args.get_usize("decode-threads", 1) {
                0 => std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4),
                n => n,
            };
            match MmapReplaySource::open(&input) {
                Ok(s) => Box::new(s.with_decode_threads(decode_threads)) as Box<dyn EventSource>,
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            }
        } else {
            let events = if input.is_empty() {
                let n = args.get_usize("jobs", 8);
                let scale = args.get_f64("scale", 0.3);
                let seed = args.get_u64("seed", 42);
                println!("simulating {n} jobs (scale {scale}, seed {seed})…");
                let specs = multi::round_robin_specs(n, scale, seed);
                let (_, events) = multi::interleaved_workload(&specs);
                events
            } else {
                let text = match std::fs::read_to_string(&input) {
                    Ok(t) => t,
                    Err(e) => {
                        eprintln!("reading {input}: {e}");
                        return 1;
                    }
                };
                match parse_tagged_events(&text) {
                    Ok(ev) => ev,
                    Err(e) => {
                        eprintln!("parsing {input}: {e}");
                        return 1;
                    }
                }
            };
            Box::new(MemorySource::new(events, 1024))
        }
    };

    println!("serving from {} over {} shards", source.describe(), cfg.shards);
    let snapshot_every = args.get_f64("snapshot-every", 5.0).max(0.1);
    let idle_timeout = args.get_f64("idle-timeout", 10.0);
    let snapshot_path = args.get_or("snapshot-path", "");
    let control_addr = args.get_or("control-port", "");
    let mut control = if control_addr.is_empty() {
        None
    } else {
        match ControlServer::bind(&control_addr) {
            Ok(c) => {
                println!("control socket on {}", c.local_addr());
                Some(c)
            }
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        }
    };
    let metrics_addr = args.get_or("metrics-port", "");
    let mut metrics_http = if metrics_addr.is_empty() {
        None
    } else {
        match obs::MetricsServer::bind(&metrics_addr) {
            Ok(s) => {
                match s.local_addr() {
                    Ok(a) => println!("metrics endpoint on http://{a}/metrics"),
                    Err(_) => println!("metrics endpoint on http://{metrics_addr}/metrics"),
                }
                Some(s)
            }
            Err(e) => {
                eprintln!("metrics bind {metrics_addr}: {e}");
                return 1;
            }
        }
    };
    let mut server = LiveServer::new(cfg);

    // Restore the fleet baseline from the last shutdown's snapshot: the
    // cross-job history the registry's verdicts depend on survives the
    // restart.
    if !snapshot_path.is_empty() && std::path::Path::new(&snapshot_path).exists() {
        match persist::load_snapshot(&snapshot_path) {
            Ok(reg) => {
                println!(
                    "restored fleet baseline from {snapshot_path}: {} stages folded",
                    reg.stages_folded()
                );
                server.restore_registry(reg);
            }
            Err(e) => obs::log::warn(
                "serve",
                &format!("snapshot restore failed ({e}); starting with a fresh baseline"),
            ),
        }
    }

    let print_job = |j: &CompletedJob| {
        let stragglers: usize = j.analyses.iter().map(|a| a.stragglers.rows.len()).sum();
        let causes: usize = j.analyses.iter().map(|a| a.causes.len()).sum();
        let best_fix = j
            .whatif
            .as_ref()
            .and_then(|w| w.top())
            .filter(|top| top.saved_secs > 0.0)
            .map(|top| {
                format!(
                    " — best fix: {} (est. {:.1}s saved)",
                    top.kind.name(),
                    top.saved_secs
                )
            })
            .unwrap_or_default();
        println!(
            "job {}{}: {} stages, {} stragglers, {} causes, {} fleet flags{}{}{}",
            j.job_id,
            if j.incarnation > 0 { format!(" (incarnation {})", j.incarnation) } else { String::new() },
            j.analyses.len(),
            stragglers,
            causes,
            j.fleet_flags.len(),
            if j.evicted_live { " [evicted]" } else { "" },
            if j.incomplete.is_empty() {
                String::new()
            } else {
                format!(" — incomplete stages {:?}", j.incomplete)
            },
            best_fix,
        );
    };

    let started = std::time::Instant::now();
    let mut last_snapshot = std::time::Instant::now();
    let mut idle_since: Option<std::time::Instant> = None;
    // Latest summary per retired job id, for the control plane's `job`
    // and `jobs` verbs (retired jobs are drained out of the server as
    // they complete). A BTreeMap so the `jobs` keyset cursor can resume
    // in id order. Bounded like everything else on the unbounded-stream
    // path: oldest retirements age out once the cap is hit.
    const MAX_JOB_SUMMARIES: usize = 4096;
    let mut job_summaries: std::collections::BTreeMap<u64, Json> =
        std::collections::BTreeMap::new();
    // The full what-if verdict per retired job, for the `what-if <id>`
    // verb. Same bound and age-out as the summaries.
    let mut job_whatifs: std::collections::HashMap<u64, Json> =
        std::collections::HashMap::new();
    // The verdict provenance document per retired job (`explain <id>`).
    let mut job_explains: std::collections::HashMap<u64, Json> =
        std::collections::HashMap::new();
    let mut job_summary_order: std::collections::VecDeque<u64> =
        std::collections::VecDeque::new();
    // Frozen flight windows are raw event buffers — orders of magnitude
    // heavier than a summary line — so they get their own, much smaller
    // retention window for `explain <id> dump <path>`.
    const MAX_JOB_DUMPS: usize = 64;
    let mut job_dumps: std::collections::HashMap<u64, bigroots::analysis::explain::FlightDump> =
        std::collections::HashMap::new();
    let mut job_dump_order: std::collections::VecDeque<u64> =
        std::collections::VecDeque::new();
    // Retirement wall-clock (unix seconds) stamped onto each summary for
    // the `jobs since=/until=` filters.
    let unix_now = || {
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0)
    };
    let mut shutdown_requested = false;
    // Non-zero when the source died — the drain-then-snapshot exit still
    // runs (losing the registry on a disk error would defeat the point of
    // persistence), but the process reports the failure.
    let mut exit_code = 0;
    // stages_folded at the last periodic snapshot write; restored state
    // counts, so an idle rebooted server doesn't rewrite the same file.
    let mut last_snapshot_stages = server.registry().stages_folded();
    let write_snapshot = |server: &LiveServer, path: &str| -> Result<usize, String> {
        let _g = obs::span(obs::SpanKind::SnapshotWrite);
        let reg = server.registry();
        persist::save_snapshot(reg, path).map(|()| reg.stages_folded())
    };
    loop {
        let poll_span = obs::span(obs::SpanKind::SourcePoll);
        let polled = source.poll();
        poll_span.finish();
        match polled {
            Ok(SourcePoll::Events(events)) => {
                idle_since = None;
                // Batched ingest: the run-length demux routes whole
                // same-job runs, not individual events.
                server.feed_all(&events);
            }
            Ok(SourcePoll::Idle) => {
                server.pump();
                let idle = idle_since.get_or_insert_with(std::time::Instant::now);
                if idle_timeout > 0.0 && idle.elapsed().as_secs_f64() >= idle_timeout {
                    println!("(idle for {idle_timeout}s — stopping)");
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            Ok(SourcePoll::End) => {
                if control.is_some() {
                    // The capture is exhausted but the control plane is
                    // live: linger so operators (and the CI client) can
                    // still query; exit via the idle timeout or the
                    // `shutdown` verb.
                    server.pump();
                    let idle = idle_since.get_or_insert_with(std::time::Instant::now);
                    if idle_timeout > 0.0 && idle.elapsed().as_secs_f64() >= idle_timeout {
                        println!("(source ended; idle for {idle_timeout}s — stopping)");
                        break;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(20));
                } else {
                    break;
                }
            }
            Err(e) => {
                obs::log::error(
                    "serve",
                    &format!("source error: {e} — draining and snapshotting before exit"),
                );
                exit_code = 1;
                break;
            }
        }
        server.record_source_stats(source.dropped_partial_lines(), source.parse_errors());
        server.record_source_wire_stats(source.frame_resyncs(), source.dropped_frames());
        for j in server.drain_completed() {
            let mut summary = control::job_summary_json(&j);
            summary.set("retired_at", unix_now().into());
            // A refreshed id (revived incarnation) moves to the back of
            // the age queue, so the newest summary is the last to go.
            if job_summaries.insert(j.job_id, summary).is_some() {
                if let Some(pos) = job_summary_order.iter().position(|&id| id == j.job_id) {
                    job_summary_order.remove(pos);
                }
            }
            match &j.whatif {
                Some(w) => {
                    job_whatifs.insert(j.job_id, w.to_json());
                }
                None => {
                    // A revived incarnation with no analyzed stages must
                    // not serve the previous incarnation's verdict.
                    job_whatifs.remove(&j.job_id);
                }
            }
            // Same revival rule for the provenance document and the
            // flight dump: a fresh incarnation supersedes or clears.
            match control::explain_json(&j) {
                Ok(doc) => {
                    job_explains.insert(j.job_id, doc);
                }
                Err(_) => {
                    job_explains.remove(&j.job_id);
                }
            }
            match control::flight_dump(&j, &analyzer_cfg) {
                Ok(dump) => {
                    if job_dumps.insert(j.job_id, dump).is_some() {
                        if let Some(pos) = job_dump_order.iter().position(|&id| id == j.job_id)
                        {
                            job_dump_order.remove(pos);
                        }
                    }
                    job_dump_order.push_back(j.job_id);
                    while job_dump_order.len() > MAX_JOB_DUMPS {
                        if let Some(old) = job_dump_order.pop_front() {
                            job_dumps.remove(&old);
                        }
                    }
                }
                Err(_) => {
                    job_dumps.remove(&j.job_id);
                    if let Some(pos) = job_dump_order.iter().position(|&id| id == j.job_id) {
                        job_dump_order.remove(pos);
                    }
                }
            }
            job_summary_order.push_back(j.job_id);
            while job_summary_order.len() > MAX_JOB_SUMMARIES {
                if let Some(old) = job_summary_order.pop_front() {
                    job_summaries.remove(&old);
                    job_whatifs.remove(&old);
                    job_explains.remove(&old);
                    job_dumps.remove(&old);
                    if let Some(pos) = job_dump_order.iter().position(|&id| id == old) {
                        job_dump_order.remove(pos);
                    }
                }
            }
            print_job(&j);
        }
        // Control plane: answer operator queries on the same driver
        // thread, in request order.
        if let Some(ctrl) = control.as_mut() {
            let requests = match ctrl.poll() {
                Ok(r) => r,
                Err(e) => {
                    obs::log::error("live.control", &format!("control error: {e}"));
                    Vec::new()
                }
            };
            for req in requests {
                let req_span = obs::span(obs::SpanKind::Control);
                let resp = match &req.command {
                    ControlCommand::FleetReport => control::ok_response(
                        "fleet-report",
                        control::fleet_report_json(&control::fleet_report(&server)),
                    ),
                    ControlCommand::Metrics => control::ok_response(
                        "metrics",
                        control::live_metrics_json(&server.metrics()),
                    ),
                    // The exposition text rides inside the JSON envelope so
                    // the one-line-per-response protocol holds; operators
                    // wanting plain text scrape --metrics-port instead.
                    ControlCommand::MetricsProm => control::ok_response(
                        "metrics-prom",
                        Json::from_pairs(vec![(
                            "text",
                            obs::prom::render(
                                obs::global(),
                                Some(&server.metrics()),
                                Some(&control::fleet_report(&server)),
                            )
                            .into(),
                        )]),
                    ),
                    ControlCommand::SelfReport => {
                        match obs::selfmon::analyze(&obs::telemetry().samples()) {
                            Some(r) => control::ok_response("self-report", r.to_json()),
                            None => control::err_response(
                                "self-analysis needs more batch samples (keep the stream \
                                 flowing and retry)",
                            ),
                        }
                    }
                    ControlCommand::Job(id) => match job_summaries.get(id) {
                        Some(j) => control::ok_response("job", j.clone()),
                        None => control::err_response(&format!("job {id} has not retired")),
                    },
                    ControlCommand::Jobs(q) => {
                        control::ok_response("jobs", control::jobs_page(&job_summaries, q))
                    }
                    ControlCommand::Explain(id) => match job_explains.get(id) {
                        Some(doc) => control::ok_response("explain", doc.clone()),
                        None if job_summaries.contains_key(id) => control::err_response(
                            &format!("job {id} retired with no analyzed stages"),
                        ),
                        None => control::err_response(&format!("job {id} has not retired")),
                    },
                    ControlCommand::ExplainDump(id, path) => match job_dumps.get(id) {
                        // A `.bew` destination gets the binary container
                        // (`bigroots explain --replay` sniffs either).
                        Some(dump) => match if path.ends_with(".bew") {
                            std::fs::write(path, dump.encode_binary())
                        } else {
                            std::fs::write(path, dump.encode_ndjson())
                        } {
                            Ok(()) => control::ok_response(
                                "explain-dump",
                                Json::from_pairs(vec![
                                    ("path", path.as_str().into()),
                                    ("job_id", id.to_string().into()),
                                    ("events", dump.events.len().into()),
                                    ("complete", dump.complete.into()),
                                ]),
                            ),
                            Err(e) => control::err_response(&format!("writing {path}: {e}")),
                        },
                        None if job_summaries.contains_key(id) => control::err_response(
                            &format!(
                                "job {id} has no flight window (no straggler verdict fired, \
                                 or the dump aged out)"
                            ),
                        ),
                        None => control::err_response(&format!("job {id} has not retired")),
                    },
                    ControlCommand::WhatIf(id) => match job_whatifs.get(id) {
                        Some(w) => control::ok_response("what-if", w.clone()),
                        None if job_summaries.contains_key(id) => control::err_response(
                            &format!("job {id} retired with no analyzed stages"),
                        ),
                        None => control::err_response(&format!("job {id} has not retired")),
                    },
                    ControlCommand::Snapshot => {
                        if snapshot_path.is_empty() {
                            control::err_response("no --snapshot-path configured")
                        } else {
                            match write_snapshot(&server, &snapshot_path) {
                                Ok(stages) => {
                                    // The cadence guard sees this write.
                                    last_snapshot_stages = stages;
                                    control::ok_response(
                                        "snapshot",
                                        Json::from_pairs(vec![
                                            ("path", snapshot_path.as_str().into()),
                                            ("stages", stages.into()),
                                        ]),
                                    )
                                }
                                Err(e) => control::err_response(&e),
                            }
                        }
                    }
                    ControlCommand::Shutdown => {
                        shutdown_requested = true;
                        control::ok_response("shutdown", Json::obj())
                    }
                    ControlCommand::Invalid(msg) => control::err_response(msg),
                };
                ctrl.respond(&req, &resp);
                req_span.finish();
            }
        }
        // Scrape endpoint: render on demand, never block the driver.
        if let Some(ms) = metrics_http.as_mut() {
            ms.poll(|| {
                obs::prom::render(
                    obs::global(),
                    Some(&server.metrics()),
                    Some(&control::fleet_report(&server)),
                )
            });
        }
        if shutdown_requested {
            println!("(shutdown requested via control socket — draining)");
            break;
        }
        if last_snapshot.elapsed().as_secs_f64() >= snapshot_every
            && server.registry().stages_folded() > 0
        {
            last_snapshot = std::time::Instant::now();
            print!("{}", control::fleet_report_text(&server));
            if self_analyze {
                match obs::selfmon::analyze(&obs::telemetry().samples()) {
                    Some(r) => print!("{}", r.render()),
                    None => println!("self-analysis: warming up (not enough batch samples yet)"),
                }
            }
            // Skip the file write when nothing folded since the last one
            // — an idle restored server must not churn the disk forever.
            let folded = server.registry().stages_folded();
            if !snapshot_path.is_empty() && folded != last_snapshot_stages {
                match write_snapshot(&server, &snapshot_path) {
                    Ok(_) => last_snapshot_stages = folded,
                    Err(e) => obs::log::warn("serve", &format!("snapshot write failed: {e}")),
                }
            }
        }
    }

    // Get any queued control responses (the shutdown ack in particular)
    // onto the wire before draining — respond() never blocks, so a
    // WouldBlock leftover would otherwise die with the process.
    if let Some(ctrl) = control.as_mut() {
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        while ctrl.pending_responses() > 0 && std::time::Instant::now() < deadline {
            ctrl.flush();
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        ctrl.flush();
    }

    // Drain-then-snapshot exit: retire every resident job, then persist
    // the final baseline so the next boot resumes from it.
    server.record_source_stats(source.dropped_partial_lines(), source.parse_errors());
    server.record_source_wire_stats(source.frame_resyncs(), source.dropped_frames());
    let (report, registry) = server.finish_with_registry();
    if !snapshot_path.is_empty() {
        match persist::save_snapshot(&registry, &snapshot_path) {
            Ok(()) => println!(
                "wrote fleet snapshot {snapshot_path} ({} stages folded)",
                registry.stages_folded()
            ),
            Err(e) => obs::log::error("serve", &format!("final snapshot write failed: {e}")),
        }
    }
    for j in &report.jobs {
        print_job(j);
    }
    print!("{}", report.fleet.render());
    let m = &report.metrics;
    println!(
        "{} events, {} jobs completed ({} live evictions, {} strays dropped, \
         {} partial lines dropped) in {:.3}s — {:.0} events/s, {} stages analyzed \
         ({} stats-cache hits / {} misses), resident high-water {}",
        m.events_total,
        m.jobs_completed,
        m.evictions_live,
        m.events_dropped,
        m.dropped_partial_lines,
        started.elapsed().as_secs_f64(),
        m.events_per_sec,
        m.stages_analyzed,
        m.cache_hits,
        m.cache_misses,
        m.resident_high_water,
    );
    if self_analyze {
        match obs::selfmon::analyze(&obs::telemetry().samples()) {
            Some(r) => print!("{}", r.render()),
            None => println!(
                "self-analysis: not enough batch samples ({} recorded) — \
                 a longer run is needed for a verdict",
                obs::telemetry().total_recorded()
            ),
        }
    }
    if args.flag("metrics") {
        let mut t = Table::new("Per-shard metrics")
            .header(&["shard", "events", "stages", "resident", "high-water", "evicted"])
            .aligns(&[
                Align::Right,
                Align::Right,
                Align::Right,
                Align::Right,
                Align::Right,
                Align::Right,
            ]);
        for s in &m.per_shard {
            t.row(vec![
                s.shard.to_string(),
                s.events.to_string(),
                s.stages.to_string(),
                s.resident.to_string(),
                s.resident_high.to_string(),
                s.evicted.to_string(),
            ]);
        }
        print!("{}", t.render());
    }
    exit_code
}

fn cmd_verify(args: &bigroots::util::cli::Args) -> i32 {
    let reps = args.get_usize("reps", 10);
    let scale = args.get_f64("scale", 1.0);
    let seed = args.get_u64("seed", 42);
    let rows = experiments::table3(reps, scale, seed);
    let mut t = Table::new("Table III: BigRoots vs PCC (TP/FP over resource features)")
        .header(&["Experiment", "BigRoots TP", "BigRoots FP", "PCC TP", "PCC FP"])
        .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
    for (kind, m) in &rows {
        t.row(vec![
            format!("{} AG", kind.as_str()),
            m.bigroots_kind.0.to_string(),
            m.bigroots_kind.1.to_string(),
            m.pcc_kind.0.to_string(),
            m.pcc_kind.1.to_string(),
        ]);
    }
    print!("{}", t.render());
    0
}

fn cmd_multi(args: &bigroots::util::cli::Args) -> i32 {
    let m = experiments::table5(args.get_f64("scale", 1.0), args.get_u64("seed", 42));
    let mut t = Table::new("Table V: multi-node anomaly schedule (Table IV)")
        .header(&["Method", "TP", "TN", "FP", "FN", "FPR", "TPR", "ACC"])
        .aligns(&[
            Align::Left,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
        ]);
    for (name, c) in [("BigRoots", m.bigroots), ("PCC", m.pcc)] {
        t.row(vec![
            name.to_string(),
            c.tp.to_string(),
            c.tn.to_string(),
            c.fp.to_string(),
            c.fn_.to_string(),
            pct(c.fpr()),
            pct(c.tpr()),
            pct(c.acc()),
        ]);
    }
    print!("{}", t.render());
    0
}

fn cmd_hibench(args: &bigroots::util::cli::Args) -> i32 {
    let rows = experiments::table6(args.get_f64("scale", 1.0), args.get_u64("seed", 42));
    print!("{}", bigroots::analysis::report::render_table6(&rows));
    0
}

fn cmd_roc(args: &bigroots::util::cli::Args) -> i32 {
    let Some(setting) = parse_setting(&args.get_or("setting", "cpu")) else {
        eprintln!("unknown setting");
        return 2;
    };
    let r = experiments::fig8(
        setting,
        args.get_usize("reps", 5),
        args.get_f64("scale", 0.6),
        args.get_u64("seed", 42),
    );
    println!(
        "{}: BigRoots AUC {} vs PCC AUC {} ({} / {} sweep points)",
        setting.label(),
        fnum(r.bigroots_auc, 4),
        fnum(r.pcc_auc, 4),
        r.bigroots_points.len(),
        r.pcc_points.len()
    );
    0
}

fn cmd_run(args: &bigroots::util::cli::Args) -> i32 {
    let path = args.get("config").unwrap();
    let cfg = match ExperimentConfig::load(path) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config: {e:#}");
            return 1;
        }
    };
    let Some(w) = workloads::by_name(&cfg.workload, cfg.scale) else {
        eprintln!("unknown workload '{}'", cfg.workload);
        return 2;
    };
    let plan = cfg.injection.plan(cfg.seed, cfg.sim.nodes);
    let mut eng = Engine::new(cfg.sim.clone());
    let trace = eng.run(&cfg.workload, w.name, &w.stages, &plan);
    let mut pipeline = Pipeline::auto();
    pipeline.bigroots = cfg.bigroots;
    pipeline.pcc = Some(cfg.pcc);
    let analysis = pipeline.analyze(&trace, w.domain);
    println!(
        "{}: {} stragglers / {} tasks; causes: {}",
        cfg.workload,
        analysis.total_stragglers(),
        trace.tasks.len(),
        analysis
            .summary
            .causes
            .iter()
            .map(|(k, n)| format!("{}({})", k.name(), n))
            .collect::<Vec<_>>()
            .join(" ")
    );
    // Scored confusion when the plan carries ground truth.
    if !trace.injections.is_empty() {
        let mut conf = bigroots::analysis::Confusion::default();
        for (sf, a) in &analysis.per_stage {
            let gt = bigroots::analysis::ground_truth(&trace, sf, experiments::GT_COVERAGE);
            conf.add(bigroots::analysis::roc::score_filtered(a, &gt, &resource_features()));
        }
        println!(
            "vs ground truth: TP {} FP {} TN {} FN {} (FPR {} TPR {} ACC {})",
            conf.tp,
            conf.fp,
            conf.tn,
            conf.fn_,
            pct(conf.fpr()),
            pct(conf.tpr()),
            pct(conf.acc())
        );
    }
    let _ = FeatureKind::COUNT;
    0
}
