//! `bigroots` — the command-line launcher.
//!
//! Subcommands cover the full paper workflow:
//!
//! ```text
//! bigroots simulate   — run a workload on the simulated cluster → trace.json
//! bigroots analyze    — offline root-cause analysis of a trace file
//! bigroots stream     — streaming analysis of an event log (ndjson)
//! bigroots verify     — Table III single-AG verification (BigRoots vs PCC)
//! bigroots multi      — Tables IV+V multi-node anomaly schedule
//! bigroots hibench    — Table VI case study over the 11 workloads
//! bigroots roc        — Fig. 8 threshold sweep + AUC comparison
//! bigroots run        — run a declarative experiment config (JSON)
//! ```

use bigroots::analysis::features::FeatureKind;
use bigroots::analysis::roc::resource_features;
use bigroots::coordinator::experiments::{self, AgSetting};
use bigroots::coordinator::{ExperimentConfig, Pipeline};
use bigroots::sim::{workloads, Engine};
use bigroots::trace::{codec, eventlog, AnomalyKind};
use bigroots::util::cli::Command;
use bigroots::util::table::{fnum, pct, Align, Table};

fn main() {
    let cmd = Command::new("bigroots", "root-cause analysis of stragglers in big data systems")
        .subcommand(
            Command::new("simulate", "simulate a workload, write a trace file")
                .opt("workload", "NaiveBayes", "workload name (see `hibench` for the list)")
                .opt("scale", "1.0", "task-count scale factor")
                .opt("seed", "42", "rng seed")
                .opt("inject", "none", "anomaly: none | cpu | io | network | mixed | table4")
                .opt("node", "1", "injection target node")
                .opt("out", "trace.json", "output trace path")
                .flag("events", "also write an event log next to the trace"),
        )
        .subcommand(
            Command::new("analyze", "offline analysis of a trace file")
                .opt_req("input", "trace file (from `simulate` or a converter)")
                .opt("backend", "auto", "stats backend: auto | native | xla")
                .flag("pcc", "also run the PCC baseline")
                .flag("verbose", "print every straggler with its causes"),
        )
        .subcommand(
            Command::new("stream", "streaming analysis of an ndjson event log")
                .opt_req("input", "event log path"),
        )
        .subcommand(
            Command::new("serve", "long-running multi-tenant analysis server (live/ subsystem)")
                .opt("tail", "", "follow a growing job-tagged ndjson event log (live mode)")
                .opt("listen", "", "accept line-delimited events over TCP, e.g. 127.0.0.1:7070")
                .flag("stdin", "read the event stream from stdin (live mode)")
                .opt("input", "", "replay a job-tagged ndjson event log (omit to simulate --jobs)")
                .opt("jobs", "8", "jobs to simulate when no input/tail/listen is given")
                .opt("scale", "0.3", "workload scale for simulated jobs")
                .opt("seed", "42", "base seed for simulated jobs")
                .opt("shards", "4", "shard worker threads (parallel demux + analysis)")
                .opt("queue-cap", "8", "per-shard queue capacity in batches (backpressure bound)")
                .opt("ingest-batch", "64", "events per shard-queue send")
                .opt("evict-after", "5", "event-time quiescence (s) after job_end before eviction")
                .opt("stats-cache", "256", "per-shard stage-stats memo capacity (0 disables)")
                .opt("snapshot-every", "5", "seconds between fleet-baseline snapshots (live mode)")
                .opt(
                    "idle-timeout",
                    "10",
                    "stop after this many idle seconds (0 = run forever; with --listen, \
                     also keeps the socket open across client generations)",
                )
                .flag("metrics", "print per-shard metrics"),
        )
        .subcommand(
            Command::new("verify", "Table III: single-AG verification vs PCC")
                .opt("reps", "10", "repetitions per AG kind")
                .opt("scale", "1.0", "workload scale")
                .opt("seed", "42", "base seed"),
        )
        .subcommand(
            Command::new("multi", "Tables IV+V: multi-node anomaly schedule")
                .opt("scale", "1.0", "workload scale")
                .opt("seed", "42", "seed"),
        )
        .subcommand(
            Command::new("hibench", "Table VI: the 11-workload case study")
                .opt("scale", "1.0", "workload scale")
                .opt("seed", "42", "seed"),
        )
        .subcommand(
            Command::new("roc", "Fig. 8: ROC sweep + AUC, BigRoots vs PCC")
                .opt("setting", "cpu", "cpu | io | network | mixed")
                .opt("reps", "5", "repetitions")
                .opt("scale", "0.6", "workload scale")
                .opt("seed", "42", "base seed"),
        )
        .subcommand(
            Command::new("run", "run a declarative experiment config")
                .opt_req("config", "JSON config path (see coordinator::config)"),
        );

    let (sub, args) = cmd.parse_env();
    let code = match sub.as_str() {
        "simulate" => cmd_simulate(&args),
        "analyze" => cmd_analyze(&args),
        "stream" => cmd_stream(&args),
        "serve" => cmd_serve(&args),
        "verify" => cmd_verify(&args),
        "multi" => cmd_multi(&args),
        "hibench" => cmd_hibench(&args),
        "roc" => cmd_roc(&args),
        "run" => cmd_run(&args),
        _ => unreachable!(),
    };
    std::process::exit(code);
}

fn parse_setting(s: &str) -> Option<AgSetting> {
    Some(match s.to_ascii_lowercase().as_str() {
        "none" => AgSetting::None,
        "cpu" => AgSetting::Single(AnomalyKind::Cpu),
        "io" => AgSetting::Single(AnomalyKind::Io),
        "network" | "net" => AgSetting::Single(AnomalyKind::Network),
        "mixed" => AgSetting::Mixed,
        _ => return None,
    })
}

fn cmd_simulate(args: &bigroots::util::cli::Args) -> i32 {
    let name = args.get_or("workload", "NaiveBayes");
    let scale = args.get_f64("scale", 1.0);
    let seed = args.get_u64("seed", 42);
    let Some(w) = workloads::by_name(&name, scale) else {
        eprintln!("unknown workload '{name}'");
        return 2;
    };
    let inject = args.get_or("inject", "none");
    let node = args.get_usize("node", 1);
    let horizon = 400.0 * scale.max(0.25);
    let plan = match inject.as_str() {
        "none" => bigroots::sim::InjectionPlan::none(),
        "cpu" => bigroots::sim::InjectionPlan::intermittent(AnomalyKind::Cpu, node, 15.0, 10.0, horizon),
        "io" => bigroots::sim::InjectionPlan::intermittent(AnomalyKind::Io, node, 15.0, 10.0, horizon),
        "network" | "net" => {
            bigroots::sim::InjectionPlan::intermittent(AnomalyKind::Network, node, 15.0, 10.0, horizon)
        }
        "mixed" => {
            let mut rng = bigroots::util::rng::Pcg64::seeded(seed ^ 0xA6);
            bigroots::sim::InjectionPlan::mixed(&mut rng, node, 15.0, 10.0, horizon)
        }
        "table4" => bigroots::sim::InjectionPlan::table4(|s| s - 1),
        other => {
            eprintln!("unknown injection '{other}'");
            return 2;
        }
    };
    let mut eng = Engine::new(bigroots::sim::SimConfig { seed, ..Default::default() });
    let trace = eng.run(&format!("{name}-{inject}"), w.name, &w.stages, &plan);
    let out = args.get_or("out", "trace.json");
    if let Err(e) = codec::save(&trace, &out) {
        eprintln!("write failed: {e:#}");
        return 1;
    }
    println!(
        "wrote {out}: {} tasks, {} stages, makespan {:.1}s, {} injections",
        trace.tasks.len(),
        trace.stages.len(),
        trace.makespan(),
        trace.injections.len()
    );
    if args.flag("events") {
        let epath = format!("{out}.events.ndjson");
        let events = eventlog::trace_to_events(&trace);
        if let Err(e) = eventlog::write_events(&events, &epath) {
            eprintln!("event log write failed: {e:#}");
            return 1;
        }
        println!("wrote {epath}: {} events", events.len());
    }
    0
}

fn make_pipeline(backend: &str) -> Result<Pipeline, String> {
    match backend {
        "auto" => Ok(Pipeline::auto()),
        "native" => Ok(Pipeline::native()),
        "xla" => {
            let dir = bigroots::runtime::XlaBackend::default_dir();
            let b = bigroots::runtime::XlaBackend::open(&dir)
                .map_err(|e| format!("XLA backend: {e:#}"))?;
            Ok(Pipeline::new(Box::new(b)))
        }
        other => Err(format!("unknown backend '{other}'")),
    }
}

fn cmd_analyze(args: &bigroots::util::cli::Args) -> i32 {
    let input = args.get("input").unwrap();
    let trace = match codec::load(input) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("loading {input}: {e:#}");
            return 1;
        }
    };
    let mut pipeline = match make_pipeline(&args.get_or("backend", "auto")) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if !args.flag("pcc") {
        pipeline.pcc = None;
    }
    let analysis = pipeline.analyze(&trace, "-");
    println!(
        "{} [{}] — {} tasks, {} stages, backend {}",
        trace.job_name,
        trace.workload,
        trace.tasks.len(),
        trace.stages.len(),
        pipeline.backend.name()
    );
    let mut t = Table::new("Per-stage summary")
        .header(&["stage", "tasks", "median (s)", "stragglers", "causes"])
        .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Left]);
    for (sf, a) in &analysis.per_stage {
        let hist = a
            .cause_histogram()
            .iter()
            .map(|(k, n)| format!("{}({})", k.name(), n))
            .collect::<Vec<_>>()
            .join(" ");
        t.row(vec![
            format!("{}", sf.stage_id),
            format!("{}", sf.num_tasks()),
            fnum(a.stragglers.median, 2),
            format!("{}", a.stragglers.rows.len()),
            if hist.is_empty() { "-".into() } else { hist },
        ]);
    }
    print!("{}", t.render());
    if args.flag("verbose") {
        for ann in &analysis.annotations {
            let causes: Vec<&str> = ann.causes.iter().map(|k| k.name()).collect();
            println!(
                "straggler task {} (stage {}, node {}) [{:.1}s..{:.1}s] scale {:.2}x → {}",
                ann.task_id,
                ann.stage_id,
                ann.node,
                ann.start,
                ann.finish,
                ann.scale,
                if causes.is_empty() { "unexplained".to_string() } else { causes.join(", ") }
            );
        }
    }
    if args.flag("pcc") {
        let pcc_causes: usize = analysis.pcc_per_stage.iter().map(|a| a.causes.len()).sum();
        println!("PCC baseline: {pcc_causes} causes (vs BigRoots {})", analysis.total_causes());
    }
    0
}

fn cmd_stream(args: &bigroots::util::cli::Args) -> i32 {
    let input = args.get("input").unwrap();
    let text = match std::fs::read_to_string(input) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("reading {input}: {e}");
            return 1;
        }
    };
    match bigroots::coordinator::streaming::analyze_stream_threaded(
        text,
        Box::new(bigroots::analysis::stats::NativeBackend::new()),
        Default::default(),
    ) {
        Ok(an) => {
            println!("consumed {} events, analyzed {} stages", an.events_seen, an.results.len());
            for a in &an.results {
                println!(
                    "stage {}: {} stragglers, {} causes",
                    a.stage_id,
                    a.stragglers.rows.len(),
                    a.causes.len()
                );
            }
            let inc = an.incomplete_stages();
            if !inc.is_empty() {
                println!("incomplete stages at stream end: {inc:?}");
            }
            0
        }
        Err(e) => {
            eprintln!("stream error: {e}");
            1
        }
    }
}

fn cmd_serve(args: &bigroots::util::cli::Args) -> i32 {
    use bigroots::live::{
        CompletedJob, EventSource, LifecycleConfig, LiveConfig, LiveServer, MemorySource,
        SourcePoll, StdinSource, TailSource, TcpSource,
    };
    use bigroots::sim::multi;
    use bigroots::trace::eventlog::parse_tagged_events;

    let cfg = LiveConfig {
        shards: args.get_usize("shards", 4),
        queue_capacity: args.get_usize("queue-cap", 8),
        ingest_batch: args.get_usize("ingest-batch", 64),
        lifecycle: LifecycleConfig {
            evict_after: args.get_f64("evict-after", 5.0),
            ..Default::default()
        },
        stats_cache_capacity: args.get_usize("stats-cache", 256),
        ..Default::default()
    };

    // Pick the transport: tail / listen / stdin are live; --input replays
    // a file; with none of those, simulate an interleaved multi-job run.
    let tail = args.get_or("tail", "");
    let listen = args.get_or("listen", "");
    let mut source: Box<dyn EventSource> = if !tail.is_empty() {
        Box::new(TailSource::new(&tail))
    } else if !listen.is_empty() {
        // --idle-timeout 0 means "run forever": keep the socket open
        // across client generations instead of ending after the last
        // client disconnects.
        let bound = if args.get_f64("idle-timeout", 10.0) == 0.0 {
            TcpSource::bind_persistent(&listen)
        } else {
            TcpSource::bind(&listen)
        };
        match bound {
            Ok(s) => {
                println!("listening on {}", s.local_addr());
                Box::new(s)
            }
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        }
    } else if args.flag("stdin") {
        Box::new(StdinSource::new())
    } else {
        let input = args.get_or("input", "");
        let events = if input.is_empty() {
            let n = args.get_usize("jobs", 8);
            let scale = args.get_f64("scale", 0.3);
            let seed = args.get_u64("seed", 42);
            println!("simulating {n} jobs (scale {scale}, seed {seed})…");
            let specs = multi::round_robin_specs(n, scale, seed);
            let (_, events) = multi::interleaved_workload(&specs);
            events
        } else {
            let text = match std::fs::read_to_string(&input) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("reading {input}: {e}");
                    return 1;
                }
            };
            match parse_tagged_events(&text) {
                Ok(ev) => ev,
                Err(e) => {
                    eprintln!("parsing {input}: {e}");
                    return 1;
                }
            }
        };
        Box::new(MemorySource::new(events, 1024))
    };

    println!("serving from {} over {} shards", source.describe(), cfg.shards);
    let snapshot_every = args.get_f64("snapshot-every", 5.0).max(0.1);
    let idle_timeout = args.get_f64("idle-timeout", 10.0);
    let mut server = LiveServer::new(cfg);

    let print_job = |j: &CompletedJob| {
        let stragglers: usize = j.analyses.iter().map(|a| a.stragglers.rows.len()).sum();
        let causes: usize = j.analyses.iter().map(|a| a.causes.len()).sum();
        println!(
            "job {}{}: {} stages, {} stragglers, {} causes, {} fleet flags{}{}",
            j.job_id,
            if j.incarnation > 0 { format!(" (incarnation {})", j.incarnation) } else { String::new() },
            j.analyses.len(),
            stragglers,
            causes,
            j.fleet_flags.len(),
            if j.evicted_live { " [evicted]" } else { "" },
            if j.incomplete.is_empty() {
                String::new()
            } else {
                format!(" — incomplete stages {:?}", j.incomplete)
            },
        );
    };

    let started = std::time::Instant::now();
    let mut last_snapshot = std::time::Instant::now();
    let mut idle_since: Option<std::time::Instant> = None;
    loop {
        match source.poll() {
            Ok(SourcePoll::Events(events)) => {
                idle_since = None;
                for e in events {
                    server.feed(e);
                }
            }
            Ok(SourcePoll::Idle) => {
                server.pump();
                let idle = idle_since.get_or_insert_with(std::time::Instant::now);
                if idle_timeout > 0.0 && idle.elapsed().as_secs_f64() >= idle_timeout {
                    println!("(idle for {idle_timeout}s — stopping)");
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            Ok(SourcePoll::End) => break,
            Err(e) => {
                eprintln!("source error: {e}");
                return 1;
            }
        }
        for j in server.drain_completed() {
            print_job(&j);
        }
        if last_snapshot.elapsed().as_secs_f64() >= snapshot_every
            && server.registry().stages_folded() > 0
        {
            last_snapshot = std::time::Instant::now();
            print!("{}", server.registry().report().render());
        }
    }

    let report = server.finish();
    for j in &report.jobs {
        print_job(j);
    }
    print!("{}", report.fleet.render());
    let m = &report.metrics;
    println!(
        "{} events, {} jobs completed ({} live evictions, {} strays dropped) in {:.3}s — \
         {:.0} events/s, {} stages analyzed ({} stats-cache hits / {} misses), \
         resident high-water {}",
        m.events_total,
        m.jobs_completed,
        m.evictions_live,
        m.events_dropped,
        started.elapsed().as_secs_f64(),
        m.events_per_sec,
        m.stages_analyzed,
        m.cache_hits,
        m.cache_misses,
        m.resident_high_water,
    );
    if args.flag("metrics") {
        let mut t = Table::new("Per-shard metrics")
            .header(&["shard", "events", "stages", "resident", "high-water", "evicted"])
            .aligns(&[
                Align::Right,
                Align::Right,
                Align::Right,
                Align::Right,
                Align::Right,
                Align::Right,
            ]);
        for s in &m.per_shard {
            t.row(vec![
                s.shard.to_string(),
                s.events.to_string(),
                s.stages.to_string(),
                s.resident.to_string(),
                s.resident_high.to_string(),
                s.evicted.to_string(),
            ]);
        }
        print!("{}", t.render());
    }
    0
}

fn cmd_verify(args: &bigroots::util::cli::Args) -> i32 {
    let reps = args.get_usize("reps", 10);
    let scale = args.get_f64("scale", 1.0);
    let seed = args.get_u64("seed", 42);
    let rows = experiments::table3(reps, scale, seed);
    let mut t = Table::new("Table III: BigRoots vs PCC (TP/FP over resource features)")
        .header(&["Experiment", "BigRoots TP", "BigRoots FP", "PCC TP", "PCC FP"])
        .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
    for (kind, m) in &rows {
        t.row(vec![
            format!("{} AG", kind.as_str()),
            m.bigroots_kind.0.to_string(),
            m.bigroots_kind.1.to_string(),
            m.pcc_kind.0.to_string(),
            m.pcc_kind.1.to_string(),
        ]);
    }
    print!("{}", t.render());
    0
}

fn cmd_multi(args: &bigroots::util::cli::Args) -> i32 {
    let m = experiments::table5(args.get_f64("scale", 1.0), args.get_u64("seed", 42));
    let mut t = Table::new("Table V: multi-node anomaly schedule (Table IV)")
        .header(&["Method", "TP", "TN", "FP", "FN", "FPR", "TPR", "ACC"])
        .aligns(&[
            Align::Left,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
        ]);
    for (name, c) in [("BigRoots", m.bigroots), ("PCC", m.pcc)] {
        t.row(vec![
            name.to_string(),
            c.tp.to_string(),
            c.tn.to_string(),
            c.fp.to_string(),
            c.fn_.to_string(),
            pct(c.fpr()),
            pct(c.tpr()),
            pct(c.acc()),
        ]);
    }
    print!("{}", t.render());
    0
}

fn cmd_hibench(args: &bigroots::util::cli::Args) -> i32 {
    let rows = experiments::table6(args.get_f64("scale", 1.0), args.get_u64("seed", 42));
    print!("{}", bigroots::analysis::report::render_table6(&rows));
    0
}

fn cmd_roc(args: &bigroots::util::cli::Args) -> i32 {
    let Some(setting) = parse_setting(&args.get_or("setting", "cpu")) else {
        eprintln!("unknown setting");
        return 2;
    };
    let r = experiments::fig8(
        setting,
        args.get_usize("reps", 5),
        args.get_f64("scale", 0.6),
        args.get_u64("seed", 42),
    );
    println!(
        "{}: BigRoots AUC {} vs PCC AUC {} ({} / {} sweep points)",
        setting.label(),
        fnum(r.bigroots_auc, 4),
        fnum(r.pcc_auc, 4),
        r.bigroots_points.len(),
        r.pcc_points.len()
    );
    0
}

fn cmd_run(args: &bigroots::util::cli::Args) -> i32 {
    let path = args.get("config").unwrap();
    let cfg = match ExperimentConfig::load(path) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config: {e:#}");
            return 1;
        }
    };
    let Some(w) = workloads::by_name(&cfg.workload, cfg.scale) else {
        eprintln!("unknown workload '{}'", cfg.workload);
        return 2;
    };
    let plan = cfg.injection.plan(cfg.seed, cfg.sim.nodes);
    let mut eng = Engine::new(cfg.sim.clone());
    let trace = eng.run(&cfg.workload, w.name, &w.stages, &plan);
    let mut pipeline = Pipeline::auto();
    pipeline.bigroots = cfg.bigroots;
    pipeline.pcc = Some(cfg.pcc);
    let analysis = pipeline.analyze(&trace, w.domain);
    println!(
        "{}: {} stragglers / {} tasks; causes: {}",
        cfg.workload,
        analysis.total_stragglers(),
        trace.tasks.len(),
        analysis
            .summary
            .causes
            .iter()
            .map(|(k, n)| format!("{}({})", k.name(), n))
            .collect::<Vec<_>>()
            .join(" ")
    );
    // Scored confusion when the plan carries ground truth.
    if !trace.injections.is_empty() {
        let mut conf = bigroots::analysis::Confusion::default();
        for (sf, a) in &analysis.per_stage {
            let gt = bigroots::analysis::ground_truth(&trace, sf, experiments::GT_COVERAGE);
            conf.add(bigroots::analysis::roc::score_filtered(a, &gt, &resource_features()));
        }
        println!(
            "vs ground truth: TP {} FP {} TN {} FN {} (FPR {} TPR {} ACC {})",
            conf.tp,
            conf.fp,
            conf.tn,
            conf.fn_,
            pct(conf.fpr()),
            pct(conf.tpr()),
            pct(conf.acc())
        );
    }
    let _ = FeatureKind::COUNT;
    0
}
