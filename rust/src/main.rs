//! `bigroots` — the command-line launcher.
//!
//! Subcommands cover the full paper workflow:
//!
//! ```text
//! bigroots simulate   — run a workload on the simulated cluster → trace.json
//! bigroots analyze    — offline root-cause analysis of a trace file
//! bigroots whatif     — counterfactual ranking: completion time saved per removed cause
//! bigroots stream     — streaming analysis of an event log (ndjson)
//! bigroots explain    — replay a flight-recorder dump, verify the verdict reproduces

//! bigroots verify     — Table III single-AG verification (BigRoots vs PCC)
//! bigroots multi      — Tables IV+V multi-node anomaly schedule
//! bigroots hibench    — Table VI case study over the 11 workloads
//! bigroots roc        — Fig. 8 threshold sweep + AUC comparison
//! bigroots run        — run a declarative experiment config (JSON)
//! ```

use bigroots::analysis::features::FeatureKind;
use bigroots::analysis::roc::resource_features;
use bigroots::coordinator::experiments::{self, AgSetting};
use bigroots::coordinator::{ExperimentConfig, Pipeline};
use bigroots::sim::{workloads, Engine};
use bigroots::trace::{codec, eventlog, AnomalyKind};
use bigroots::util::cli::Command;
use bigroots::util::table::{fnum, pct, Align, Table};

fn main() {
    let cmd = Command::new("bigroots", "root-cause analysis of stragglers in big data systems")
        .subcommand(
            Command::new("simulate", "simulate a workload, write a trace file")
                .opt("workload", "NaiveBayes", "workload name (see `hibench` for the list)")
                .opt("scale", "1.0", "task-count scale factor")
                .opt("seed", "42", "rng seed")
                .opt("inject", "none", "anomaly: none | cpu | io | network | mixed | table4")
                .opt("node", "1", "injection target node")
                .opt("out", "trace.json", "output trace path")
                .flag("events", "also write an event log next to the trace"),
        )
        .subcommand(
            Command::new("analyze", "offline analysis of a trace file")
                .opt_req("input", "trace file (from `simulate` or a converter)")
                .opt("backend", "auto", "stats backend: auto | native | xla")
                .flag("pcc", "also run the PCC baseline")
                .flag("verbose", "print every straggler with its causes"),
        )
        .subcommand(
            Command::new(
                "whatif",
                "counterfactual what-if: rank detected causes by estimated completion-time saved",
            )
            .opt("input", "", "trace file to analyze (omit to simulate --workload instead)")
            .opt("workload", "NaiveBayes", "workload to simulate when no --input is given")
            .opt("scale", "1.0", "task-count scale factor (simulated trace)")
            .opt("seed", "42", "rng seed (simulated trace)")
            .opt("inject", "cpu", "anomaly for the simulated trace: none | cpu | io | network")
            .opt("node", "1", "injection target node (simulated trace)")
            .opt("backend", "auto", "stats backend: auto | native | xla")
            .opt(
                "snapshot",
                "",
                "fleet-baseline snapshot (from `serve --snapshot-path`) supplying \
                 fleet-median neutralization targets",
            ),
        )
        .subcommand(
            Command::new("stream", "streaming analysis of an ndjson event log")
                .opt_req("input", "event log path"),
        )
        .subcommand(
            Command::new(
                "explain",
                "replay a flight-recorder dump offline and verify the recorded verdict \
                 reproduces bit-identically",
            )
            .opt_req(
                "replay",
                "flight dump NDJSON path (written by `explain <id> dump <path>` on the \
                 serve control socket)",
            )
            .flag("verbose", "print the full provenance document, not just the verdict line"),
        )
        .subcommand(
            Command::new("serve", "long-running multi-tenant analysis server (live/ subsystem)")
                .opt("tail", "", "follow a growing job-tagged ndjson event log (live mode)")
                .opt("listen", "", "accept line-delimited events over TCP, e.g. 127.0.0.1:7070")
                .flag("stdin", "read the event stream from stdin (live mode)")
                .opt("input", "", "replay a job-tagged ndjson event log (omit to simulate --jobs)")
                .opt("jobs", "8", "jobs to simulate when no input/tail/listen is given")
                .opt("scale", "0.3", "workload scale for simulated jobs")
                .opt("seed", "42", "base seed for simulated jobs")
                .opt("shards", "4", "shard worker threads (parallel demux + analysis)")
                .opt("queue-cap", "8", "per-shard queue capacity in batches (backpressure bound)")
                .opt("ingest-batch", "64", "events per shard-queue send")
                .opt("evict-after", "5", "event-time quiescence (s) after job_end before eviction")
                .opt("stats-cache", "256", "shared stage-stats cache capacity (0 disables)")
                .opt("cache-stripes", "8", "lock stripes in the shared stage-stats cache")
                .opt("route-large", "0", "route stages with >= this many tasks to the large-stage backend (0 = native only)")
                .opt("snapshot-every", "5", "seconds between fleet-baseline snapshots (live mode)")
                .opt(
                    "control-port",
                    "",
                    "line-delimited JSON control/query socket (fleet-report | jobs [filters] | \
                     job <id> | explain <id> [dump <path>] | what-if <id> | metrics | \
                     metrics-prom | self-report | snapshot | shutdown), e.g. 127.0.0.1:7172",
                )
                .opt(
                    "flight-capacity",
                    "16384",
                    "per-shard flight-recorder ring capacity in raw events (0 disables \
                     verdict window capture)",
                )
                .opt(
                    "metrics-port",
                    "",
                    "HTTP endpoint serving the Prometheus text exposition (scrape with \
                     curl or a Prometheus server), e.g. 127.0.0.1:9191",
                )
                .opt("log-level", "info", "diagnostics level: error | warn | info | debug | trace")
                .flag("log-json", "emit diagnostics as NDJSON lines instead of human-readable")
                .flag(
                    "self-analyze",
                    "feed the server's own per-shard batch timings through BigRoots and \
                     print which shard/phase is the straggler on the snapshot cadence",
                )
                .flag("no-obs", "disable span recording (overhead-measurement baseline)")
                .opt(
                    "snapshot-path",
                    "",
                    "fleet-baseline snapshot file: restored on boot if present, written on \
                     the snapshot cadence and at shutdown (atomic rename)",
                )
                .opt(
                    "idle-timeout",
                    "10",
                    "stop after this many idle seconds (0 = run forever; with --listen, \
                     also keeps the socket open across client generations)",
                )
                .flag("metrics", "print per-shard metrics"),
        )
        .subcommand(
            Command::new("verify", "Table III: single-AG verification vs PCC")
                .opt("reps", "10", "repetitions per AG kind")
                .opt("scale", "1.0", "workload scale")
                .opt("seed", "42", "base seed"),
        )
        .subcommand(
            Command::new("multi", "Tables IV+V: multi-node anomaly schedule")
                .opt("scale", "1.0", "workload scale")
                .opt("seed", "42", "seed"),
        )
        .subcommand(
            Command::new("hibench", "Table VI: the 11-workload case study")
                .opt("scale", "1.0", "workload scale")
                .opt("seed", "42", "seed"),
        )
        .subcommand(
            Command::new("roc", "Fig. 8: ROC sweep + AUC, BigRoots vs PCC")
                .opt("setting", "cpu", "cpu | io | network | mixed")
                .opt("reps", "5", "repetitions")
                .opt("scale", "0.6", "workload scale")
                .opt("seed", "42", "base seed"),
        )
        .subcommand(
            Command::new("run", "run a declarative experiment config")
                .opt_req("config", "JSON config path (see coordinator::config)"),
        );

    let (sub, args) = cmd.parse_env();
    let code = match sub.as_str() {
        "simulate" => cmd_simulate(&args),
        "analyze" => cmd_analyze(&args),
        "whatif" => cmd_whatif(&args),
        "stream" => cmd_stream(&args),
        "explain" => cmd_explain(&args),
        "serve" => cmd_serve(&args),
        "verify" => cmd_verify(&args),
        "multi" => cmd_multi(&args),
        "hibench" => cmd_hibench(&args),
        "roc" => cmd_roc(&args),
        "run" => cmd_run(&args),
        _ => unreachable!(),
    };
    std::process::exit(code);
}

fn parse_setting(s: &str) -> Option<AgSetting> {
    Some(match s.to_ascii_lowercase().as_str() {
        "none" => AgSetting::None,
        "cpu" => AgSetting::Single(AnomalyKind::Cpu),
        "io" => AgSetting::Single(AnomalyKind::Io),
        "network" | "net" => AgSetting::Single(AnomalyKind::Network),
        "mixed" => AgSetting::Mixed,
        _ => return None,
    })
}

fn cmd_simulate(args: &bigroots::util::cli::Args) -> i32 {
    let name = args.get_or("workload", "NaiveBayes");
    let scale = args.get_f64("scale", 1.0);
    let seed = args.get_u64("seed", 42);
    let Some(w) = workloads::by_name(&name, scale) else {
        eprintln!("unknown workload '{name}'");
        return 2;
    };
    let inject = args.get_or("inject", "none");
    let node = args.get_usize("node", 1);
    let horizon = 400.0 * scale.max(0.25);
    let plan = match inject.as_str() {
        "none" => bigroots::sim::InjectionPlan::none(),
        "cpu" => bigroots::sim::InjectionPlan::intermittent(AnomalyKind::Cpu, node, 15.0, 10.0, horizon),
        "io" => bigroots::sim::InjectionPlan::intermittent(AnomalyKind::Io, node, 15.0, 10.0, horizon),
        "network" | "net" => {
            bigroots::sim::InjectionPlan::intermittent(AnomalyKind::Network, node, 15.0, 10.0, horizon)
        }
        "mixed" => {
            let mut rng = bigroots::util::rng::Pcg64::seeded(seed ^ 0xA6);
            bigroots::sim::InjectionPlan::mixed(&mut rng, node, 15.0, 10.0, horizon)
        }
        "table4" => bigroots::sim::InjectionPlan::table4(|s| s - 1),
        other => {
            eprintln!("unknown injection '{other}'");
            return 2;
        }
    };
    let mut eng = Engine::new(bigroots::sim::SimConfig { seed, ..Default::default() });
    let trace = eng.run(&format!("{name}-{inject}"), w.name, &w.stages, &plan);
    let out = args.get_or("out", "trace.json");
    if let Err(e) = codec::save(&trace, &out) {
        eprintln!("write failed: {e:#}");
        return 1;
    }
    println!(
        "wrote {out}: {} tasks, {} stages, makespan {:.1}s, {} injections",
        trace.tasks.len(),
        trace.stages.len(),
        trace.makespan(),
        trace.injections.len()
    );
    if args.flag("events") {
        let epath = format!("{out}.events.ndjson");
        let events = eventlog::trace_to_events(&trace);
        if let Err(e) = eventlog::write_events(&events, &epath) {
            eprintln!("event log write failed: {e:#}");
            return 1;
        }
        println!("wrote {epath}: {} events", events.len());
    }
    0
}

fn make_pipeline(backend: &str) -> Result<Pipeline, String> {
    match backend {
        "auto" => Ok(Pipeline::auto()),
        "native" => Ok(Pipeline::native()),
        "xla" => {
            let dir = bigroots::runtime::XlaBackend::default_dir();
            let b = bigroots::runtime::XlaBackend::open(&dir)
                .map_err(|e| format!("XLA backend: {e:#}"))?;
            Ok(Pipeline::new(Box::new(b)))
        }
        other => Err(format!("unknown backend '{other}'")),
    }
}

fn cmd_analyze(args: &bigroots::util::cli::Args) -> i32 {
    let input = args.get("input").unwrap();
    let trace = match codec::load(input) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("loading {input}: {e:#}");
            return 1;
        }
    };
    let mut pipeline = match make_pipeline(&args.get_or("backend", "auto")) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if !args.flag("pcc") {
        pipeline.pcc = None;
    }
    let analysis = pipeline.analyze(&trace, "-");
    println!(
        "{} [{}] — {} tasks, {} stages, backend {}",
        trace.job_name,
        trace.workload,
        trace.tasks.len(),
        trace.stages.len(),
        pipeline.backend.name()
    );
    let mut t = Table::new("Per-stage summary")
        .header(&["stage", "tasks", "median (s)", "stragglers", "causes"])
        .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Left]);
    for (sf, a) in &analysis.per_stage {
        let hist = a
            .cause_histogram()
            .iter()
            .map(|(k, n)| format!("{}({})", k.name(), n))
            .collect::<Vec<_>>()
            .join(" ");
        t.row(vec![
            format!("{}", sf.stage_id),
            format!("{}", sf.num_tasks()),
            fnum(a.stragglers.median, 2),
            format!("{}", a.stragglers.rows.len()),
            if hist.is_empty() { "-".into() } else { hist },
        ]);
    }
    print!("{}", t.render());
    if args.flag("verbose") {
        for ann in &analysis.annotations {
            let causes: Vec<&str> = ann.causes.iter().map(|k| k.name()).collect();
            println!(
                "straggler task {} (stage {}, node {}) [{:.1}s..{:.1}s] scale {:.2}x → {}",
                ann.task_id,
                ann.stage_id,
                ann.node,
                ann.start,
                ann.finish,
                ann.scale,
                if causes.is_empty() { "unexplained".to_string() } else { causes.join(", ") }
            );
        }
    }
    if args.flag("pcc") {
        let pcc_causes: usize = analysis.pcc_per_stage.iter().map(|a| a.causes.len()).sum();
        println!("PCC baseline: {pcc_causes} causes (vs BigRoots {})", analysis.total_causes());
    }
    0
}

fn cmd_whatif(args: &bigroots::util::cli::Args) -> i32 {
    use bigroots::analysis::whatif::{self, WhatIfConfig};

    let input = args.get_or("input", "");
    let trace = if input.is_empty() {
        let name = args.get_or("workload", "NaiveBayes");
        let scale = args.get_f64("scale", 1.0);
        let seed = args.get_u64("seed", 42);
        let Some(w) = workloads::by_name(&name, scale) else {
            eprintln!("unknown workload '{name}'");
            return 2;
        };
        let inject = args.get_or("inject", "cpu");
        let node = args.get_usize("node", 1);
        let horizon = 400.0 * scale.max(0.25);
        let plan = match inject.as_str() {
            "none" => bigroots::sim::InjectionPlan::none(),
            "cpu" => bigroots::sim::InjectionPlan::intermittent(AnomalyKind::Cpu, node, 15.0, 10.0, horizon),
            "io" => bigroots::sim::InjectionPlan::intermittent(AnomalyKind::Io, node, 15.0, 10.0, horizon),
            "network" | "net" => {
                bigroots::sim::InjectionPlan::intermittent(AnomalyKind::Network, node, 15.0, 10.0, horizon)
            }
            other => {
                eprintln!("unknown injection '{other}'");
                return 2;
            }
        };
        let mut eng = Engine::new(bigroots::sim::SimConfig { seed, ..Default::default() });
        eng.run(&format!("{name}-{inject}"), w.name, &w.stages, &plan)
    } else {
        match codec::load(&input) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("loading {input}: {e:#}");
                return 1;
            }
        }
    };
    let mut pipeline = match make_pipeline(&args.get_or("backend", "auto")) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    pipeline.pcc = None;
    let analysis = pipeline.analyze(&trace, "-");
    // Optional fleet baseline for the neutralization targets: the same
    // snapshot file `serve --snapshot-path` writes.
    let snapshot = args.get_or("snapshot", "");
    let fleet = if snapshot.is_empty() {
        None
    } else {
        match bigroots::live::persist::load_snapshot(&snapshot) {
            Ok(reg) => Some(reg.report()),
            Err(e) => {
                eprintln!("loading snapshot {snapshot}: {e}");
                return 1;
            }
        }
    };
    let cfg = WhatIfConfig { seed: args.get_u64("seed", 42), ..Default::default() };
    let report = whatif::analyze_trace(&trace, &analysis.per_stage, fleet.as_ref(), &cfg);
    print!("{}", report.render());
    0
}

fn cmd_stream(args: &bigroots::util::cli::Args) -> i32 {
    let input = args.get("input").unwrap();
    let text = match std::fs::read_to_string(input) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("reading {input}: {e}");
            return 1;
        }
    };
    match bigroots::coordinator::streaming::analyze_stream_threaded(
        text,
        Box::new(bigroots::analysis::stats::NativeBackend::new()),
        Default::default(),
    ) {
        Ok(an) => {
            println!("consumed {} events, analyzed {} stages", an.events_seen, an.results.len());
            for a in &an.results {
                println!(
                    "stage {}: {} stragglers, {} causes",
                    a.stage_id,
                    a.stragglers.rows.len(),
                    a.causes.len()
                );
            }
            let inc = an.incomplete_stages();
            if !inc.is_empty() {
                println!("incomplete stages at stream end: {inc:?}");
            }
            0
        }
        Err(e) => {
            eprintln!("stream error: {e}");
            1
        }
    }
}

/// `bigroots explain --replay <dump>` — the offline half of the verdict
/// provenance loop: parse a flight-recorder dump, re-run the full
/// pipeline over the frozen raw events under the frozen config and fleet
/// baselines, and require the reproduced verdict to match the recorded
/// one byte for byte.
fn cmd_explain(args: &bigroots::util::cli::Args) -> i32 {
    use bigroots::analysis::explain::FlightDump;

    let path = args.get("replay").unwrap();
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("reading {path}: {e}");
            return 1;
        }
    };
    let dump = match FlightDump::parse(&text) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("parsing {path}: {e}");
            return 1;
        }
    };
    if !dump.complete {
        eprintln!(
            "warning: dump window is incomplete (ring evicted events before the verdict \
             froze it); replay may not reproduce the recorded verdict"
        );
    }
    let replayed = match dump.replay() {
        Ok(v) => v,
        Err(e) => {
            eprintln!("replay failed: {e}");
            return 1;
        }
    };
    let recorded = dump.verdict.to_string();
    let reproduced = replayed.to_string();
    if args.flag("verbose") {
        print!("{}", bigroots::analysis::report::render_explain(&replayed));
        println!("{reproduced}");
    }
    println!(
        "job {} incarnation {}: {} events, {} stages in verdict",
        dump.job_id,
        dump.incarnation,
        dump.events.len(),
        replayed.get("stages").as_arr().map(|a| a.len()).unwrap_or(0),
    );
    if recorded == reproduced {
        println!("replay verdict matches the recorded verdict bit-identically");
        0
    } else {
        eprintln!("REPLAY MISMATCH");
        eprintln!("recorded:   {recorded}");
        eprintln!("reproduced: {reproduced}");
        1
    }
}

fn cmd_serve(args: &bigroots::util::cli::Args) -> i32 {
    use bigroots::live::control::{self, ControlCommand, ControlServer};
    use bigroots::live::{
        persist, CompletedJob, EventSource, LifecycleConfig, LiveConfig, LiveServer,
        MemorySource, SourcePoll, StdinSource, TailSource, TcpSource,
    };
    use bigroots::obs;
    use bigroots::sim::multi;
    use bigroots::trace::eventlog::parse_tagged_events;
    use bigroots::util::json::Json;

    if let Err(e) = obs::log::set_level_str(&args.get_or("log-level", "info")) {
        eprintln!("{e}");
        return 2;
    }
    obs::log::set_json(args.flag("log-json"));
    // The span recorder is on for every serve run unless the operator asks
    // for the uninstrumented baseline; nothing else in the binary enables
    // it, so offline analysis stays at the one-atomic-load disabled cost.
    obs::set_enabled(!args.flag("no-obs"));
    let self_analyze = args.flag("self-analyze");

    let cfg = LiveConfig {
        shards: args.get_usize("shards", 4),
        queue_capacity: args.get_usize("queue-cap", 8),
        ingest_batch: args.get_usize("ingest-batch", 64),
        lifecycle: LifecycleConfig {
            evict_after: args.get_f64("evict-after", 5.0),
            ..Default::default()
        },
        stats_cache_capacity: args.get_usize("stats-cache", 256),
        stats_cache_stripes: args.get_usize("cache-stripes", 8),
        route_large_tasks: args.get_usize("route-large", 0),
        flight_capacity: args.get_usize("flight-capacity", 16384),
        ..Default::default()
    };
    // The flight dump freezes the analyzer config the verdict ran under;
    // keep a copy before the server takes ownership.
    let analyzer_cfg = cfg.bigroots;

    // Pick the transport: tail / listen / stdin are live; --input replays
    // a file; with none of those, simulate an interleaved multi-job run.
    let tail = args.get_or("tail", "");
    let listen = args.get_or("listen", "");
    let mut source: Box<dyn EventSource> = if !tail.is_empty() {
        Box::new(TailSource::new(&tail))
    } else if !listen.is_empty() {
        // --idle-timeout 0 means "run forever": keep the socket open
        // across client generations instead of ending after the last
        // client disconnects.
        let bound = if args.get_f64("idle-timeout", 10.0) == 0.0 {
            TcpSource::bind_persistent(&listen)
        } else {
            TcpSource::bind(&listen)
        };
        match bound {
            Ok(s) => {
                println!("listening on {}", s.local_addr());
                Box::new(s)
            }
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        }
    } else if args.flag("stdin") {
        Box::new(StdinSource::new())
    } else {
        let input = args.get_or("input", "");
        let events = if input.is_empty() {
            let n = args.get_usize("jobs", 8);
            let scale = args.get_f64("scale", 0.3);
            let seed = args.get_u64("seed", 42);
            println!("simulating {n} jobs (scale {scale}, seed {seed})…");
            let specs = multi::round_robin_specs(n, scale, seed);
            let (_, events) = multi::interleaved_workload(&specs);
            events
        } else {
            let text = match std::fs::read_to_string(&input) {
                Ok(t) => t,
                Err(e) => {
                    eprintln!("reading {input}: {e}");
                    return 1;
                }
            };
            match parse_tagged_events(&text) {
                Ok(ev) => ev,
                Err(e) => {
                    eprintln!("parsing {input}: {e}");
                    return 1;
                }
            }
        };
        Box::new(MemorySource::new(events, 1024))
    };

    println!("serving from {} over {} shards", source.describe(), cfg.shards);
    let snapshot_every = args.get_f64("snapshot-every", 5.0).max(0.1);
    let idle_timeout = args.get_f64("idle-timeout", 10.0);
    let snapshot_path = args.get_or("snapshot-path", "");
    let control_addr = args.get_or("control-port", "");
    let mut control = if control_addr.is_empty() {
        None
    } else {
        match ControlServer::bind(&control_addr) {
            Ok(c) => {
                println!("control socket on {}", c.local_addr());
                Some(c)
            }
            Err(e) => {
                eprintln!("{e}");
                return 1;
            }
        }
    };
    let metrics_addr = args.get_or("metrics-port", "");
    let mut metrics_http = if metrics_addr.is_empty() {
        None
    } else {
        match obs::MetricsServer::bind(&metrics_addr) {
            Ok(s) => {
                match s.local_addr() {
                    Ok(a) => println!("metrics endpoint on http://{a}/metrics"),
                    Err(_) => println!("metrics endpoint on http://{metrics_addr}/metrics"),
                }
                Some(s)
            }
            Err(e) => {
                eprintln!("metrics bind {metrics_addr}: {e}");
                return 1;
            }
        }
    };
    let mut server = LiveServer::new(cfg);

    // Restore the fleet baseline from the last shutdown's snapshot: the
    // cross-job history the registry's verdicts depend on survives the
    // restart.
    if !snapshot_path.is_empty() && std::path::Path::new(&snapshot_path).exists() {
        match persist::load_snapshot(&snapshot_path) {
            Ok(reg) => {
                println!(
                    "restored fleet baseline from {snapshot_path}: {} stages folded",
                    reg.stages_folded()
                );
                server.restore_registry(reg);
            }
            Err(e) => obs::log::warn(
                "serve",
                &format!("snapshot restore failed ({e}); starting with a fresh baseline"),
            ),
        }
    }

    let print_job = |j: &CompletedJob| {
        let stragglers: usize = j.analyses.iter().map(|a| a.stragglers.rows.len()).sum();
        let causes: usize = j.analyses.iter().map(|a| a.causes.len()).sum();
        let best_fix = j
            .whatif
            .as_ref()
            .and_then(|w| w.top())
            .filter(|top| top.saved_secs > 0.0)
            .map(|top| {
                format!(
                    " — best fix: {} (est. {:.1}s saved)",
                    top.kind.name(),
                    top.saved_secs
                )
            })
            .unwrap_or_default();
        println!(
            "job {}{}: {} stages, {} stragglers, {} causes, {} fleet flags{}{}{}",
            j.job_id,
            if j.incarnation > 0 { format!(" (incarnation {})", j.incarnation) } else { String::new() },
            j.analyses.len(),
            stragglers,
            causes,
            j.fleet_flags.len(),
            if j.evicted_live { " [evicted]" } else { "" },
            if j.incomplete.is_empty() {
                String::new()
            } else {
                format!(" — incomplete stages {:?}", j.incomplete)
            },
            best_fix,
        );
    };

    let started = std::time::Instant::now();
    let mut last_snapshot = std::time::Instant::now();
    let mut idle_since: Option<std::time::Instant> = None;
    // Latest summary per retired job id, for the control plane's `job`
    // and `jobs` verbs (retired jobs are drained out of the server as
    // they complete). A BTreeMap so the `jobs` keyset cursor can resume
    // in id order. Bounded like everything else on the unbounded-stream
    // path: oldest retirements age out once the cap is hit.
    const MAX_JOB_SUMMARIES: usize = 4096;
    let mut job_summaries: std::collections::BTreeMap<u64, Json> =
        std::collections::BTreeMap::new();
    // The full what-if verdict per retired job, for the `what-if <id>`
    // verb. Same bound and age-out as the summaries.
    let mut job_whatifs: std::collections::HashMap<u64, Json> =
        std::collections::HashMap::new();
    // The verdict provenance document per retired job (`explain <id>`).
    let mut job_explains: std::collections::HashMap<u64, Json> =
        std::collections::HashMap::new();
    let mut job_summary_order: std::collections::VecDeque<u64> =
        std::collections::VecDeque::new();
    // Frozen flight windows are raw event buffers — orders of magnitude
    // heavier than a summary line — so they get their own, much smaller
    // retention window for `explain <id> dump <path>`.
    const MAX_JOB_DUMPS: usize = 64;
    let mut job_dumps: std::collections::HashMap<u64, bigroots::analysis::explain::FlightDump> =
        std::collections::HashMap::new();
    let mut job_dump_order: std::collections::VecDeque<u64> =
        std::collections::VecDeque::new();
    // Retirement wall-clock (unix seconds) stamped onto each summary for
    // the `jobs since=/until=` filters.
    let unix_now = || {
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0)
    };
    let mut shutdown_requested = false;
    // Non-zero when the source died — the drain-then-snapshot exit still
    // runs (losing the registry on a disk error would defeat the point of
    // persistence), but the process reports the failure.
    let mut exit_code = 0;
    // stages_folded at the last periodic snapshot write; restored state
    // counts, so an idle rebooted server doesn't rewrite the same file.
    let mut last_snapshot_stages = server.registry().stages_folded();
    let write_snapshot = |server: &LiveServer, path: &str| -> Result<usize, String> {
        let _g = obs::span(obs::SpanKind::SnapshotWrite);
        let reg = server.registry();
        persist::save_snapshot(reg, path).map(|()| reg.stages_folded())
    };
    loop {
        let poll_span = obs::span(obs::SpanKind::SourcePoll);
        let polled = source.poll();
        poll_span.finish();
        match polled {
            Ok(SourcePoll::Events(events)) => {
                idle_since = None;
                for e in events {
                    server.feed(e);
                }
            }
            Ok(SourcePoll::Idle) => {
                server.pump();
                let idle = idle_since.get_or_insert_with(std::time::Instant::now);
                if idle_timeout > 0.0 && idle.elapsed().as_secs_f64() >= idle_timeout {
                    println!("(idle for {idle_timeout}s — stopping)");
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
            Ok(SourcePoll::End) => break,
            Err(e) => {
                obs::log::error(
                    "serve",
                    &format!("source error: {e} — draining and snapshotting before exit"),
                );
                exit_code = 1;
                break;
            }
        }
        server.record_source_stats(source.dropped_partial_lines(), source.parse_errors());
        for j in server.drain_completed() {
            let mut summary = control::job_summary_json(&j);
            summary.set("retired_at", unix_now().into());
            // A refreshed id (revived incarnation) moves to the back of
            // the age queue, so the newest summary is the last to go.
            if job_summaries.insert(j.job_id, summary).is_some() {
                if let Some(pos) = job_summary_order.iter().position(|&id| id == j.job_id) {
                    job_summary_order.remove(pos);
                }
            }
            match &j.whatif {
                Some(w) => {
                    job_whatifs.insert(j.job_id, w.to_json());
                }
                None => {
                    // A revived incarnation with no analyzed stages must
                    // not serve the previous incarnation's verdict.
                    job_whatifs.remove(&j.job_id);
                }
            }
            // Same revival rule for the provenance document and the
            // flight dump: a fresh incarnation supersedes or clears.
            match control::explain_json(&j) {
                Ok(doc) => {
                    job_explains.insert(j.job_id, doc);
                }
                Err(_) => {
                    job_explains.remove(&j.job_id);
                }
            }
            match control::flight_dump(&j, &analyzer_cfg) {
                Ok(dump) => {
                    if job_dumps.insert(j.job_id, dump).is_some() {
                        if let Some(pos) = job_dump_order.iter().position(|&id| id == j.job_id)
                        {
                            job_dump_order.remove(pos);
                        }
                    }
                    job_dump_order.push_back(j.job_id);
                    while job_dump_order.len() > MAX_JOB_DUMPS {
                        if let Some(old) = job_dump_order.pop_front() {
                            job_dumps.remove(&old);
                        }
                    }
                }
                Err(_) => {
                    job_dumps.remove(&j.job_id);
                    if let Some(pos) = job_dump_order.iter().position(|&id| id == j.job_id) {
                        job_dump_order.remove(pos);
                    }
                }
            }
            job_summary_order.push_back(j.job_id);
            while job_summary_order.len() > MAX_JOB_SUMMARIES {
                if let Some(old) = job_summary_order.pop_front() {
                    job_summaries.remove(&old);
                    job_whatifs.remove(&old);
                    job_explains.remove(&old);
                    job_dumps.remove(&old);
                    if let Some(pos) = job_dump_order.iter().position(|&id| id == old) {
                        job_dump_order.remove(pos);
                    }
                }
            }
            print_job(&j);
        }
        // Control plane: answer operator queries on the same driver
        // thread, in request order.
        if let Some(ctrl) = control.as_mut() {
            let requests = match ctrl.poll() {
                Ok(r) => r,
                Err(e) => {
                    obs::log::error("live.control", &format!("control error: {e}"));
                    Vec::new()
                }
            };
            for req in requests {
                let req_span = obs::span(obs::SpanKind::Control);
                let resp = match &req.command {
                    ControlCommand::FleetReport => control::ok_response(
                        "fleet-report",
                        control::fleet_report_json(&control::fleet_report(&server)),
                    ),
                    ControlCommand::Metrics => control::ok_response(
                        "metrics",
                        control::live_metrics_json(&server.metrics()),
                    ),
                    // The exposition text rides inside the JSON envelope so
                    // the one-line-per-response protocol holds; operators
                    // wanting plain text scrape --metrics-port instead.
                    ControlCommand::MetricsProm => control::ok_response(
                        "metrics-prom",
                        Json::from_pairs(vec![(
                            "text",
                            obs::prom::render(
                                obs::global(),
                                Some(&server.metrics()),
                                Some(&control::fleet_report(&server)),
                            )
                            .into(),
                        )]),
                    ),
                    ControlCommand::SelfReport => {
                        match obs::selfmon::analyze(&obs::telemetry().samples()) {
                            Some(r) => control::ok_response("self-report", r.to_json()),
                            None => control::err_response(
                                "self-analysis needs more batch samples (keep the stream \
                                 flowing and retry)",
                            ),
                        }
                    }
                    ControlCommand::Job(id) => match job_summaries.get(id) {
                        Some(j) => control::ok_response("job", j.clone()),
                        None => control::err_response(&format!("job {id} has not retired")),
                    },
                    ControlCommand::Jobs(q) => {
                        control::ok_response("jobs", control::jobs_page(&job_summaries, q))
                    }
                    ControlCommand::Explain(id) => match job_explains.get(id) {
                        Some(doc) => control::ok_response("explain", doc.clone()),
                        None if job_summaries.contains_key(id) => control::err_response(
                            &format!("job {id} retired with no analyzed stages"),
                        ),
                        None => control::err_response(&format!("job {id} has not retired")),
                    },
                    ControlCommand::ExplainDump(id, path) => match job_dumps.get(id) {
                        Some(dump) => match std::fs::write(path, dump.encode_ndjson()) {
                            Ok(()) => control::ok_response(
                                "explain-dump",
                                Json::from_pairs(vec![
                                    ("path", path.as_str().into()),
                                    ("job_id", id.to_string().into()),
                                    ("events", dump.events.len().into()),
                                    ("complete", dump.complete.into()),
                                ]),
                            ),
                            Err(e) => control::err_response(&format!("writing {path}: {e}")),
                        },
                        None if job_summaries.contains_key(id) => control::err_response(
                            &format!(
                                "job {id} has no flight window (no straggler verdict fired, \
                                 or the dump aged out)"
                            ),
                        ),
                        None => control::err_response(&format!("job {id} has not retired")),
                    },
                    ControlCommand::WhatIf(id) => match job_whatifs.get(id) {
                        Some(w) => control::ok_response("what-if", w.clone()),
                        None if job_summaries.contains_key(id) => control::err_response(
                            &format!("job {id} retired with no analyzed stages"),
                        ),
                        None => control::err_response(&format!("job {id} has not retired")),
                    },
                    ControlCommand::Snapshot => {
                        if snapshot_path.is_empty() {
                            control::err_response("no --snapshot-path configured")
                        } else {
                            match write_snapshot(&server, &snapshot_path) {
                                Ok(stages) => {
                                    // The cadence guard sees this write.
                                    last_snapshot_stages = stages;
                                    control::ok_response(
                                        "snapshot",
                                        Json::from_pairs(vec![
                                            ("path", snapshot_path.as_str().into()),
                                            ("stages", stages.into()),
                                        ]),
                                    )
                                }
                                Err(e) => control::err_response(&e),
                            }
                        }
                    }
                    ControlCommand::Shutdown => {
                        shutdown_requested = true;
                        control::ok_response("shutdown", Json::obj())
                    }
                    ControlCommand::Invalid(msg) => control::err_response(msg),
                };
                ctrl.respond(&req, &resp);
                req_span.finish();
            }
        }
        // Scrape endpoint: render on demand, never block the driver.
        if let Some(ms) = metrics_http.as_mut() {
            ms.poll(|| {
                obs::prom::render(
                    obs::global(),
                    Some(&server.metrics()),
                    Some(&control::fleet_report(&server)),
                )
            });
        }
        if shutdown_requested {
            println!("(shutdown requested via control socket — draining)");
            break;
        }
        if last_snapshot.elapsed().as_secs_f64() >= snapshot_every
            && server.registry().stages_folded() > 0
        {
            last_snapshot = std::time::Instant::now();
            print!("{}", control::fleet_report_text(&server));
            if self_analyze {
                match obs::selfmon::analyze(&obs::telemetry().samples()) {
                    Some(r) => print!("{}", r.render()),
                    None => println!("self-analysis: warming up (not enough batch samples yet)"),
                }
            }
            // Skip the file write when nothing folded since the last one
            // — an idle restored server must not churn the disk forever.
            let folded = server.registry().stages_folded();
            if !snapshot_path.is_empty() && folded != last_snapshot_stages {
                match write_snapshot(&server, &snapshot_path) {
                    Ok(_) => last_snapshot_stages = folded,
                    Err(e) => obs::log::warn("serve", &format!("snapshot write failed: {e}")),
                }
            }
        }
    }

    // Get any queued control responses (the shutdown ack in particular)
    // onto the wire before draining — respond() never blocks, so a
    // WouldBlock leftover would otherwise die with the process.
    if let Some(ctrl) = control.as_mut() {
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        while ctrl.pending_responses() > 0 && std::time::Instant::now() < deadline {
            ctrl.flush();
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        ctrl.flush();
    }

    // Drain-then-snapshot exit: retire every resident job, then persist
    // the final baseline so the next boot resumes from it.
    server.record_source_stats(source.dropped_partial_lines(), source.parse_errors());
    let (report, registry) = server.finish_with_registry();
    if !snapshot_path.is_empty() {
        match persist::save_snapshot(&registry, &snapshot_path) {
            Ok(()) => println!(
                "wrote fleet snapshot {snapshot_path} ({} stages folded)",
                registry.stages_folded()
            ),
            Err(e) => obs::log::error("serve", &format!("final snapshot write failed: {e}")),
        }
    }
    for j in &report.jobs {
        print_job(j);
    }
    print!("{}", report.fleet.render());
    let m = &report.metrics;
    println!(
        "{} events, {} jobs completed ({} live evictions, {} strays dropped, \
         {} partial lines dropped) in {:.3}s — {:.0} events/s, {} stages analyzed \
         ({} stats-cache hits / {} misses), resident high-water {}",
        m.events_total,
        m.jobs_completed,
        m.evictions_live,
        m.events_dropped,
        m.dropped_partial_lines,
        started.elapsed().as_secs_f64(),
        m.events_per_sec,
        m.stages_analyzed,
        m.cache_hits,
        m.cache_misses,
        m.resident_high_water,
    );
    if self_analyze {
        match obs::selfmon::analyze(&obs::telemetry().samples()) {
            Some(r) => print!("{}", r.render()),
            None => println!(
                "self-analysis: not enough batch samples ({} recorded) — \
                 a longer run is needed for a verdict",
                obs::telemetry().total_recorded()
            ),
        }
    }
    if args.flag("metrics") {
        let mut t = Table::new("Per-shard metrics")
            .header(&["shard", "events", "stages", "resident", "high-water", "evicted"])
            .aligns(&[
                Align::Right,
                Align::Right,
                Align::Right,
                Align::Right,
                Align::Right,
                Align::Right,
            ]);
        for s in &m.per_shard {
            t.row(vec![
                s.shard.to_string(),
                s.events.to_string(),
                s.stages.to_string(),
                s.resident.to_string(),
                s.resident_high.to_string(),
                s.evicted.to_string(),
            ]);
        }
        print!("{}", t.render());
    }
    exit_code
}

fn cmd_verify(args: &bigroots::util::cli::Args) -> i32 {
    let reps = args.get_usize("reps", 10);
    let scale = args.get_f64("scale", 1.0);
    let seed = args.get_u64("seed", 42);
    let rows = experiments::table3(reps, scale, seed);
    let mut t = Table::new("Table III: BigRoots vs PCC (TP/FP over resource features)")
        .header(&["Experiment", "BigRoots TP", "BigRoots FP", "PCC TP", "PCC FP"])
        .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
    for (kind, m) in &rows {
        t.row(vec![
            format!("{} AG", kind.as_str()),
            m.bigroots_kind.0.to_string(),
            m.bigroots_kind.1.to_string(),
            m.pcc_kind.0.to_string(),
            m.pcc_kind.1.to_string(),
        ]);
    }
    print!("{}", t.render());
    0
}

fn cmd_multi(args: &bigroots::util::cli::Args) -> i32 {
    let m = experiments::table5(args.get_f64("scale", 1.0), args.get_u64("seed", 42));
    let mut t = Table::new("Table V: multi-node anomaly schedule (Table IV)")
        .header(&["Method", "TP", "TN", "FP", "FN", "FPR", "TPR", "ACC"])
        .aligns(&[
            Align::Left,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
        ]);
    for (name, c) in [("BigRoots", m.bigroots), ("PCC", m.pcc)] {
        t.row(vec![
            name.to_string(),
            c.tp.to_string(),
            c.tn.to_string(),
            c.fp.to_string(),
            c.fn_.to_string(),
            pct(c.fpr()),
            pct(c.tpr()),
            pct(c.acc()),
        ]);
    }
    print!("{}", t.render());
    0
}

fn cmd_hibench(args: &bigroots::util::cli::Args) -> i32 {
    let rows = experiments::table6(args.get_f64("scale", 1.0), args.get_u64("seed", 42));
    print!("{}", bigroots::analysis::report::render_table6(&rows));
    0
}

fn cmd_roc(args: &bigroots::util::cli::Args) -> i32 {
    let Some(setting) = parse_setting(&args.get_or("setting", "cpu")) else {
        eprintln!("unknown setting");
        return 2;
    };
    let r = experiments::fig8(
        setting,
        args.get_usize("reps", 5),
        args.get_f64("scale", 0.6),
        args.get_u64("seed", 42),
    );
    println!(
        "{}: BigRoots AUC {} vs PCC AUC {} ({} / {} sweep points)",
        setting.label(),
        fnum(r.bigroots_auc, 4),
        fnum(r.pcc_auc, 4),
        r.bigroots_points.len(),
        r.pcc_points.len()
    );
    0
}

fn cmd_run(args: &bigroots::util::cli::Args) -> i32 {
    let path = args.get("config").unwrap();
    let cfg = match ExperimentConfig::load(path) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("config: {e:#}");
            return 1;
        }
    };
    let Some(w) = workloads::by_name(&cfg.workload, cfg.scale) else {
        eprintln!("unknown workload '{}'", cfg.workload);
        return 2;
    };
    let plan = cfg.injection.plan(cfg.seed, cfg.sim.nodes);
    let mut eng = Engine::new(cfg.sim.clone());
    let trace = eng.run(&cfg.workload, w.name, &w.stages, &plan);
    let mut pipeline = Pipeline::auto();
    pipeline.bigroots = cfg.bigroots;
    pipeline.pcc = Some(cfg.pcc);
    let analysis = pipeline.analyze(&trace, w.domain);
    println!(
        "{}: {} stragglers / {} tasks; causes: {}",
        cfg.workload,
        analysis.total_stragglers(),
        trace.tasks.len(),
        analysis
            .summary
            .causes
            .iter()
            .map(|(k, n)| format!("{}({})", k.name(), n))
            .collect::<Vec<_>>()
            .join(" ")
    );
    // Scored confusion when the plan carries ground truth.
    if !trace.injections.is_empty() {
        let mut conf = bigroots::analysis::Confusion::default();
        for (sf, a) in &analysis.per_stage {
            let gt = bigroots::analysis::ground_truth(&trace, sf, experiments::GT_COVERAGE);
            conf.add(bigroots::analysis::roc::score_filtered(a, &gt, &resource_features()));
        }
        println!(
            "vs ground truth: TP {} FP {} TN {} FN {} (FPR {} TPR {} ACC {})",
            conf.tp,
            conf.fp,
            conf.tn,
            conf.fn_,
            pct(conf.fpr()),
            pct(conf.tpr()),
            pct(conf.acc())
        );
    }
    let _ = FeatureKind::COUNT;
    0
}
