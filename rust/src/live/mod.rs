//! The live multi-tenant ingest subsystem: a long-running analysis
//! *server* on top of the per-job streaming analyzer — now a durable,
//! queryable control plane.
//!
//! Six layers, composed left to right:
//!
//! ```text
//!  sources ──▶ sharded ingest ──▶ lifecycle GC ──▶ analysis/routing ──▶ registry ──▶ control
//!  (source)      (ingest)         (lifecycle)    (analysis::router +   (registry +   plane
//!                                                 shared stats cache)   persist)    (control)
//! ```
//!
//! - [`source`] — pluggable transports ([`source::EventSource`]): tail a
//!   growing NDJSON *or binary* capture with rotation detection (binary
//!   frames resync across partial appends), accept line-delimited TCP
//!   clients (mid-line disconnects are logged and counted, never
//!   silently dropped), read stdin, replay memory, or walk an mmap'd
//!   binary capture with zero-copy frame decode
//!   ([`source::MmapReplaySource`]);
//! - [`ingest`] — [`ingest::LiveServer`]: one worker thread per shard
//!   behind a bounded queue (per-shard backpressure), each running demux,
//!   watermark accounting, feature extraction and the BigRoots rules for
//!   its slice of the job population. Workers memoize through one
//!   lock-striped [`crate::analysis::cache::SharedStatsCache`] (repeated
//!   shapes hit across shards) and can route large stages to the
//!   XLA-capable backend ([`crate::analysis::router::RoutingBackend`]);
//! - [`lifecycle`] — [`lifecycle::Lifecycle`]: flush-and-evict `JobState`
//!   after `JobEnd` plus a quiescence window, with incarnation counters
//!   so a revived job id is a fresh job — bounded memory on unbounded
//!   streams;
//! - [`registry`] — [`registry::FleetRegistry`]: cross-job per-feature
//!   quantile sketches (P²) and root-cause incidence counters, fleet
//!   snapshot queries, and a second verdict pass that flags stages
//!   anomalous versus the *fleet* baseline, not just their own stage
//!   median;
//! - [`persist`] — versioned, bit-exact registry snapshots (atomic
//!   write-temp-rename; restore on boot), so the baseline survives server
//!   restarts;
//! - [`control`] — [`control::ControlServer`]: a line-delimited TCP
//!   control/query protocol (`fleet-report`, `jobs`, `job <id>`,
//!   `explain <id>`, `what-if <id>`, `metrics`, `metrics-prom`,
//!   `self-report`, `snapshot`, `shutdown`) sharing one query path with
//!   the CLI's periodic snapshot printing — `jobs` paginates with a
//!   keyset cursor and filters by cause/confidence/time, `explain`
//!   returns the verdict provenance trace and can dump the frozen
//!   flight-recorder window for offline bit-identical replay
//!   (`bigroots explain --replay`).
//!
//! Every layer is instrumented through [`crate::obs`]: spans time source
//! polls, decode, queue waits, the stats kernel, cache lookups, registry
//! folds, control handling and snapshot writes; per-shard batch timings
//! feed the server's BigRoots-on-BigRoots self-analysis
//! ([`crate::obs::selfmon`]).
//!
//! `bigroots serve --tail/--listen --control-port --snapshot-path` and
//! `examples/live_tail.rs` / `examples/control_client.rs` drive the
//! subsystem end to end; `rust/tests/live_integration.rs` pins the
//! batch-parity, eviction, revival, restart-parity and cross-shard-cache
//! contracts.

pub mod control;
pub mod ingest;
pub mod lifecycle;
pub mod persist;
pub mod registry;
pub mod source;

pub use control::{ControlCommand, ControlRequest, ControlServer, JobsQuery};
pub use ingest::{CompletedJob, LiveConfig, LiveMetrics, LiveReport, LiveServer};
pub use lifecycle::{Lifecycle, LifecycleConfig};
pub use persist::{load_snapshot, save_snapshot};
pub use registry::{FeatureSnapshot, FleetFlag, FleetRegistry, FleetReport, QuantileSketch};
pub use source::{
    BinaryTailSource, EventSource, MemorySource, MmapReplaySource, SourcePoll, StdinSource,
    TailSource, TcpSource,
};
