//! The live multi-tenant ingest subsystem: a long-running analysis
//! *server* on top of the per-job streaming analyzer.
//!
//! Four layers, composed left to right:
//!
//! ```text
//!  sources ──▶ sharded ingest ──▶ job lifecycle GC ──▶ fleet registry
//!  (source)      (ingest)           (lifecycle)          (registry)
//! ```
//!
//! - [`source`] — pluggable transports ([`source::EventSource`]): tail a
//!   growing NDJSON file with rotation detection, accept line-delimited
//!   TCP clients, read stdin, or replay memory;
//! - [`ingest`] — [`ingest::LiveServer`]: one worker thread per shard
//!   behind a bounded queue (per-shard backpressure), each running demux,
//!   watermark accounting, feature extraction and the BigRoots rules for
//!   its slice of the job population;
//! - [`lifecycle`] — [`lifecycle::Lifecycle`]: flush-and-evict `JobState`
//!   after `JobEnd` plus a quiescence window, with incarnation counters
//!   so a revived job id is a fresh job — bounded memory on unbounded
//!   streams;
//! - [`registry`] — [`registry::FleetRegistry`]: cross-job per-feature
//!   quantile sketches (P²) and root-cause incidence counters, fleet
//!   snapshot queries, and a second verdict pass that flags stages
//!   anomalous versus the *fleet* baseline, not just their own stage
//!   median.
//!
//! `bigroots serve --tail/--listen` and `examples/live_tail.rs` drive the
//! subsystem end to end; `rust/tests/live_integration.rs` pins the
//! batch-parity, eviction and revival contracts.

pub mod ingest;
pub mod lifecycle;
pub mod registry;
pub mod source;

pub use ingest::{CompletedJob, LiveConfig, LiveMetrics, LiveReport, LiveServer};
pub use lifecycle::{Lifecycle, LifecycleConfig};
pub use registry::{FleetFlag, FleetRegistry, FleetReport, QuantileSketch};
pub use source::{EventSource, MemorySource, SourcePoll, StdinSource, TailSource, TcpSource};
