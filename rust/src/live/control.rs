//! The TCP control/query plane of `bigroots serve` — a second,
//! line-delimited socket (`--control-port`) that answers operator queries
//! while the event port keeps ingesting.
//!
//! Protocol: one request per line, one JSON response line per request, in
//! request order per connection. Verbs:
//!
//! | request        | response `data`                                   |
//! |----------------|---------------------------------------------------|
//! | `fleet-report` | the [`FleetReport`] (counters, quantiles, shares) |
//! | `jobs [cause=<feature>] [min-confidence=<x>] [since=<t>] [until=<t>] [limit=<n>] [cursor=<id>]` | filtered page of retired-job summaries with a keyset cursor ([`jobs_page`]) |
//! | `job <id>`     | summary of a retired job (stages, causes, flags)  |
//! | `explain <id>` | the job's verdict provenance trace ([`crate::analysis::explain`]): per-cause values, thresholds, baselines, confidence, co-occurrence groups |
//! | `explain <id> dump <path>` | writes the job's flight-recorder window + frozen context as NDJSON to `<path>` (server-side, like `snapshot`), for `bigroots explain --replay` |
//! | `what-if <id>` | a retired job's counterfactual verdict: causes ranked by estimated completion-time saved |
//! | `metrics`      | [`LiveMetrics`] incl. per-shard counters          |
//! | `metrics-prom` | `{"text": ...}` — Prometheus exposition text      |
//! | `self-report`  | BigRoots-on-BigRoots verdict on the server itself |
//! | `snapshot`     | writes the fleet snapshot file, returns its path  |
//! | `shutdown`     | asks the server to drain, snapshot and exit       |
//!
//! `jobs` pages by *keyset*, not offset: `cursor` is the last job id of
//! the previous page and the next page starts strictly after it, so a
//! listing stays stable while jobs retire (and age out) concurrently —
//! an entry that existed when its page was read is never repeated, and
//! survivors are never skipped.
//!
//! Every response is `{"ok":true,"kind":...,"data":...}` or
//! `{"ok":false,"error":...}`. Unknown verbs get an error response, never
//! a dropped connection — an operator typo must not cost the session.
//!
//! The same query path backs the CLI: the periodic snapshot printing in
//! `main.rs` goes through [`fleet_report`]/[`fleet_report_text`], so the
//! console and the socket can never drift apart. [`ControlServer`] is
//! poll-based and non-blocking like [`crate::live::source::EventSource`],
//! so one driver thread multiplexes event ingest, control traffic and
//! snapshot cadence.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};

use crate::analysis::explain::{job_verdict_json, max_confidence, FlightDump};
use crate::analysis::features::FeatureKind;
use crate::live::ingest::{CompletedJob, LiveMetrics, LiveServer};
use crate::live::registry::FleetReport;
use crate::util::json::Json;

/// One parsed control request. `Invalid` carries the error text so the
/// driver can answer in order instead of dropping the line.
#[derive(Debug, Clone, PartialEq)]
pub enum ControlCommand {
    FleetReport,
    /// Filtered, keyset-paginated listing of retired-job summaries.
    Jobs(JobsQuery),
    Job(u64),
    /// A retired job's verdict provenance trace
    /// ([`crate::analysis::explain`]).
    Explain(u64),
    /// Write the job's flight-recorder dump to a server-side path
    /// (embedding raw event windows in a one-line response would trip the
    /// [`MAX_PENDING_OUT`] guard; the `snapshot` verb makes the same
    /// call).
    ExplainDump(u64, String),
    /// A retired job's what-if verdict ([`crate::analysis::whatif`]):
    /// detected causes ranked by estimated completion-time saved.
    WhatIf(u64),
    Metrics,
    /// Prometheus text exposition, embedded in the JSON envelope as
    /// `{"text": ...}` so the one-line-per-response protocol holds.
    MetricsProm,
    /// The server's self-analysis ([`crate::obs::selfmon`]): which shard
    /// is the straggler and which internal phase dominates.
    SelfReport,
    Snapshot,
    Shutdown,
    Invalid(String),
}

/// Filters + keyset cursor for the `jobs` verb. All filters are ANDed;
/// the page never exceeds [`MAX_JOBS_PAGE`] entries.
#[derive(Debug, Clone, PartialEq)]
pub struct JobsQuery {
    /// Only jobs whose verdict traces implicate this cause kind (a
    /// [`FeatureKind::name`], validated at parse time).
    pub cause: Option<String>,
    /// Only jobs whose highest cause confidence reaches this value.
    pub min_confidence: Option<f64>,
    /// Only jobs retired at/after this unix time (seconds).
    pub since: Option<f64>,
    /// Only jobs retired at/before this unix time (seconds).
    pub until: Option<f64>,
    /// Page size (clamped to 1..=[`MAX_JOBS_PAGE`]).
    pub limit: usize,
    /// Keyset cursor: the last job id of the previous page; this page
    /// starts strictly after it.
    pub cursor: Option<u64>,
}

impl Default for JobsQuery {
    fn default() -> Self {
        JobsQuery {
            cause: None,
            min_confidence: None,
            since: None,
            until: None,
            limit: 32,
            cursor: None,
        }
    }
}

const JOBS_USAGE: &str = "usage: jobs [cause=<feature>] [min-confidence=<x>] [since=<t>] \
     [until=<t>] [limit=<n>] [cursor=<id>]";

fn parse_jobs_query<'a>(parts: impl Iterator<Item = &'a str>) -> Result<JobsQuery, String> {
    let mut q = JobsQuery::default();
    for tok in parts {
        let (key, value) = tok
            .split_once('=')
            .ok_or_else(|| format!("bad filter '{tok}' ({JOBS_USAGE})"))?;
        match key {
            "cause" => {
                if FeatureKind::from_name(value).is_none() {
                    return Err(format!("unknown cause '{value}' (a feature name)"));
                }
                q.cause = Some(value.to_string());
            }
            "min-confidence" => {
                let x: f64 = value
                    .parse()
                    .map_err(|_| format!("bad min-confidence '{value}'"))?;
                if !(0.0..=1.0).contains(&x) {
                    return Err(format!("min-confidence {value} outside [0, 1]"));
                }
                q.min_confidence = Some(x);
            }
            "since" => {
                q.since =
                    Some(value.parse().map_err(|_| format!("bad since '{value}'"))?);
            }
            "until" => {
                q.until =
                    Some(value.parse().map_err(|_| format!("bad until '{value}'"))?);
            }
            "limit" => {
                let n: usize =
                    value.parse().map_err(|_| format!("bad limit '{value}'"))?;
                if n == 0 {
                    return Err("limit must be at least 1".to_string());
                }
                q.limit = n;
            }
            "cursor" => {
                q.cursor =
                    Some(value.parse().map_err(|_| format!("bad cursor '{value}'"))?);
            }
            _ => return Err(format!("unknown filter '{key}' ({JOBS_USAGE})")),
        }
    }
    Ok(q)
}

/// Parse one request line. Never fails — unparseable input becomes
/// [`ControlCommand::Invalid`] so the response stream stays aligned with
/// the request stream.
pub fn parse_command(line: &str) -> ControlCommand {
    let mut parts = line.split_whitespace();
    match parts.next() {
        Some("fleet-report") if parts.next().is_none() => ControlCommand::FleetReport,
        Some("metrics") if parts.next().is_none() => ControlCommand::Metrics,
        Some("metrics-prom") if parts.next().is_none() => ControlCommand::MetricsProm,
        Some("self-report") if parts.next().is_none() => ControlCommand::SelfReport,
        Some("snapshot") if parts.next().is_none() => ControlCommand::Snapshot,
        Some("shutdown") if parts.next().is_none() => ControlCommand::Shutdown,
        Some("jobs") => match parse_jobs_query(parts) {
            Ok(q) => ControlCommand::Jobs(q),
            Err(e) => ControlCommand::Invalid(e),
        },
        Some("job") => match (parts.next().map(str::parse::<u64>), parts.next()) {
            (Some(Ok(id)), None) => ControlCommand::Job(id),
            _ => ControlCommand::Invalid("usage: job <id>".to_string()),
        },
        Some("explain") => {
            match (parts.next().map(str::parse::<u64>), parts.next(), parts.next(), parts.next())
            {
                (Some(Ok(id)), None, None, None) => ControlCommand::Explain(id),
                (Some(Ok(id)), Some("dump"), Some(path), None) => {
                    ControlCommand::ExplainDump(id, path.to_string())
                }
                _ => ControlCommand::Invalid("usage: explain <id> [dump <path>]".to_string()),
            }
        }
        Some("what-if") => match (parts.next().map(str::parse::<u64>), parts.next()) {
            (Some(Ok(id)), None) => ControlCommand::WhatIf(id),
            _ => ControlCommand::Invalid("usage: what-if <id>".to_string()),
        },
        _ => ControlCommand::Invalid(format!(
            "unknown command '{}' (try: fleet-report | jobs [filters] | job <id> | \
             explain <id> [dump <path>] | what-if <id> | metrics | metrics-prom | \
             self-report | snapshot | shutdown)",
            line.trim()
        )),
    }
}

/// A request read off a control connection; pass it back to
/// [`ControlServer::respond`] to answer it.
#[derive(Debug)]
pub struct ControlRequest {
    conn_id: u64,
    pub command: ControlCommand,
}

/// A request line longer than this is not a control command (e.g. an
/// event stream mistakenly pointed at the control port). The offending
/// connection gets one JSON error envelope and is closed after it drains
/// — never buffered without bound, never silently cut.
const MAX_REQUEST_LINE: usize = 64 * 1024;

/// Bytes read per connection per poll — bounds how long one fast writer
/// can hold the driver thread before ingest gets its turn again.
const MAX_READ_PER_POLL: usize = 256 * 1024;

/// Unflushed response bytes tolerated per connection before the client
/// is declared not-reading and dropped.
const MAX_PENDING_OUT: usize = 256 * 1024;

struct ControlConn {
    id: u64,
    stream: TcpStream,
    peer: String,
    buf: Vec<u8>,
    /// Response bytes accepted but not yet written to the socket.
    out: Vec<u8>,
    /// The client half-closed its write side (`read()` hit EOF). Requests
    /// already buffered still get their responses — a one-shot
    /// `printf 'metrics\n' | nc` client must not be dropped before its
    /// reply is written. The connection dies once `out` drains.
    read_closed: bool,
    open: bool,
}

/// Write as much of `conn.out` as the socket will take without blocking.
fn try_flush(conn: &mut ControlConn) {
    while !conn.out.is_empty() {
        match conn.stream.write(&conn.out) {
            Ok(0) => {
                conn.open = false;
                return;
            }
            Ok(n) => {
                conn.out.drain(..n);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.open = false;
                return;
            }
        }
    }
}

/// Non-blocking line-protocol listener. See module docs.
pub struct ControlServer {
    listener: TcpListener,
    conns: Vec<ControlConn>,
    addr: String,
    next_conn_id: u64,
    requests_served: usize,
}

impl ControlServer {
    pub fn bind(addr: &str) -> Result<Self, String> {
        let listener =
            TcpListener::bind(addr).map_err(|e| format!("control bind {addr}: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("nonblocking control listener: {e}"))?;
        let addr = listener
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| addr.to_string());
        Ok(ControlServer {
            listener,
            conns: Vec::new(),
            addr,
            next_conn_id: 0,
            requests_served: 0,
        })
    }

    /// The bound address (resolves `:0` to the chosen port).
    pub fn local_addr(&self) -> &str {
        &self.addr
    }

    /// Live control connections.
    pub fn connections(&self) -> usize {
        self.conns.len()
    }

    /// Responses accepted (queued or written) since bind.
    pub fn requests_served(&self) -> usize {
        self.requests_served
    }

    /// Response bytes queued but not yet written, across connections.
    pub fn pending_responses(&self) -> usize {
        self.conns.iter().map(|c| c.out.len()).sum()
    }

    /// Write whatever the sockets will take without blocking, dropping
    /// connections that finished (half-closed with nothing left to send).
    /// The serve loop calls this after `shutdown` so the final ack gets
    /// out before the process exits.
    pub fn flush(&mut self) {
        for conn in &mut self.conns {
            try_flush(conn);
            if conn.read_closed && conn.out.is_empty() {
                conn.open = false;
            }
        }
        self.conns.retain(|c| c.open);
    }

    /// Accept waiting clients and read complete request lines. Never
    /// blocks; returns the requests in per-connection arrival order.
    pub fn poll(&mut self) -> Result<Vec<ControlRequest>, String> {
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    stream
                        .set_nonblocking(true)
                        .map_err(|e| format!("nonblocking control conn: {e}"))?;
                    self.next_conn_id += 1;
                    self.conns.push(ControlConn {
                        id: self.next_conn_id,
                        stream,
                        peer: peer.to_string(),
                        buf: Vec::new(),
                        out: Vec::new(),
                        read_closed: false,
                        open: true,
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) => return Err(format!("control accept: {e}")),
            }
        }
        let mut requests = Vec::new();
        let addr = self.addr.clone();
        let mut chunk = [0u8; 16 * 1024];
        for conn in &mut self.conns {
            // Drain any response bytes an earlier respond() could not
            // write without blocking.
            try_flush(conn);
            // A half-closed client lives until its responses are out.
            if conn.read_closed {
                if conn.out.is_empty() {
                    conn.open = false;
                }
                continue;
            }
            let mut read_budget = MAX_READ_PER_POLL;
            while read_budget > 0 {
                match conn.stream.read(&mut chunk) {
                    Ok(0) => {
                        conn.read_closed = true;
                        break;
                    }
                    Ok(n) => {
                        conn.buf.extend_from_slice(&chunk[..n]);
                        read_budget = read_budget.saturating_sub(n);
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        conn.open = false;
                        break;
                    }
                }
            }
            // Split complete lines off the buffer; a trailing partial line
            // stays until its newline arrives.
            while let Some(nl) = conn.buf.iter().position(|&b| b == b'\n') {
                let line: Vec<u8> = conn.buf.drain(..=nl).collect();
                let text = String::from_utf8_lossy(&line);
                let trimmed = text.trim();
                if trimmed.is_empty() {
                    continue;
                }
                requests.push(ControlRequest {
                    conn_id: conn.id,
                    command: parse_command(trimmed),
                });
            }
            // A "line" that long is not a control command (an event stream
            // pointed at the wrong port, most likely): answer with a JSON
            // error envelope, stop reading, and close once the reply has
            // drained — the client learns *why* instead of seeing a reset.
            if conn.open && !conn.read_closed && conn.buf.len() > MAX_REQUEST_LINE {
                crate::obs::log::log(
                    crate::obs::log::Level::Warn,
                    "live.control",
                    "client sent an over-long line with no newline; rejecting",
                    &[
                        ("addr", addr.clone()),
                        ("peer", conn.peer.clone()),
                        ("bytes", conn.buf.len().to_string()),
                    ],
                );
                let err = err_response(&format!(
                    "request line exceeds {MAX_REQUEST_LINE} bytes; closing connection"
                ));
                conn.out.extend_from_slice(format!("{}\n", err.to_string()).as_bytes());
                conn.buf.clear();
                conn.read_closed = true;
                try_flush(conn);
            }
        }
        self.conns.retain(|c| c.open);
        Ok(requests)
    }

    /// Queue one JSON response line for `req` and write as much as the
    /// socket takes *without blocking* — the driver thread never waits on
    /// a control client. Leftover bytes drain on subsequent polls; a
    /// client that stops reading past [`MAX_PENDING_OUT`] is dropped.
    pub fn respond(&mut self, req: &ControlRequest, body: &Json) {
        let Some(conn) = self.conns.iter_mut().find(|c| c.id == req.conn_id) else {
            return; // client already gone
        };
        conn.out.extend_from_slice(format!("{}\n", body.to_string()).as_bytes());
        try_flush(conn);
        if conn.open && conn.out.len() > MAX_PENDING_OUT {
            crate::obs::log::log(
                crate::obs::log::Level::Warn,
                "live.control",
                "client is not reading responses; dropping connection",
                &[("addr", self.addr.clone()), ("peer", conn.peer.clone())],
            );
            conn.open = false;
        }
        self.requests_served += 1;
        self.conns.retain(|c| c.open);
    }
}

// ---------------------------------------------------------------------------
// Response envelopes

/// `{"ok":true,"kind":<kind>,"data":<data>}`
pub fn ok_response(kind: &str, data: Json) -> Json {
    Json::from_pairs(vec![("ok", true.into()), ("kind", kind.into()), ("data", data)])
}

/// `{"ok":false,"error":<message>}`
pub fn err_response(message: &str) -> Json {
    Json::from_pairs(vec![("ok", false.into()), ("error", message.into())])
}

// ---------------------------------------------------------------------------
// The one query path (CLI printing and socket responses)

/// Point-in-time fleet report — the single query path behind both the
/// periodic console snapshot and the socket's `fleet-report` verb.
pub fn fleet_report(server: &LiveServer) -> FleetReport {
    server.registry().report()
}

/// The console rendering of [`fleet_report`] (what `bigroots serve`
/// prints on its snapshot cadence).
pub fn fleet_report_text(server: &LiveServer) -> String {
    fleet_report(server).render()
}

/// JSON shape of a [`FleetReport`].
pub fn fleet_report_json(r: &FleetReport) -> Json {
    let cause_incidence: Vec<Json> = r
        .cause_incidence
        .iter()
        .map(|(kind, n)| {
            Json::from_pairs(vec![
                ("feature", kind.name().into()),
                ("count", (*n).into()),
                ("share", Json::Num(r.cause_fraction(*kind))),
            ])
        })
        .collect();
    let estimated_savings: Vec<Json> = r
        .estimated_savings
        .iter()
        .map(|(kind, saved)| {
            Json::from_pairs(vec![
                ("feature", kind.name().into()),
                ("saved_secs", Json::Num(*saved)),
            ])
        })
        .collect();
    let baselines: Vec<Json> = r
        .baselines
        .iter()
        .map(|b| {
            Json::from_pairs(vec![
                ("feature", b.kind.name().into()),
                ("count", b.count.into()),
                ("p50", Json::Num(b.p50)),
                ("p95", Json::Num(b.p95)),
                ("straggler_p50", Json::Num(b.straggler_p50)),
                ("cause_count", b.cause_count.into()),
            ])
        })
        .collect();
    Json::from_pairs(vec![
        ("jobs_completed", r.jobs_completed.into()),
        ("stages", r.stages.into()),
        ("tasks", r.tasks.into()),
        ("straggler_tasks", r.straggler_tasks.into()),
        ("straggler_rate", Json::Num(r.straggler_rate())),
        ("stage_median_p50", Json::Num(r.stage_median_p50)),
        ("stage_median_p95", Json::Num(r.stage_median_p95)),
        ("shuffle_heavy", r.shuffle_heavy.into()),
        ("shuffle_heavy_gc", r.shuffle_heavy_gc.into()),
        ("shuffle_heavy_gc_fraction", Json::Num(r.shuffle_heavy_gc_fraction())),
        ("cause_incidence", Json::Arr(cause_incidence)),
        ("estimated_savings", Json::Arr(estimated_savings)),
        ("baselines", Json::Arr(baselines)),
    ])
}

/// JSON shape of [`LiveMetrics`].
pub fn live_metrics_json(m: &LiveMetrics) -> Json {
    let per_shard: Vec<Json> = m
        .per_shard
        .iter()
        .map(|s| {
            Json::from_pairs(vec![
                ("shard", s.shard.into()),
                ("events", s.events.into()),
                ("stages", s.stages.into()),
                ("resident", s.resident.into()),
                ("resident_high", s.resident_high.into()),
                ("evicted", s.evicted.into()),
                ("cache_hits", s.cache_hits.into()),
                ("cache_misses", s.cache_misses.into()),
            ])
        })
        .collect();
    Json::from_pairs(vec![
        ("events_total", m.events_total.into()),
        ("jobs_completed", m.jobs_completed.into()),
        ("evictions_live", m.evictions_live.into()),
        ("stages_analyzed", m.stages_analyzed.into()),
        ("resident_high_water", m.resident_high_water.into()),
        ("resident_now", m.resident_now.into()),
        ("events_dropped", m.events_dropped.into()),
        ("dropped_partial_lines", m.dropped_partial_lines.into()),
        ("source_parse_errors", m.source_parse_errors.into()),
        ("source_frame_resyncs", m.source_frame_resyncs.into()),
        ("source_dropped_frames", m.source_dropped_frames.into()),
        ("cache_hits", m.cache_hits.into()),
        ("cache_misses", m.cache_misses.into()),
        ("cache_evictions", m.cache_evictions.into()),
        ("elapsed_secs", Json::Num(m.elapsed_secs)),
        ("events_per_sec", Json::Num(m.events_per_sec)),
        ("per_shard", Json::Arr(per_shard)),
    ])
}

/// JSON summary of one retired job (what the `job <id>` verb returns).
/// Job and stage *identities* are decimal strings, not JSON numbers: a
/// tenant hashing 64-bit ids past 2^53 would otherwise get a rounded id
/// back (`Json::Num` is an f64 — see [`crate::live::persist`], which
/// makes the same call for its counters).
pub fn job_summary_json(j: &CompletedJob) -> Json {
    let stragglers: usize = j.analyses.iter().map(|a| a.stragglers.rows.len()).sum();
    let causes: usize = j.analyses.iter().map(|a| a.causes.len()).sum();
    let cause_kinds: Vec<Json> = crate::analysis::explain::cause_kinds(&j.traces)
        .iter()
        .map(|k| k.name().into())
        .collect();
    Json::from_pairs(vec![
        ("job_id", j.job_id.to_string().into()),
        ("incarnation", j.incarnation.into()),
        ("ended", j.ended.into()),
        ("evicted_live", j.evicted_live.into()),
        ("stages", j.analyses.len().into()),
        ("stragglers", stragglers.into()),
        ("causes", causes.into()),
        ("cause_kinds", Json::Arr(cause_kinds)),
        ("max_confidence", Json::Num(max_confidence(&j.traces))),
        ("fleet_flags", j.fleet_flags.len().into()),
        (
            "flight",
            match &j.flight {
                Some(w) => Json::from_pairs(vec![
                    ("events", w.events.len().into()),
                    ("complete", w.complete().into()),
                ]),
                None => Json::Null,
            },
        ),
        (
            "estimated_savings",
            match &j.whatif {
                Some(w) => w.to_json(),
                None => Json::Null,
            },
        ),
        (
            "incomplete",
            Json::Arr(j.incomplete.iter().map(|s| Json::Str(s.to_string())).collect()),
        ),
    ])
}

/// The `explain <id>` verb's response body: the retired job's verdict
/// provenance document ([`job_verdict_json`]) plus flight-window
/// availability, or why there is none.
pub fn explain_json(j: &CompletedJob) -> Result<Json, String> {
    if j.analyses.is_empty() {
        return Err(format!("job {} retired with no analyzed stages", j.job_id));
    }
    let mut doc = job_verdict_json(j.job_id, j.incarnation, &j.traces);
    doc.set(
        "flight",
        match &j.flight {
            Some(w) => Json::from_pairs(vec![
                ("events", w.events.len().into()),
                ("complete", w.complete().into()),
            ]),
            None => Json::Null,
        },
    );
    Ok(doc)
}

/// Assemble the flight dump for a retired job: the recorded verdict, the
/// analyzer config and fleet baselines it was derived under, and the
/// frozen raw-event window ([`crate::analysis::explain::FlightDump`]).
/// Errors when no straggler verdict ever froze a window for the job.
pub fn flight_dump(
    j: &CompletedJob,
    config: &crate::analysis::bigroots::BigRootsConfig,
) -> Result<FlightDump, String> {
    let w = j.flight.as_ref().ok_or_else(|| {
        format!("job {} has no flight window (no straggler verdict fired)", j.job_id)
    })?;
    Ok(FlightDump {
        job_id: j.job_id,
        incarnation: j.incarnation,
        complete: w.complete(),
        config: *config,
        baselines: j.baselines.clone(),
        verdict: job_verdict_json(j.job_id, j.incarnation, &j.traces),
        events: w.events.clone(),
    })
}

/// Hard cap on a `jobs` page.
pub const MAX_JOBS_PAGE: usize = 256;

fn summary_matches(s: &Json, q: &JobsQuery) -> bool {
    if let Some(cause) = &q.cause {
        let has = s
            .get("cause_kinds")
            .as_arr()
            .map(|a| a.iter().any(|k| k.as_str() == Some(cause.as_str())))
            .unwrap_or(false);
        if !has {
            return false;
        }
    }
    if let Some(min) = q.min_confidence {
        if s.get("max_confidence").as_f64().unwrap_or(0.0) < min {
            return false;
        }
    }
    if q.since.is_some() || q.until.is_some() {
        // `retired_at` is stamped by the driver when it stores the
        // summary (wall-clock retirement time, unix seconds).
        let at = s.get("retired_at").as_f64().unwrap_or(0.0);
        if q.since.map_or(false, |t| at < t) || q.until.map_or(false, |t| at > t) {
            return false;
        }
    }
    true
}

/// One page of the `jobs` listing: filter, then walk the id-ordered store
/// strictly past the cursor. Returns
/// `{"jobs": [...], "count": n, "next_cursor": <id-string> | null}`;
/// `next_cursor` is the last id included, present only when more matches
/// remain. Keyset semantics make the page stable under concurrent
/// retirement: ids only ever *enter* past the tail and *leave* anywhere,
/// and a departed id simply stops matching — never renumbering what
/// offset pagination would.
pub fn jobs_page(entries: &BTreeMap<u64, Json>, q: &JobsQuery) -> Json {
    let limit = q.limit.clamp(1, MAX_JOBS_PAGE);
    let mut jobs: Vec<Json> = Vec::new();
    let mut last_id: Option<u64> = None;
    let mut next_cursor = Json::Null;
    let range = match q.cursor {
        Some(c) => entries.range((std::ops::Bound::Excluded(c), std::ops::Bound::Unbounded)),
        None => entries.range(..),
    };
    for (id, s) in range {
        if !summary_matches(s, q) {
            continue;
        }
        if jobs.len() == limit {
            // A further match exists: the page is full, resume after its
            // last entry.
            next_cursor = Json::Str(last_id.expect("page has entries").to_string());
            break;
        }
        jobs.push(s.clone());
        last_id = Some(*id);
    }
    Json::from_pairs(vec![
        ("count", jobs.len().into()),
        ("jobs", Json::Arr(jobs)),
        ("next_cursor", next_cursor),
    ])
}

/// The `what-if <id>` verb's response body: the retired job's full
/// [`WhatIfReport`](crate::analysis::whatif::WhatIfReport), or an error
/// shape explaining why there is none (never retired / no analyzed
/// stages).
pub fn whatif_json(j: &CompletedJob) -> Result<Json, String> {
    match &j.whatif {
        Some(w) => Ok(w.to_json()),
        None => Err(format!("job {} retired with no analyzed stages", j.job_id)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    #[test]
    fn parses_every_verb() {
        assert_eq!(parse_command("fleet-report"), ControlCommand::FleetReport);
        assert_eq!(parse_command("  metrics  "), ControlCommand::Metrics);
        assert_eq!(parse_command("metrics-prom"), ControlCommand::MetricsProm);
        assert_eq!(parse_command("self-report"), ControlCommand::SelfReport);
        assert_eq!(parse_command("snapshot"), ControlCommand::Snapshot);
        assert_eq!(parse_command("shutdown"), ControlCommand::Shutdown);
        assert_eq!(parse_command("job 42"), ControlCommand::Job(42));
        assert_eq!(parse_command("what-if 42"), ControlCommand::WhatIf(42));
        assert!(matches!(parse_command("job"), ControlCommand::Invalid(_)));
        assert!(matches!(parse_command("job x"), ControlCommand::Invalid(_)));
        assert!(matches!(parse_command("job 1 2"), ControlCommand::Invalid(_)));
        assert!(matches!(parse_command("what-if"), ControlCommand::Invalid(_)));
        assert!(matches!(parse_command("what-if x"), ControlCommand::Invalid(_)));
        assert!(matches!(parse_command("bogus"), ControlCommand::Invalid(_)));
        assert!(matches!(parse_command("fleet-report extra"), ControlCommand::Invalid(_)));
        assert_eq!(parse_command("explain 7"), ControlCommand::Explain(7));
        assert_eq!(
            parse_command("explain 7 dump /tmp/w.ndjson"),
            ControlCommand::ExplainDump(7, "/tmp/w.ndjson".to_string())
        );
        assert!(matches!(parse_command("explain"), ControlCommand::Invalid(_)));
        assert!(matches!(parse_command("explain x"), ControlCommand::Invalid(_)));
        assert!(matches!(parse_command("explain 7 dump"), ControlCommand::Invalid(_)));
        assert!(matches!(parse_command("explain 7 dump a b"), ControlCommand::Invalid(_)));
        assert_eq!(parse_command("jobs"), ControlCommand::Jobs(JobsQuery::default()));
        let q = match parse_command("jobs cause=cpu min-confidence=0.5 limit=3 cursor=12") {
            ControlCommand::Jobs(q) => q,
            other => panic!("expected Jobs, got {other:?}"),
        };
        assert_eq!(q.cause.as_deref(), Some("cpu"));
        assert_eq!(q.min_confidence, Some(0.5));
        assert_eq!(q.limit, 3);
        assert_eq!(q.cursor, Some(12));
        assert!(matches!(parse_command("jobs cause=nope"), ControlCommand::Invalid(_)));
        assert!(matches!(parse_command("jobs min-confidence=2"), ControlCommand::Invalid(_)));
        assert!(matches!(parse_command("jobs limit=0"), ControlCommand::Invalid(_)));
        assert!(matches!(parse_command("jobs froz=1"), ControlCommand::Invalid(_)));
    }

    fn summary_fixture(id: u64, cause: &str, conf: f64, retired_at: f64) -> Json {
        Json::from_pairs(vec![
            ("job_id", id.to_string().into()),
            ("cause_kinds", Json::Arr(vec![cause.into()])),
            ("max_confidence", Json::Num(conf)),
            ("retired_at", Json::Num(retired_at)),
        ])
    }

    #[test]
    fn jobs_pagination_walks_to_exhaustion() {
        let mut store: BTreeMap<u64, Json> = BTreeMap::new();
        for id in 1..=5u64 {
            store.insert(id, summary_fixture(id, "cpu", 0.9, id as f64));
        }
        let mut q = JobsQuery { limit: 2, ..JobsQuery::default() };
        let mut seen = Vec::new();
        loop {
            let page = jobs_page(&store, &q);
            for j in page.get("jobs").as_arr().unwrap() {
                seen.push(j.get("job_id").as_str().unwrap().to_string());
            }
            match page.get("next_cursor").as_str() {
                Some(c) => q.cursor = Some(c.parse().unwrap()),
                None => break,
            }
        }
        assert_eq!(seen, vec!["1", "2", "3", "4", "5"]);
        // Past the end: an empty page with a null cursor, not an error.
        let empty = jobs_page(&store, &JobsQuery { cursor: Some(5), ..JobsQuery::default() });
        assert_eq!(empty.get("count").as_usize(), Some(0));
        assert!(matches!(empty.get("next_cursor"), Json::Null));
    }

    #[test]
    fn jobs_cursor_stable_under_concurrent_retirement() {
        let mut store: BTreeMap<u64, Json> = BTreeMap::new();
        for id in 1..=6u64 {
            store.insert(id, summary_fixture(id, "cpu", 0.9, id as f64));
        }
        let page1 = jobs_page(&store, &JobsQuery { limit: 3, ..JobsQuery::default() });
        let cursor: u64 = page1.get("next_cursor").as_str().unwrap().parse().unwrap();
        assert_eq!(cursor, 3);
        // Between pages: an already-returned job ages out and a new one
        // retires. Keyset resumption neither re-serves nor skips.
        store.remove(&2);
        store.insert(7, summary_fixture(7, "cpu", 0.9, 7.0));
        let page2 =
            jobs_page(&store, &JobsQuery { limit: 3, cursor: Some(cursor), ..JobsQuery::default() });
        let ids: Vec<&str> = page2
            .get("jobs")
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.get("job_id").as_str().unwrap())
            .collect();
        assert_eq!(ids, vec!["4", "5", "6"]);
        let cursor2: u64 = page2.get("next_cursor").as_str().unwrap().parse().unwrap();
        let page3 =
            jobs_page(&store, &JobsQuery { limit: 3, cursor: Some(cursor2), ..JobsQuery::default() });
        let ids3: Vec<&str> = page3
            .get("jobs")
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.get("job_id").as_str().unwrap())
            .collect();
        assert_eq!(ids3, vec!["7"]);
        assert!(matches!(page3.get("next_cursor"), Json::Null));
    }

    #[test]
    fn jobs_filters_compose_with_cursor() {
        let mut store: BTreeMap<u64, Json> = BTreeMap::new();
        store.insert(1, summary_fixture(1, "cpu", 0.9, 10.0));
        store.insert(2, summary_fixture(2, "network_in", 0.9, 20.0));
        store.insert(3, summary_fixture(3, "cpu", 0.2, 30.0));
        store.insert(4, summary_fixture(4, "cpu", 0.8, 40.0));
        store.insert(5, summary_fixture(5, "cpu", 0.7, 50.0));
        let q = JobsQuery {
            cause: Some("cpu".into()),
            min_confidence: Some(0.5),
            since: Some(15.0),
            limit: 1,
            ..JobsQuery::default()
        };
        // Jobs 2 (cause), 3 (confidence) and 1 (since) are filtered out.
        let page = jobs_page(&store, &q);
        let ids: Vec<&str> = page
            .get("jobs")
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.get("job_id").as_str().unwrap())
            .collect();
        assert_eq!(ids, vec!["4"]);
        let cursor: u64 = page.get("next_cursor").as_str().unwrap().parse().unwrap();
        assert_eq!(cursor, 4);
        let page2 = jobs_page(&store, &JobsQuery { cursor: Some(cursor), ..q });
        let ids2: Vec<&str> = page2
            .get("jobs")
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.get("job_id").as_str().unwrap())
            .collect();
        assert_eq!(ids2, vec!["5"]);
        assert!(matches!(page2.get("next_cursor"), Json::Null));
        // until filter: only the earliest survivor.
        let until = JobsQuery {
            cause: Some("cpu".into()),
            until: Some(15.0),
            ..JobsQuery::default()
        };
        assert_eq!(jobs_page(&store, &until).get("count").as_usize(), Some(1));
    }

    #[test]
    fn oversized_request_line_gets_error_envelope() {
        use std::io::{BufRead, BufReader, Write as _};
        let mut srv = match ControlServer::bind("127.0.0.1:0") {
            Ok(s) => s,
            Err(_) => return,
        };
        let addr = srv.local_addr().to_string();
        let client = std::thread::spawn(move || {
            let mut c = TcpStream::connect(&addr).unwrap();
            // One newline-free blob larger than the request-line cap.
            let blob = vec![b'x'; MAX_REQUEST_LINE + 1024];
            let _ = c.write_all(&blob);
            let mut reader = BufReader::new(c);
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            line
        });
        let deadline = Instant::now() + Duration::from_secs(10);
        let line = loop {
            assert!(Instant::now() < deadline, "oversized-line test timed out");
            let _ = srv.poll().unwrap();
            if client.is_finished() {
                break client.join().unwrap();
            }
            std::thread::sleep(Duration::from_millis(2));
        };
        let resp = Json::parse(line.trim()).expect("error envelope, not a silent drop");
        assert_eq!(resp.get("ok").as_bool(), Some(false));
        assert!(resp.get("error").as_str().unwrap().contains("exceeds"));
    }

    #[test]
    fn envelopes_are_well_formed() {
        let ok = ok_response("metrics", Json::obj());
        assert_eq!(ok.get("ok").as_bool(), Some(true));
        assert_eq!(ok.get("kind").as_str(), Some("metrics"));
        let err = err_response("nope");
        assert_eq!(err.get("ok").as_bool(), Some(false));
        assert_eq!(err.get("error").as_str(), Some("nope"));
        // Single-line framing survives serialization.
        assert!(!ok.to_string().contains('\n'));
    }

    #[test]
    fn socket_requests_answered_in_order() {
        use std::io::{BufRead, BufReader, Write as _};
        let mut srv = match ControlServer::bind("127.0.0.1:0") {
            Ok(s) => s,
            // Sandboxed environments may forbid binding; parsing and
            // envelope logic are covered above.
            Err(_) => return,
        };
        let addr = srv.local_addr().to_string();
        let client = std::thread::spawn(move || {
            let mut c = TcpStream::connect(&addr).unwrap();
            c.write_all(b"metrics\njob 3\nbogus\n").unwrap();
            let mut reader = BufReader::new(c);
            let mut lines = Vec::new();
            for _ in 0..3 {
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                lines.push(line);
            }
            lines
        });
        let mut served = 0;
        let deadline = Instant::now() + Duration::from_secs(10);
        while served < 3 {
            assert!(Instant::now() < deadline, "control test timed out");
            for req in srv.poll().unwrap() {
                let resp = match &req.command {
                    ControlCommand::Metrics => ok_response("metrics", Json::obj()),
                    ControlCommand::Job(id) => {
                        ok_response("job", Json::from_pairs(vec![("job_id", (*id).into())]))
                    }
                    ControlCommand::Invalid(msg) => err_response(msg),
                    other => err_response(&format!("unexpected {other:?}")),
                };
                srv.respond(&req, &resp);
                served += 1;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        // Drain any response bytes a WouldBlock deferred to later polls.
        for _ in 0..100 {
            let _ = srv.poll();
            std::thread::sleep(Duration::from_millis(1));
        }
        let lines = client.join().unwrap();
        let first = Json::parse(lines[0].trim()).unwrap();
        assert_eq!(first.get("ok").as_bool(), Some(true));
        assert_eq!(first.get("kind").as_str(), Some("metrics"));
        let second = Json::parse(lines[1].trim()).unwrap();
        assert_eq!(second.get("data").get("job_id").as_u64(), Some(3));
        let third = Json::parse(lines[2].trim()).unwrap();
        assert_eq!(third.get("ok").as_bool(), Some(false));
        assert_eq!(srv.requests_served(), 3);
    }

    #[test]
    fn whatif_json_shapes() {
        use crate::analysis::features::FeatureKind;
        use crate::analysis::whatif::{CauseSavings, WhatIfReport};
        let mut job = CompletedJob {
            job_id: 9,
            incarnation: 0,
            ended: true,
            evicted_live: false,
            analyses: Vec::new(),
            traces: Vec::new(),
            baselines: Vec::new(),
            flight: None,
            fleet_flags: Vec::new(),
            whatif: None,
            incomplete: Vec::new(),
        };
        // No verdict → the verb errors, and the job summary carries null.
        assert!(whatif_json(&job).is_err());
        assert!(matches!(job_summary_json(&job).get("estimated_savings"), Json::Null));
        job.whatif = Some(WhatIfReport {
            job: "job-9".into(),
            seed: 42,
            slots_per_node: 12,
            baseline_secs: 30.0,
            rows: vec![CauseSavings {
                kind: FeatureKind::JvmGcTime,
                tasks_affected: 2,
                stages_affected: 1,
                counterfactual_secs: 25.0,
                saved_secs: 5.0,
                saved_frac: 5.0 / 30.0,
            }],
        });
        let w = whatif_json(&job).expect("verdict present");
        assert_eq!(w.get("job").as_str(), Some("job-9"));
        let rows = w.get("rows").as_arr().expect("rows");
        assert_eq!(rows[0].get("cause").as_str(), Some("jvm_gc_time"));
        assert_eq!(rows[0].get("saved_secs").as_f64(), Some(5.0));
        let summary = job_summary_json(&job);
        assert_eq!(
            summary.get("estimated_savings").get("baseline_secs").as_f64(),
            Some(30.0)
        );
    }

    #[test]
    fn fleet_report_json_shape() {
        let server = LiveServer::new(crate::live::ingest::LiveConfig::default());
        let r = fleet_report(&server);
        let j = fleet_report_json(&r);
        assert_eq!(j.get("jobs_completed").as_usize(), Some(0));
        assert!(j.get("baselines").as_arr().is_some());
        // The console path renders the same report.
        assert!(fleet_report_text(&server).contains("fleet baseline"));
        drop(server);
    }
}
