//! Shard-parallel live ingest — the long-running, multi-tenant analysis
//! server.
//!
//! The PR-2 [`crate::coordinator::service::AnalysisService`] demuxes on
//! the caller's thread: every `JobState::feed`, watermark check and
//! feature extraction runs single-threaded, and only the stats math fans
//! out to the pool. [`LiveServer`] moves the whole per-shard pipeline —
//! demux, accumulation, stage freezing, feature extraction, stats and
//! rule evaluation — onto one dedicated worker thread per shard, fed
//! through a *bounded* queue ([`crate::util::queue`]):
//!
//! ```text
//!  source ─feed─▶ router ──▶ [queue 0] ─▶ shard 0: JobState GC + analyze ─┐
//!                 (batches)  [queue 1] ─▶ shard 1:        "              ─┤─▶ collector
//!                            [queue 2] ─▶ shard 2:        "              ─┘   (fleet
//!                                                                            registry,
//!                                                             per-job results, verdicts)
//! ```
//!
//! Events move through the queues as columnar
//! [`crate::trace::batch::EventBatch`]es: the router demuxes
//! *runs* of consecutive same-job events (one rendezvous hash per run,
//! not per event), each queue handshake moves a whole batch (one lock,
//! one condvar signal), workers fold a batch under one `obs` span, and
//! drained batch buffers cycle back to the router through a per-shard
//! free-list so steady-state ingest allocates nothing. See
//! `docs/BATCHING.md` for the full lifecycle.
//!
//! - **Backpressure**: `feed` blocks once the slowest shard's queue is
//!   full — the transport naturally throttles to analysis speed. Queue
//!   capacity is accounted in *events* (`queue_capacity × ingest_batch`
//!   per shard), so buffered memory stays
//!   `shards × queue_capacity × ingest_batch` events at most regardless
//!   of how events pack into batches.
//! - **Lifecycle GC**: each shard runs a [`Lifecycle`] that evicts
//!   `JobState`s after `JobEnd` (drain or quiescence; see
//!   [`crate::live::lifecycle`]), so resident state is bounded by the
//!   number of *concurrently running* jobs, not jobs ever seen.
//! - **Fleet registry**: the collector folds every completed stage into a
//!   [`FleetRegistry`] and attaches the second-pass fleet verdict to each
//!   job as it retires. The registry can be restored from a
//!   [`crate::live::persist`] snapshot on boot and handed back at
//!   shutdown ([`LiveServer::finish_with_registry`]), so the cross-job
//!   baseline survives restarts.
//! - **Shared stats cache**: all shard workers memoize through one
//!   lock-striped [`SharedStatsCache`] — a repeated stage shape hits even
//!   when rendezvous routing sent its first occurrence to a different
//!   shard — and, with `route_large_tasks` set, dispatch large stages to
//!   the XLA-capable backend via
//!   [`crate::analysis::router::RoutingBackend`].
//!
//! Determinism: a job's events all hash to one shard and stay in order,
//! so per-job analyses are bit-identical to the offline batch pipeline —
//! the same guarantee the PR-2 service makes, now with parallel demux
//! (`rust/tests/live_integration.rs` asserts it through a byte-level file
//! tail).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::analysis::bigroots::{analyze_stage_with_stats, BigRootsConfig, StageAnalysis};
use crate::analysis::cache::{SharedCachedBackend, SharedStatsCache};
use crate::analysis::explain::{explain_stage, VerdictTrace};
use crate::analysis::features::StageFeatures;
use crate::analysis::router::RoutingBackend;
use crate::analysis::stats::{NativeBackend, StatsBackend};
use crate::analysis::whatif::{self, WhatIfConfig, WhatIfReport};
use crate::live::lifecycle::{Lifecycle, LifecycleConfig};
use crate::live::registry::{FeatureSnapshot, FleetFlag, FleetRegistry, FleetReport};
use crate::obs::flight::{FlightRecorder, FlightWindow};
use crate::obs::{self, SpanKind};
use crate::trace::batch::EventBatch;
use crate::trace::eventlog::TaggedEvent;
use crate::util::queue::{bounded, BoundedSender, PopTimeout};

/// Live server tuning knobs. Correctness is independent of all of them.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    /// Shard worker threads (each owns its jobs' state and a backend).
    pub shards: usize,
    /// Events buffered per shard before a queue send (amortizes the
    /// queue's lock). Also the allocation size of recycled batch buffers.
    pub ingest_batch: usize,
    /// Per-shard queue capacity in full batches — the backpressure bound.
    /// The queue itself accounts in events (`queue_capacity ×
    /// ingest_batch`), so undersized batches don't inflate buffering.
    pub queue_capacity: usize,
    /// Job eviction policy.
    pub lifecycle: LifecycleConfig,
    /// Total stage-stats memo capacity, shared by *all* shard workers
    /// through one lock-striped [`SharedStatsCache`] — a tenant's repeated
    /// stage shape hits no matter which shard rendezvous routing picked.
    /// 0 disables caching. Bit-identical results either way.
    pub stats_cache_capacity: usize,
    /// Lock stripes in the shared stage-stats cache (contention knob;
    /// never more than the capacity).
    pub stats_cache_stripes: usize,
    /// Route stages with at least this many tasks to the large-stage
    /// backend ([`crate::analysis::router::RoutingBackend`]: XLA-capable,
    /// native-stubbed without artifacts). 0 keeps every stage on the
    /// native backend.
    pub route_large_tasks: usize,
    /// Analyzer thresholds (paper defaults).
    pub bigroots: BigRootsConfig,
    /// Fleet-verdict cold-start guard (min observations per baseline).
    pub fleet_min_samples: usize,
    /// Counterfactual what-if replay knobs — each retiring job gets a
    /// [`WhatIfReport`] computed against the fleet baseline of that
    /// moment.
    pub whatif: WhatIfConfig,
    /// Per-shard flight-recorder ring capacity in raw events
    /// ([`crate::obs::flight::FlightRecorder`]): how much recent history a
    /// straggler verdict can freeze for bit-identical replay. 0 disables
    /// event buffering (verdict windows come back empty and incomplete).
    pub flight_capacity: usize,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig {
            shards: 4,
            ingest_batch: 64,
            queue_capacity: 8,
            lifecycle: LifecycleConfig::default(),
            stats_cache_capacity: 256,
            stats_cache_stripes: 8,
            route_large_tasks: 0,
            bigroots: BigRootsConfig::default(),
            fleet_min_samples: 64,
            whatif: WhatIfConfig::default(),
            flight_capacity: 16_384,
        }
    }
}

/// Per-shard counters, written by the worker, read by anyone.
#[derive(Default)]
struct ShardStats {
    events: AtomicUsize,
    stages: AtomicUsize,
    resident: AtomicUsize,
    resident_high: AtomicUsize,
    evicted: AtomicUsize,
    dropped: AtomicUsize,
    cache_hits: AtomicUsize,
    cache_misses: AtomicUsize,
}

/// What a shard worker sends the collector.
enum LiveMsg {
    Stage {
        job_id: u64,
        incarnation: u32,
        seq: u64,
        features: crate::analysis::features::StageFeatures,
        analysis: StageAnalysis,
    },
    Evicted {
        job_id: u64,
        incarnation: u32,
        ended: bool,
        incomplete: Vec<u64>,
        /// Evicted while the stream was still flowing (vs end-of-stream).
        live: bool,
        /// The frozen flight-recorder window, when a straggler verdict
        /// fired for this job.
        flight: Option<FlightWindow>,
    },
}

/// One fully retired job.
#[derive(Debug)]
pub struct CompletedJob {
    pub job_id: u64,
    pub incarnation: u32,
    /// A `JobEnd` was seen.
    pub ended: bool,
    /// Evicted by the lifecycle GC mid-stream (vs flushed at stream end).
    pub evicted_live: bool,
    /// Per-stage analyses in stage-emission order — bit-identical to the
    /// offline batch pipeline for complete jobs.
    pub analyses: Vec<StageAnalysis>,
    /// Second-pass flags versus the fleet baseline at retirement time.
    pub fleet_flags: Vec<FleetFlag>,
    /// Counterfactual verdict: detected causes ranked by estimated
    /// completion-time saved, computed at retirement against the fleet
    /// baseline of that moment. `None` for jobs that retired with no
    /// analyzed stages.
    pub whatif: Option<WhatIfReport>,
    /// Verdict provenance, one trace per analyzed stage (same order as
    /// `analyses`): per-cause thresholds, stage baselines, fleet
    /// percentiles, confidence scores and co-occurrence groups
    /// ([`crate::analysis::explain`]).
    pub traces: Vec<VerdictTrace>,
    /// The fleet per-feature baselines the traces were derived against —
    /// frozen here because the live registry keeps evolving; a flight
    /// dump carries these for bit-identical replay.
    pub baselines: Vec<FeatureSnapshot>,
    /// The frozen flight-recorder event window, present when a straggler
    /// verdict fired for this job ([`crate::obs::flight`]).
    pub flight: Option<FlightWindow>,
    /// Announced stages that never completed.
    pub incomplete: Vec<u64>,
}

/// Snapshot of live-server throughput and GC behavior.
#[derive(Debug, Clone, Default)]
pub struct LiveMetrics {
    pub events_total: usize,
    pub jobs_completed: usize,
    pub evictions_live: usize,
    pub stages_analyzed: usize,
    /// Sum of per-shard resident high-water marks — the peak number of
    /// `JobState`s held at once (upper bound across shards).
    pub resident_high_water: usize,
    pub resident_now: usize,
    /// Stray post-eviction events dropped.
    pub events_dropped: usize,
    /// Partial lines lost to mid-line client disconnects, as reported by
    /// the event source (see
    /// [`crate::live::source::EventSource::dropped_partial_lines`]).
    pub dropped_partial_lines: usize,
    /// Event lines the source failed to parse (see
    /// [`crate::live::source::EventSource::parse_errors`]). Updated every
    /// driver-loop iteration, so the `metrics` control verb sees it while
    /// the stream is still flowing.
    pub source_parse_errors: usize,
    /// Binary frames completed across a chunk boundary by the source's
    /// incremental reader (see
    /// [`crate::live::source::EventSource::frame_resyncs`]).
    pub source_frame_resyncs: usize,
    /// Binary frames lost mid-buffer to rotation/truncation (see
    /// [`crate::live::source::EventSource::dropped_frames`]) — the binary
    /// twin of `dropped_partial_lines`.
    pub source_dropped_frames: usize,
    /// Stage-stats memo hits across shard backends (live — shard workers
    /// publish after every ingest batch, so fleet snapshots see them).
    /// The memo is the cross-shard [`SharedStatsCache`], so hits include
    /// shapes another shard computed.
    pub cache_hits: usize,
    /// Stage-stats memo misses (see `cache_hits`).
    pub cache_misses: usize,
    /// Entries evicted from the shared stage-stats cache (global).
    pub cache_evictions: usize,
    pub per_shard: Vec<LiveShardMetrics>,
    pub elapsed_secs: f64,
    pub events_per_sec: f64,
}

#[derive(Debug, Clone)]
pub struct LiveShardMetrics {
    pub shard: usize,
    pub events: usize,
    pub stages: usize,
    pub resident: usize,
    pub resident_high: usize,
    pub evicted: usize,
    pub cache_hits: usize,
    pub cache_misses: usize,
}

/// Final output of a live run. Jobs already taken with
/// [`LiveServer::drain_completed`] are *not* repeated here.
#[derive(Debug)]
pub struct LiveReport {
    /// Retired jobs sorted by (job id, incarnation).
    pub jobs: Vec<CompletedJob>,
    pub fleet: FleetReport,
    pub metrics: LiveMetrics,
}

impl LiveReport {
    /// First incarnation of a job id, if it retired in this report.
    /// `jobs` is sorted by (job id, incarnation), so this is a binary
    /// search — no linear scan at high job counts (the same contract
    /// [`crate::coordinator::service::ServiceReport::job`] keeps via its
    /// index).
    pub fn job(&self, job_id: u64) -> Option<&CompletedJob> {
        let i = self.jobs.partition_point(|j| j.job_id < job_id);
        self.jobs.get(i).filter(|j| j.job_id == job_id)
    }

    pub fn total_stages(&self) -> usize {
        self.jobs.iter().map(|j| j.analyses.len()).sum()
    }

    pub fn total_stragglers(&self) -> usize {
        self.jobs
            .iter()
            .flat_map(|j| j.analyses.iter())
            .map(|a| a.stragglers.rows.len())
            .sum()
    }
}

/// The long-running shard-parallel analysis server. See module docs.
pub struct LiveServer {
    cfg: LiveConfig,
    senders: Vec<BoundedSender<EventBatch>>,
    pending: Vec<EventBatch>,
    /// Drained batch buffers coming back from the workers (per-shard
    /// free-list): the router reuses them instead of allocating, so
    /// steady-state ingest runs allocation-free. Bounded by construction —
    /// a worker can only return buffers it was sent.
    pools: Vec<Receiver<EventBatch>>,
    /// Last (job id, shard) routed — consecutive same-job events skip the
    /// rendezvous hash entirely (the run-length demux fast path).
    route_memo: Option<(u64, usize)>,
    workers: Vec<JoinHandle<()>>,
    results_rx: Receiver<LiveMsg>,
    stats: Vec<Arc<ShardStats>>,
    /// The cross-shard stage-stats cache every worker shares.
    shared_cache: Arc<SharedStatsCache>,
    registry: FleetRegistry,
    /// Cumulative partial-line drops reported by the event source.
    source_dropped_partial_lines: usize,
    /// Cumulative parse failures reported by the event source.
    source_parse_errors: usize,
    /// Cumulative binary frame resyncs reported by the event source.
    source_frame_resyncs: usize,
    /// Cumulative binary frames lost mid-buffer, per the event source.
    source_dropped_frames: usize,
    /// (job id, incarnation) → collected (seq, features, analysis, fleet
    /// flags). Features stay resident until the job retires — the
    /// counterfactual replay needs the full per-task matrices — and are
    /// dropped with the job.
    collected: HashMap<(u64, u32), Vec<(u64, StageFeatures, StageAnalysis, Vec<FleetFlag>)>>,
    completed: Vec<CompletedJob>,
    jobs_completed: usize,
    evictions_live: usize,
    events_total: usize,
    started: Instant,
}

impl LiveServer {
    pub fn new(mut cfg: LiveConfig) -> Self {
        cfg.shards = cfg.shards.max(1);
        cfg.ingest_batch = cfg.ingest_batch.max(1);
        cfg.queue_capacity = cfg.queue_capacity.max(1);
        let (results_tx, results_rx) = channel::<LiveMsg>();
        let shared_cache =
            Arc::new(SharedStatsCache::new(cfg.stats_cache_capacity, cfg.stats_cache_stripes));
        let mut senders = Vec::with_capacity(cfg.shards);
        let mut pools = Vec::with_capacity(cfg.shards);
        let mut workers = Vec::with_capacity(cfg.shards);
        let mut stats = Vec::with_capacity(cfg.shards);
        for shard in 0..cfg.shards {
            // Queue capacity in *events*: `queue_capacity` full batches.
            let (tx, rx) = bounded::<EventBatch>(cfg.queue_capacity * cfg.ingest_batch);
            let (pool_tx, pool_rx) = channel::<EventBatch>();
            let shard_stats = Arc::new(ShardStats::default());
            let worker_stats = Arc::clone(&shard_stats);
            let worker_tx = results_tx.clone();
            let bigroots = cfg.bigroots;
            let lifecycle = cfg.lifecycle.clone();
            let worker_cache = Arc::clone(&shared_cache);
            let route_large_tasks = cfg.route_large_tasks;
            let flight_capacity = cfg.flight_capacity;
            workers.push(std::thread::spawn(move || {
                shard_worker(
                    shard,
                    rx,
                    pool_tx,
                    worker_tx,
                    worker_stats,
                    bigroots,
                    lifecycle,
                    worker_cache,
                    route_large_tasks,
                    flight_capacity,
                );
            }));
            senders.push(tx);
            pools.push(pool_rx);
            stats.push(shard_stats);
        }
        // The workers hold the only result senders: when they exit, the
        // collector sees the channel disconnect and knows the drain is
        // complete.
        drop(results_tx);
        let pending =
            (0..cfg.shards).map(|_| EventBatch::with_capacity(cfg.ingest_batch)).collect();
        LiveServer {
            registry: FleetRegistry::new(cfg.fleet_min_samples),
            cfg,
            senders,
            pending,
            pools,
            route_memo: None,
            workers,
            results_rx,
            stats,
            shared_cache,
            source_dropped_partial_lines: 0,
            source_parse_errors: 0,
            source_frame_resyncs: 0,
            source_dropped_frames: 0,
            collected: HashMap::new(),
            completed: Vec::new(),
            jobs_completed: 0,
            evictions_live: 0,
            events_total: 0,
            started: Instant::now(),
        }
    }

    fn shard_of(&self, job_id: u64) -> usize {
        // Rendezvous hashing — skew-proof job → shard routing (see
        // `util::shard`): strided tenant id schemes no longer pile onto a
        // few shards, and a job's shard never changes mid-stream.
        crate::util::shard::shard_of(job_id, self.cfg.shards)
    }

    /// Route a job id, memoizing the last answer: a run of consecutive
    /// same-job events pays for one rendezvous hash, not one per event.
    fn route(&mut self, job_id: u64) -> usize {
        if let Some((memo_id, shard)) = self.route_memo {
            if memo_id == job_id {
                return shard;
            }
        }
        let shard = self.shard_of(job_id);
        self.route_memo = Some((job_id, shard));
        shard
    }

    /// Swap the shard's pending batch with a recycled (or fresh) buffer
    /// and push it onto the shard queue. Blocks on a full queue — the
    /// backpressure contract.
    fn send_shard(&mut self, shard: usize) {
        let fresh = self.pools[shard]
            .try_recv()
            .unwrap_or_else(|_| EventBatch::with_capacity(self.cfg.ingest_batch));
        let batch = std::mem::replace(&mut self.pending[shard], fresh);
        let events = batch.len();
        let g = obs::span(SpanKind::EnqueueWait);
        let sent = self.senders[shard].push_batch(batch, events);
        g.finish();
        if sent.is_err() {
            panic!("live shard {shard} worker died");
        }
    }

    /// Ingest one event. Blocks when the target shard's queue is full —
    /// that is the backpressure contract.
    pub fn feed(&mut self, event: TaggedEvent) {
        self.events_total += 1;
        let shard = self.route(event.job_id);
        self.pending[shard].push(&event);
        if self.pending[shard].len() >= self.cfg.ingest_batch {
            self.send_shard(shard);
        }
        self.drain_results();
    }

    /// Ingest a slice. The run-length demux: consecutive events with the
    /// same job id route as one unit (a single rendezvous hash for the
    /// whole run), which is where real traces spend most of their time —
    /// a job's task storm arrives as long same-job runs.
    pub fn feed_all(&mut self, events: &[TaggedEvent]) {
        let mut i = 0;
        while i < events.len() {
            let job_id = events[i].job_id;
            let mut end = i + 1;
            while end < events.len() && events[end].job_id == job_id {
                end += 1;
            }
            let shard = self.route(job_id);
            for e in &events[i..end] {
                self.pending[shard].push(e);
                if self.pending[shard].len() >= self.cfg.ingest_batch {
                    self.send_shard(shard);
                }
            }
            self.events_total += end - i;
            i = end;
        }
        self.drain_results();
    }

    /// Push partially-filled ingest batches through and absorb any ready
    /// results. Call when the source is idle so analyses don't wait for a
    /// batch to fill. Also nudges each shard's lifecycle scan (an empty
    /// batch is the idle tick), so a job that drained with the stream's
    /// final events retires without waiting for more traffic. The tick is
    /// best-effort (`try_send`): a shard with a full queue has work in
    /// flight and scans on its own — pump stays non-blocking, so the
    /// driver (and control plane) never stall behind a busy shard.
    pub fn pump(&mut self) {
        self.flush_pending();
        for shard in 0..self.cfg.shards {
            // Weight 0 floors to 1 in the queue, so ticks can't starve
            // real batches; `try_push_batch` keeps the pump non-blocking.
            let _ = self.senders[shard].try_push_batch(EventBatch::new(), 0);
        }
        self.drain_results();
    }

    fn flush_pending(&mut self) {
        for shard in 0..self.cfg.shards {
            if !self.pending[shard].is_empty() {
                self.send_shard(shard);
            }
        }
    }

    /// Retired jobs since the last call (print verdicts incrementally).
    pub fn drain_completed(&mut self) -> Vec<CompletedJob> {
        self.drain_results();
        std::mem::take(&mut self.completed)
    }

    /// Events accepted so far.
    pub fn events_total(&self) -> usize {
        self.events_total
    }

    /// Read-only fleet registry access (snapshot queries mid-run).
    pub fn registry(&self) -> &FleetRegistry {
        &self.registry
    }

    /// Replace the fleet registry with a restored snapshot
    /// ([`crate::live::persist`]) — call before feeding any events so the
    /// server resumes exactly where the snapshotted deployment stopped.
    pub fn restore_registry(&mut self, registry: FleetRegistry) {
        self.registry = registry;
    }

    /// Record the event source's cumulative partial-line drop count
    /// (surfaced in [`LiveMetrics::dropped_partial_lines`]). The driver
    /// loop calls this with
    /// [`crate::live::source::EventSource::dropped_partial_lines`].
    pub fn record_source_drops(&mut self, dropped_partial_lines: usize) {
        self.source_dropped_partial_lines = dropped_partial_lines;
    }

    /// Record both cumulative source-side loss counters in one call —
    /// partial-line drops and parse failures — so the `metrics` control
    /// verb and Prometheus exposition see them while the stream is still
    /// flowing, not only at shutdown.
    pub fn record_source_stats(&mut self, dropped_partial_lines: usize, parse_errors: usize) {
        self.source_dropped_partial_lines = dropped_partial_lines;
        self.source_parse_errors = parse_errors;
    }

    /// Record the event source's cumulative binary-frame counters —
    /// resyncs across chunk boundaries and frames lost to mid-buffer
    /// rotation (surfaced in [`LiveMetrics::source_frame_resyncs`] /
    /// [`LiveMetrics::source_dropped_frames`]). The driver loop calls
    /// this with [`crate::live::source::EventSource::frame_resyncs`] and
    /// [`crate::live::source::EventSource::dropped_frames`], mirroring
    /// `record_source_stats` for NDJSON loss.
    pub fn record_source_wire_stats(&mut self, frame_resyncs: usize, dropped_frames: usize) {
        self.source_frame_resyncs = frame_resyncs;
        self.source_dropped_frames = dropped_frames;
    }

    fn drain_results(&mut self) {
        while let Ok(msg) = self.results_rx.try_recv() {
            self.absorb(msg);
        }
    }

    fn absorb(&mut self, msg: LiveMsg) {
        match msg {
            LiveMsg::Stage { job_id, incarnation, seq, features, analysis } => {
                // Second verdict pass against the baseline *before* this
                // stage joins it (no self-comparison), then fold.
                let _g = obs::span(SpanKind::RegistryFold);
                let flags = self.registry.fleet_verdict(&features, &analysis);
                self.registry.fold_stage(&features, &analysis);
                self.collected
                    .entry((job_id, incarnation))
                    .or_default()
                    .push((seq, features, analysis, flags));
            }
            LiveMsg::Evicted { job_id, incarnation, ended, incomplete, live, flight } => {
                let mut rows =
                    self.collected.remove(&(job_id, incarnation)).unwrap_or_default();
                rows.sort_by_key(|(seq, _, _, _)| *seq);
                let mut per_stage = Vec::with_capacity(rows.len());
                let mut fleet_flags = Vec::new();
                for (_, sf, a, flags) in rows {
                    per_stage.push((sf, a));
                    fleet_flags.extend(flags);
                }
                // One fleet snapshot for everything derived at retirement:
                // provenance traces, the counterfactual verdict, and the
                // baselines a flight dump freezes for replay.
                let (whatif_report, traces, baselines) = if per_stage.is_empty() {
                    (None, Vec::new(), Vec::new())
                } else {
                    let fleet = self.registry.report();
                    // Verdict provenance per stage, derived against the
                    // baseline as of this moment; the confidence scores
                    // fold back into the registry's per-cause aggregates.
                    let traces: Vec<VerdictTrace> = per_stage
                        .iter()
                        .map(|(sf, a)| explain_stage(sf, a, &fleet.baselines))
                        .collect();
                    self.registry.fold_traces(&traces);
                    // Counterfactual verdict against the same baseline;
                    // its savings feed back into the registry so the
                    // fleet report ranks causes by total time lost.
                    let r = whatif::analyze_job(
                        &format!("job-{job_id}"),
                        &per_stage,
                        Some(&fleet),
                        &self.cfg.whatif,
                    );
                    self.registry.fold_whatif(&r);
                    (Some(r), traces, fleet.baselines)
                };
                // Features drop here; only the analyses stay resident.
                let analyses: Vec<StageAnalysis> =
                    per_stage.into_iter().map(|(_, a)| a).collect();
                if ended {
                    self.registry.job_completed();
                }
                self.jobs_completed += 1;
                if live {
                    self.evictions_live += 1;
                }
                self.completed.push(CompletedJob {
                    job_id,
                    incarnation,
                    ended,
                    evicted_live: live,
                    analyses,
                    fleet_flags,
                    whatif: whatif_report,
                    traces,
                    baselines,
                    flight,
                    incomplete,
                });
            }
        }
    }

    /// Current health snapshot.
    pub fn metrics(&self) -> LiveMetrics {
        let elapsed = self.started.elapsed().as_secs_f64();
        let per_shard: Vec<LiveShardMetrics> = self
            .stats
            .iter()
            .enumerate()
            .map(|(i, s)| LiveShardMetrics {
                shard: i,
                events: s.events.load(Ordering::Relaxed),
                stages: s.stages.load(Ordering::Relaxed),
                resident: s.resident.load(Ordering::Relaxed),
                resident_high: s.resident_high.load(Ordering::Relaxed),
                evicted: s.evicted.load(Ordering::Relaxed),
                cache_hits: s.cache_hits.load(Ordering::Relaxed),
                cache_misses: s.cache_misses.load(Ordering::Relaxed),
            })
            .collect();
        LiveMetrics {
            events_total: self.events_total,
            jobs_completed: self.jobs_completed,
            evictions_live: self.evictions_live,
            stages_analyzed: per_shard.iter().map(|s| s.stages).sum(),
            resident_high_water: per_shard.iter().map(|s| s.resident_high).sum(),
            resident_now: per_shard.iter().map(|s| s.resident).sum(),
            events_dropped: self
                .stats
                .iter()
                .map(|s| s.dropped.load(Ordering::Relaxed))
                .sum(),
            dropped_partial_lines: self.source_dropped_partial_lines,
            source_parse_errors: self.source_parse_errors,
            source_frame_resyncs: self.source_frame_resyncs,
            source_dropped_frames: self.source_dropped_frames,
            cache_hits: per_shard.iter().map(|s| s.cache_hits).sum(),
            cache_misses: per_shard.iter().map(|s| s.cache_misses).sum(),
            cache_evictions: self.shared_cache.evictions() as usize,
            per_shard,
            elapsed_secs: elapsed,
            events_per_sec: if elapsed > 0.0 {
                self.events_total as f64 / elapsed
            } else {
                0.0
            },
        }
    }

    /// End of stream: flush the ingest buffers, retire every resident
    /// job, wait for the shard workers, and assemble the report.
    pub fn finish(self) -> LiveReport {
        self.finish_with_registry().0
    }

    /// [`LiveServer::finish`], additionally handing back the final
    /// [`FleetRegistry`] so the caller can persist it
    /// ([`crate::live::persist::save_snapshot`]) — the drain-then-snapshot
    /// shutdown path of `bigroots serve`.
    pub fn finish_with_registry(mut self) -> (LiveReport, FleetRegistry) {
        self.flush_pending();
        // Dropping the queue senders closes the shards' input; each
        // worker drains its queue, retires its jobs and exits.
        self.senders.clear();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // All result senders are gone now — drain to disconnect.
        while let Ok(msg) = self.results_rx.recv() {
            self.absorb(msg);
        }
        let metrics = self.metrics();
        let mut jobs = std::mem::take(&mut self.completed);
        jobs.sort_by_key(|j| (j.job_id, j.incarnation));
        let registry = self.registry.clone();
        (LiveReport { jobs, fleet: self.registry.report(), metrics }, registry)
    }
}

/// How long a shard worker waits on its queue before running a lifecycle
/// scan on its own ([`crate::util::queue::BoundedReceiver::pop_timeout`]).
/// Jobs that drain right before the stream goes quiet retire within one
/// tick even if the driver never pumps. Wall-clock ticks cannot change
/// analysis results — eviction is event-time gated (see
/// [`crate::live::lifecycle`]) and the scan is idempotent.
const WORKER_TICK: std::time::Duration = std::time::Duration::from_millis(25);

/// One shard's worker loop: demux → lifecycle → analyze → report. The
/// shard's backend memoizes through the *shared* striped cache —
/// repeated stage shapes skip the stats kernel even when another shard
/// computed them — and routes large stages to the XLA-capable backend
/// when routing is enabled. Hit/miss counters (this worker's lookups)
/// publish to [`ShardStats`] after every ingest batch so snapshots stay
/// live. Drained batch buffers go back to the router through `pool_tx`
/// (the free-list; sends after the router is gone are ignored).
#[allow(clippy::too_many_arguments)]
fn shard_worker(
    shard: usize,
    rx: crate::util::queue::BoundedReceiver<EventBatch>,
    pool_tx: Sender<EventBatch>,
    tx: Sender<LiveMsg>,
    stats: Arc<ShardStats>,
    bigroots: BigRootsConfig,
    lifecycle_cfg: LifecycleConfig,
    cache: Arc<SharedStatsCache>,
    route_large_tasks: usize,
    flight_capacity: usize,
) {
    // Built inside the worker thread, so the large-stage backend never has
    // to cross a thread boundary.
    let inner: Box<dyn StatsBackend + Send> = if route_large_tasks > 0 {
        Box::new(RoutingBackend::new(
            NativeBackend::new(),
            crate::analysis::router::auto_large_backend(),
            route_large_tasks,
        ))
    } else {
        Box::new(NativeBackend::new())
    };
    let mut backend = SharedCachedBackend::new(inner, cache);
    let mut lc = Lifecycle::new(lifecycle_cfg, bigroots.edge_width);
    // Per-shard flight recorder: every event passes through it, and the
    // moment a stage verdict flags stragglers the job's recent window is
    // frozen for bit-identical replay. Single-threaded with the shard, so
    // recording never contends.
    let mut flight = FlightRecorder::new(flight_capacity);
    let analyze_and_send =
        |job_id: u64,
         incarnation: u32,
         ready: Vec<crate::coordinator::streaming::ReadyStage>,
         backend: &mut SharedCachedBackend<Box<dyn StatsBackend + Send>>,
         stats: &ShardStats,
         tx: &Sender<LiveMsg>,
         flight: &mut FlightRecorder,
         kernel_secs: &mut f64| {
            for r in ready {
                let t0 = obs::enabled().then(Instant::now);
                let st = backend.stage_stats(&r.features);
                if let Some(t0) = t0 {
                    let d = t0.elapsed();
                    obs::record(SpanKind::StatsKernel, d);
                    *kernel_secs += d.as_secs_f64();
                }
                let analysis = analyze_stage_with_stats(&r.features, &st, &bigroots);
                if !analysis.stragglers.rows.is_empty() {
                    // A straggler verdict fired: pin this job's raw-event
                    // window before the ring can evict it.
                    flight.freeze(job_id);
                }
                stats.stages.fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(LiveMsg::Stage {
                    job_id,
                    incarnation,
                    seq: r.seq,
                    features: r.features,
                    analysis,
                });
            }
        };
    let publish = |backend: &SharedCachedBackend<Box<dyn StatsBackend + Send>>,
                   lc: &Lifecycle,
                   stats: &ShardStats| {
        stats.resident.store(lc.resident(), Ordering::Relaxed);
        stats.resident_high.store(lc.resident_high(), Ordering::Relaxed);
        stats.evicted.store(lc.evicted_total(), Ordering::Relaxed);
        stats.dropped.store(lc.dropped(), Ordering::Relaxed);
        // Lock-free: counters() would sum evictions across every stripe
        // of the shared cache, and this publish runs per batch/idle tick.
        let (hits, misses) = backend.lookup_counts();
        stats.cache_hits.store(hits as usize, Ordering::Relaxed);
        stats.cache_misses.store(misses as usize, Ordering::Relaxed);
    };
    loop {
        // Time the bounded wait so queue-idle shows up as dequeue wait in
        // the span histograms and in this shard's self-analysis samples.
        let wait_t0 = obs::enabled().then(Instant::now);
        let batch = match rx.pop_timeout(WORKER_TICK) {
            PopTimeout::Item(b) => Some(b),
            // Self-tick: nothing arrived for a whole tick. Run the
            // eviction scan below so a job that drained with the last
            // events to arrive retires without waiting for the driver's
            // pump (or for more traffic).
            PopTimeout::TimedOut => None,
            PopTimeout::Closed => break,
        };
        let queue_wait = wait_t0.map(|t| t.elapsed()).unwrap_or_default();
        let is_tick = batch.as_ref().map(|b| b.is_empty()).unwrap_or(true);
        if is_tick {
            // A timeout, or an explicit empty batch from
            // `LiveServer::pump`: run the eviction scan. Not a real batch
            // — no dequeue-wait span, no telemetry sample.
            lc.force_scan();
            let mut kernel = 0.0;
            for e in lc.take_evictions() {
                analyze_and_send(
                    e.job_id,
                    e.incarnation,
                    e.flushed,
                    &mut backend,
                    &stats,
                    &tx,
                    &mut flight,
                    &mut kernel,
                );
                let window = flight.take(e.job_id);
                let _ = tx.send(LiveMsg::Evicted {
                    job_id: e.job_id,
                    incarnation: e.incarnation,
                    ended: e.ended,
                    incomplete: e.incomplete,
                    live: true,
                    flight: window,
                });
            }
            publish(&backend, &lc, &stats);
            if let Some(b) = batch {
                let _ = pool_tx.send(b);
            }
            continue;
        }
        let mut batch = batch.unwrap();
        obs::record(SpanKind::DequeueWait, queue_wait);
        let batch_t0 = wait_t0.map(|_| Instant::now());
        let batch_start =
            if batch_t0.is_some() { obs::global().uptime_secs() } else { 0.0 };
        let misses_before =
            if batch_t0.is_some() { backend.lookup_counts().1 } else { 0 };
        let n_events = batch.len();
        // One counter bump per batch, not per event.
        stats.events.fetch_add(n_events, Ordering::Relaxed);
        let mut kernel = 0.0;
        for ev in batch.iter() {
            let job_id = ev.job_id;
            // Recorded before analysis so a verdict triggered by this very
            // event freezes a window that includes it.
            flight.record(&ev);
            if let Some((incarnation, ready)) = lc.feed(&ev) {
                if !ready.is_empty() {
                    analyze_and_send(
                        job_id,
                        incarnation,
                        ready,
                        &mut backend,
                        &stats,
                        &tx,
                        &mut flight,
                        &mut kernel,
                    );
                }
            }
            for e in lc.take_evictions() {
                analyze_and_send(
                    e.job_id,
                    e.incarnation,
                    e.flushed,
                    &mut backend,
                    &stats,
                    &tx,
                    &mut flight,
                    &mut kernel,
                );
                let window = flight.take(e.job_id);
                let _ = tx.send(LiveMsg::Evicted {
                    job_id: e.job_id,
                    incarnation: e.incarnation,
                    ended: e.ended,
                    incomplete: e.incomplete,
                    live: true,
                    flight: window,
                });
            }
        }
        // Drained: recycle the buffer back to the router's free-list.
        batch.clear();
        let _ = pool_tx.send(batch);
        publish(&backend, &lc, &stats);
        if let Some(t0) = batch_t0 {
            let miss_delta = backend.lookup_counts().1.saturating_sub(misses_before);
            crate::obs::telemetry().record(crate::obs::BatchSample {
                shard,
                start: batch_start,
                duration: t0.elapsed().as_secs_f64(),
                queue_wait: queue_wait.as_secs_f64(),
                kernel,
                events: n_events,
                cache_misses: miss_delta,
            });
        }
    }
    // Input closed: retire everything still resident.
    let mut kernel = 0.0;
    for e in lc.drain_all() {
        analyze_and_send(
            e.job_id,
            e.incarnation,
            e.flushed,
            &mut backend,
            &stats,
            &tx,
            &mut flight,
            &mut kernel,
        );
        let window = flight.take(e.job_id);
        let _ = tx.send(LiveMsg::Evicted {
            job_id: e.job_id,
            incarnation: e.incarnation,
            ended: e.ended,
            incomplete: e.incomplete,
            live: false,
            flight: window,
        });
    }
    publish(&backend, &lc, &stats);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Pipeline;
    use crate::sim::multi::{interleaved_workload, round_robin_specs};

    fn run_live(events: &[TaggedEvent], cfg: LiveConfig) -> LiveReport {
        let mut server = LiveServer::new(cfg);
        server.feed_all(events);
        server.finish()
    }

    #[test]
    fn interleaved_jobs_match_batch_bit_for_bit() {
        let specs = round_robin_specs(4, 0.12, 909);
        let (traces, events) = interleaved_workload(&specs);
        let report = run_live(
            &events,
            LiveConfig { shards: 3, ingest_batch: 16, ..Default::default() },
        );
        assert_eq!(report.jobs.len(), 4);
        for (job_id, trace) in &traces {
            let got = report.job(*job_id).expect("job retired");
            assert!(got.ended);
            assert!(got.incomplete.is_empty());
            let mut p = Pipeline::native();
            let want = p.analyze(trace, "live");
            assert_eq!(got.analyses.len(), want.per_stage.len());
            for (g, (_, w)) in got.analyses.iter().zip(&want.per_stage) {
                assert_eq!(g, w, "job {job_id} stage {}", g.stage_id);
            }
        }
        assert_eq!(report.metrics.events_total, events.len());
        assert_eq!(report.metrics.stages_analyzed, report.total_stages());
        assert_eq!(report.fleet.stages, report.total_stages());
        assert_eq!(report.fleet.jobs_completed, 4);
    }

    #[test]
    fn shard_count_does_not_change_results() {
        let specs = round_robin_specs(5, 0.1, 333);
        let (_, events) = interleaved_workload(&specs);
        let base = run_live(&events, LiveConfig { shards: 1, ..Default::default() });
        for shards in [2usize, 4, 8] {
            let other = run_live(
                &events,
                LiveConfig { shards, ingest_batch: 5, ..Default::default() },
            );
            assert_eq!(base.jobs.len(), other.jobs.len());
            for (a, b) in base.jobs.iter().zip(&other.jobs) {
                assert_eq!(a.job_id, b.job_id);
                assert_eq!(a.analyses, b.analyses, "shards={shards}");
            }
        }
    }

    #[test]
    fn batched_feed_all_matches_per_event_feed() {
        // The run-length demux and the EventBatch round-trip must be
        // invisible: feeding a slice and feeding event-by-event produce
        // the same jobs, analyses and fleet report.
        let specs = round_robin_specs(4, 0.12, 717);
        let (_, events) = interleaved_workload(&specs);
        let cfg = LiveConfig { shards: 3, ingest_batch: 7, ..Default::default() };
        let per_event = {
            let mut s = LiveServer::new(cfg.clone());
            for e in &events {
                s.feed(e.clone());
            }
            s.finish()
        };
        let batched = run_live(&events, cfg);
        assert_eq!(per_event.jobs.len(), batched.jobs.len());
        for (a, b) in per_event.jobs.iter().zip(&batched.jobs) {
            assert_eq!(a.job_id, b.job_id);
            assert_eq!(a.analyses, b.analyses);
        }
        assert_eq!(per_event.fleet, batched.fleet);
    }

    #[test]
    fn worker_self_ticks_retire_drained_jobs_without_pump() {
        // Jobs whose final events have reached the workers must retire on
        // the workers' own pop_timeout ticks — no `pump()` call, no
        // further traffic. ingest_batch=1 so nothing lingers in the
        // router's pending buffers.
        let specs = round_robin_specs(2, 0.1, 808);
        let (_, events) = interleaved_workload(&specs);
        let mut server = LiveServer::new(LiveConfig {
            shards: 2,
            ingest_batch: 1,
            ..Default::default()
        });
        server.feed_all(&events);
        let deadline = Instant::now() + std::time::Duration::from_secs(10);
        let mut done = Vec::new();
        while done.len() < 2 && Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(5));
            done.extend(server.drain_completed());
        }
        assert_eq!(done.len(), 2, "drained jobs retire via worker self-ticks");
        let report = server.finish();
        assert!(report.jobs.is_empty(), "nothing left for shutdown to flush");
    }

    #[test]
    fn drain_completed_hands_jobs_over_once() {
        let specs = round_robin_specs(3, 0.1, 555);
        let (_, events) = interleaved_workload(&specs);
        let mut server = LiveServer::new(LiveConfig {
            shards: 2,
            ingest_batch: 8,
            ..Default::default()
        });
        let mut drained = Vec::new();
        for e in &events {
            server.feed(e.clone());
            drained.extend(server.drain_completed());
        }
        server.pump();
        let report = server.finish();
        let total = drained.len() + report.jobs.len();
        assert_eq!(total, 3, "every job retires exactly once");
    }

    #[test]
    fn repeated_tenants_hit_the_shard_caches() {
        // One spec repeated under many job ids: identical stage matrices.
        let mut specs = round_robin_specs(1, 0.12, 77);
        let base = specs.remove(0);
        let specs: Vec<_> = (0..4u64)
            .map(|i| crate::sim::multi::MultiJobSpec { job_id: i, ..base.clone() })
            .collect();
        let (_, events) = interleaved_workload(&specs);
        let report = run_live(
            &events,
            LiveConfig { shards: 1, ..Default::default() },
        );
        let m = &report.metrics;
        assert_eq!(
            m.cache_hits + m.cache_misses,
            m.stages_analyzed,
            "every analyzed stage is one lookup"
        );
        assert!(
            m.cache_hits * 2 >= m.stages_analyzed,
            "repeated shapes should mostly hit: {} / {}",
            m.cache_hits,
            m.stages_analyzed
        );
        // And the repeated jobs' analyses are bit-identical.
        let first = &report.job(0).unwrap().analyses;
        for id in 1..4u64 {
            assert_eq!(&report.job(id).unwrap().analyses, first);
        }
    }

    #[test]
    fn fleet_registry_accumulates_across_jobs() {
        let specs = round_robin_specs(6, 0.1, 202);
        let (_, events) = interleaved_workload(&specs);
        let report = run_live(
            &events,
            LiveConfig { fleet_min_samples: 8, ..Default::default() },
        );
        assert_eq!(report.fleet.jobs_completed, 6);
        assert!(report.fleet.tasks > 0);
        assert!(report.fleet.straggler_rate() >= 0.0);
        // The incidence counters agree exactly with the per-job analyses.
        let want_causes: usize = report
            .jobs
            .iter()
            .flat_map(|j| j.analyses.iter())
            .map(|a| a.causes.len())
            .sum();
        let got_causes: usize =
            report.fleet.cause_incidence.iter().map(|(_, n)| n).sum();
        assert_eq!(got_causes, want_causes);
        let want_stragglers: usize = report.total_stragglers();
        assert_eq!(report.fleet.straggler_tasks, want_stragglers);
    }

    #[test]
    fn retired_jobs_carry_traces_and_frozen_windows() {
        let specs = round_robin_specs(3, 0.12, 606);
        let (_, events) = interleaved_workload(&specs);
        let report = run_live(&events, LiveConfig::default());
        assert_eq!(report.jobs.len(), 3);
        let mut saw_window = false;
        for job in &report.jobs {
            // One provenance trace per analyzed stage, same order.
            assert_eq!(job.traces.len(), job.analyses.len());
            for (t, a) in job.traces.iter().zip(&job.analyses) {
                assert_eq!(t.stage_id, a.stage_id);
                assert_eq!(t.causes.len(), a.causes.len());
                assert_eq!(t.flagged.len(), a.stragglers.rows.len());
                for c in &t.causes {
                    assert!((0.0..=1.0).contains(&c.confidence));
                }
            }
            assert_eq!(job.baselines.len(), crate::analysis::FeatureKind::COUNT);
            let has_stragglers =
                job.analyses.iter().any(|a| !a.stragglers.rows.is_empty());
            // A window is frozen exactly when some stage verdict flagged
            // stragglers.
            assert_eq!(job.flight.is_some(), has_stragglers);
            if let Some(w) = &job.flight {
                assert_eq!(w.job_id, job.job_id);
                assert!(w.complete(), "default capacity holds the whole job");
                assert!(w.events.iter().all(|e| e.job_id == job.job_id));
                saw_window = true;
            }
        }
        assert!(saw_window, "workload produced no straggler verdicts");
        // The registry's confidence aggregates saw every cause trace.
        let want: usize = report
            .jobs
            .iter()
            .flat_map(|j| j.traces.iter())
            .map(|t| t.causes.len())
            .sum();
        let got: usize = report.fleet.baselines.iter().map(|b| b.verdicts).sum();
        assert_eq!(got, want);
    }

    #[test]
    fn retired_jobs_carry_a_whatif_verdict() {
        let specs = round_robin_specs(3, 0.12, 404);
        let (_, events) = interleaved_workload(&specs);
        let report = run_live(&events, LiveConfig::default());
        let mut fleet_total = 0.0;
        for job in &report.jobs {
            let w = job.whatif.as_ref().expect("analyzed job has a what-if verdict");
            assert!(w.baseline_secs > 0.0);
            for r in &w.rows {
                assert!(r.saved_secs >= 0.0);
            }
            // Ranked descending.
            for pair in w.rows.windows(2) {
                assert!(pair[0].saved_secs >= pair[1].saved_secs);
            }
            fleet_total += w.rows.iter().map(|r| r.saved_secs).sum::<f64>();
        }
        // The registry accumulated exactly the per-job savings.
        let got: f64 = report.fleet.estimated_savings.iter().map(|(_, s)| s).sum();
        assert!((got - fleet_total).abs() < 1e-6, "{got} vs {fleet_total}");
    }
}
