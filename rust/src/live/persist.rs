//! Durable fleet-baseline snapshots — what makes the registry survive a
//! server restart.
//!
//! The [`FleetRegistry`] is exactly the state a long-running deployment
//! cannot afford to lose: P² sketch markers accumulate over *every job
//! ever seen*, and the paper's fleet verdicts are only as good as that
//! history. This module serializes the full registry — sketch marker
//! state, incidence counters, job/stage/task counts — to a **versioned
//! JSON document** and restores it bit-exactly:
//!
//! - every `f64` is encoded as its 16-hex-digit IEEE-754 bit pattern, so
//!   the round trip is *bit-identical* (no decimal shortest-repr detours,
//!   no `±inf` corner cases — a fresh sketch's `min = +inf` survives);
//! - writes are **atomic**: the document lands in `<path>.tmp` first and
//!   is renamed over the target, so a crash mid-write leaves the previous
//!   snapshot intact;
//! - the document carries a `kind` marker and a `version`; decode rejects
//!   anything it does not understand instead of guessing.
//!
//! `LiveServer::restore_registry` + `bigroots serve --snapshot-path`
//! complete the loop: restore on boot, write on cadence and on shutdown.
//! `rust/tests/live_integration.rs` proves a restored server's final
//! [`FleetReport`](crate::live::registry::FleetReport) is identical to an
//! uninterrupted run.

use crate::analysis::features::FeatureKind;
use crate::live::registry::{FeatureBaseline, FleetRegistry, QuantileSketch};
use crate::util::json::Json;
use crate::util::stats::{P2Quantile, Welford};

/// Current snapshot document version. Bump on any layout change.
/// v2 added the per-cause `whatif_saved` accumulator; v3 added the
/// per-cause `confidence` Welford aggregates from the verdict provenance
/// traces. Older documents are still accepted and restore with the
/// missing accumulators zeroed.
pub const SNAPSHOT_VERSION: u64 = 3;

/// Oldest document version this build can still restore.
pub const SNAPSHOT_MIN_VERSION: u64 = 1;

/// Document kind marker, so a stray JSON file is rejected early.
pub const SNAPSHOT_KIND: &str = "bigroots-fleet-snapshot";

// ---------------------------------------------------------------------------
// Bit-exact f64 codec

fn fbits(x: f64) -> Json {
    Json::Str(format!("{:016x}", x.to_bits()))
}

fn fbits_arr(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| fbits(x)).collect())
}

fn read_fbits(j: &Json, what: &str) -> Result<f64, String> {
    let s = j
        .as_str()
        .ok_or_else(|| format!("{what}: expected a hex f64-bits string"))?;
    let bits =
        u64::from_str_radix(s, 16).map_err(|e| format!("{what}: bad hex '{s}' ({e})"))?;
    Ok(f64::from_bits(bits))
}

fn read_fbits5(j: &Json, what: &str) -> Result<[f64; 5], String> {
    let arr = j.as_arr().ok_or_else(|| format!("{what}: expected an array"))?;
    if arr.len() != 5 {
        return Err(format!("{what}: expected 5 elements, got {}", arr.len()));
    }
    let mut out = [0.0; 5];
    for (i, v) in arr.iter().enumerate() {
        out[i] = read_fbits(v, what)?;
    }
    Ok(out)
}

fn read_fbits_vec(j: &Json, want: usize, what: &str) -> Result<Vec<f64>, String> {
    let arr = j.as_arr().ok_or_else(|| format!("{what}: expected an array"))?;
    if arr.len() != want {
        return Err(format!("{what}: expected {want} elements, got {}", arr.len()));
    }
    arr.iter().map(|v| read_fbits(v, what)).collect()
}

// Counters travel as decimal *strings*, not JSON numbers: `Json::Num` is
// an f64, which silently rounds integers past 2^53 — a fleet-lifetime
// task counter can get there, and this codec's contract is exactness.

fn count_json(x: u64) -> Json {
    Json::Str(x.to_string())
}

fn read_count_u64(j: &Json, key: &str) -> Result<u64, String> {
    let s = j
        .get(key)
        .as_str()
        .ok_or_else(|| format!("field '{key}': expected a decimal-string counter"))?;
    s.parse::<u64>().map_err(|e| format!("field '{key}': bad counter '{s}' ({e})"))
}

fn read_count(j: &Json, key: &str) -> Result<usize, String> {
    Ok(read_count_u64(j, key)? as usize)
}

/// The `version` field stays a plain JSON number (it is tiny).
fn read_u64(j: &Json, key: &str) -> Result<u64, String> {
    j.get(key)
        .as_u64()
        .ok_or_else(|| format!("field '{key}': expected an unsigned integer"))
}

// ---------------------------------------------------------------------------
// Encoders

fn encode_welford(w: &Welford) -> Json {
    Json::from_pairs(vec![
        ("n", count_json(w.n)),
        ("mean", fbits(w.mean)),
        ("m2", fbits(w.m2)),
    ])
}

fn decode_welford(j: &Json) -> Result<Welford, String> {
    Ok(Welford {
        n: read_count_u64(j, "n")?,
        mean: read_fbits(j.get("mean"), "welford.mean")?,
        m2: read_fbits(j.get("m2"), "welford.m2")?,
    })
}

fn encode_p2(p2: &P2Quantile) -> Json {
    Json::from_pairs(vec![
        ("p", fbits(p2.p)),
        ("q", fbits_arr(&p2.q)),
        ("n", fbits_arr(&p2.n)),
        ("np", fbits_arr(&p2.np)),
        ("dn", fbits_arr(&p2.dn)),
        ("count", count_json(p2.count as u64)),
    ])
}

fn decode_p2(j: &Json) -> Result<P2Quantile, String> {
    Ok(P2Quantile {
        p: read_fbits(j.get("p"), "p2.p")?,
        q: read_fbits5(j.get("q"), "p2.q")?,
        n: read_fbits5(j.get("n"), "p2.n")?,
        np: read_fbits5(j.get("np"), "p2.np")?,
        dn: read_fbits5(j.get("dn"), "p2.dn")?,
        count: read_count(j, "count")?,
    })
}

fn encode_sketch(s: &QuantileSketch) -> Json {
    Json::from_pairs(vec![
        ("count", count_json(s.count as u64)),
        ("min", fbits(s.min)),
        ("max", fbits(s.max)),
        ("mean", encode_welford(&s.mean)),
        ("p50", encode_p2(&s.p50)),
        ("p90", encode_p2(&s.p90)),
        ("p95", encode_p2(&s.p95)),
    ])
}

fn decode_sketch(j: &Json) -> Result<QuantileSketch, String> {
    Ok(QuantileSketch {
        count: read_count(j, "count")?,
        min: read_fbits(j.get("min"), "sketch.min")?,
        max: read_fbits(j.get("max"), "sketch.max")?,
        mean: decode_welford(j.get("mean"))?,
        p50: decode_p2(j.get("p50"))?,
        p90: decode_p2(j.get("p90"))?,
        p95: decode_p2(j.get("p95"))?,
    })
}

/// Encode the full registry state as a versioned JSON document.
pub fn encode_registry(reg: &FleetRegistry) -> Json {
    let features: Vec<Json> = reg
        .features
        .iter()
        .map(|b| {
            Json::from_pairs(vec![
                ("kind", b.kind.name().into()),
                ("cause_count", count_json(b.cause_count as u64)),
                ("all", encode_sketch(&b.all)),
                ("stragglers", encode_sketch(&b.stragglers)),
            ])
        })
        .collect();
    let fleet = Json::from_pairs(vec![
        ("min_samples", count_json(reg.min_samples as u64)),
        ("jobs_completed", count_json(reg.jobs_completed as u64)),
        ("stages", count_json(reg.stages as u64)),
        ("tasks", count_json(reg.tasks as u64)),
        ("straggler_tasks", count_json(reg.straggler_tasks as u64)),
        ("shuffle_heavy", count_json(reg.shuffle_heavy as u64)),
        ("shuffle_heavy_gc", count_json(reg.shuffle_heavy_gc as u64)),
        ("stage_medians", encode_sketch(&reg.stage_medians)),
        ("features", Json::Arr(features)),
        ("whatif_saved", fbits_arr(&reg.whatif_saved)),
        ("confidence", Json::Arr(reg.confidence.iter().map(encode_welford).collect())),
    ]);
    Json::from_pairs(vec![
        ("kind", SNAPSHOT_KIND.into()),
        ("version", SNAPSHOT_VERSION.into()),
        ("fleet", fleet),
    ])
}

/// Decode a snapshot document back into a registry. Strict: the kind
/// marker, version, and the full feature set must match this build.
pub fn decode_registry(j: &Json) -> Result<FleetRegistry, String> {
    let kind = j
        .get("kind")
        .as_str()
        .ok_or_else(|| "missing 'kind' marker (not a fleet snapshot?)".to_string())?;
    if kind != SNAPSHOT_KIND {
        return Err(format!("unexpected document kind '{kind}' (want '{SNAPSHOT_KIND}')"));
    }
    let version = read_u64(j, "version")?;
    if !(SNAPSHOT_MIN_VERSION..=SNAPSHOT_VERSION).contains(&version) {
        return Err(format!(
            "snapshot version {version} not supported (this build reads \
             {SNAPSHOT_MIN_VERSION}..={SNAPSHOT_VERSION})"
        ));
    }
    let fleet = j.get("fleet");
    let feats = fleet
        .get("features")
        .as_arr()
        .ok_or_else(|| "field 'features': expected an array".to_string())?;
    if feats.len() != FeatureKind::COUNT {
        return Err(format!(
            "snapshot has {} feature baselines, this build has {}",
            feats.len(),
            FeatureKind::COUNT
        ));
    }
    let mut features: Vec<Option<FeatureBaseline>> =
        (0..FeatureKind::COUNT).map(|_| None).collect();
    for f in feats {
        let name = f
            .get("kind")
            .as_str()
            .ok_or_else(|| "feature 'kind': expected a string".to_string())?;
        let kind = FeatureKind::ALL
            .iter()
            .copied()
            .find(|k| k.name() == name)
            .ok_or_else(|| format!("unknown feature kind '{name}'"))?;
        let slot = &mut features[kind.index()];
        if slot.is_some() {
            return Err(format!("duplicate feature kind '{name}'"));
        }
        *slot = Some(FeatureBaseline {
            kind,
            all: decode_sketch(f.get("all"))?,
            stragglers: decode_sketch(f.get("stragglers"))?,
            cause_count: read_count(f, "cause_count")?,
        });
    }
    Ok(FleetRegistry {
        min_samples: read_count(fleet, "min_samples")?.max(1),
        jobs_completed: read_count(fleet, "jobs_completed")?,
        stages: read_count(fleet, "stages")?,
        tasks: read_count(fleet, "tasks")?,
        straggler_tasks: read_count(fleet, "straggler_tasks")?,
        features: features
            .into_iter()
            .map(|f| f.expect("every feature slot filled (checked above)"))
            .collect(),
        stage_medians: decode_sketch(fleet.get("stage_medians"))?,
        shuffle_heavy: read_count(fleet, "shuffle_heavy")?,
        shuffle_heavy_gc: read_count(fleet, "shuffle_heavy_gc")?,
        whatif_saved: if version >= 2 {
            read_fbits_vec(fleet.get("whatif_saved"), FeatureKind::COUNT, "whatif_saved")?
        } else {
            // v1 predates the what-if accumulator: restore with zeros.
            vec![0.0; FeatureKind::COUNT]
        },
        confidence: if version >= 3 {
            let arr = fleet
                .get("confidence")
                .as_arr()
                .ok_or_else(|| "field 'confidence': expected an array".to_string())?;
            if arr.len() != FeatureKind::COUNT {
                return Err(format!(
                    "field 'confidence': expected {} elements, got {}",
                    FeatureKind::COUNT,
                    arr.len()
                ));
            }
            arr.iter().map(decode_welford).collect::<Result<Vec<_>, _>>()?
        } else {
            // v1/v2 predate the provenance layer: restore with empty
            // (zeroed-but-valid) confidence aggregates.
            vec![Welford::new(); FeatureKind::COUNT]
        },
    })
}

/// Write a snapshot atomically: serialize to `<path>.tmp`, then rename
/// over `path`. A crash mid-write leaves the previous snapshot intact.
pub fn save_snapshot(reg: &FleetRegistry, path: &str) -> Result<(), String> {
    let tmp = format!("{path}.tmp");
    let doc = encode_registry(reg).to_pretty();
    std::fs::write(&tmp, doc).map_err(|e| format!("writing {tmp}: {e}"))?;
    std::fs::rename(&tmp, path).map_err(|e| format!("renaming {tmp} -> {path}: {e}"))
}

/// Load a snapshot written by [`save_snapshot`].
pub fn load_snapshot(path: &str) -> Result<FleetRegistry, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
    let j = Json::parse(&text).map_err(|e| format!("parsing {path}: {e}"))?;
    decode_registry(&j)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::bigroots::{analyze_stage, BigRootsConfig};
    use crate::analysis::features::extract_all;
    use crate::analysis::stats::NativeBackend;
    use crate::sim::{workloads, Engine, InjectionPlan, SimConfig};
    use crate::trace::AnomalyKind;

    fn folded_registry(jobs: usize) -> FleetRegistry {
        let cfg = BigRootsConfig::default();
        let mut backend = NativeBackend::new();
        let mut reg = FleetRegistry::new(8);
        for seed in 0..jobs as u64 {
            let w = workloads::wordcount(0.2);
            let mut eng = Engine::new(SimConfig { seed: 100 + seed, ..Default::default() });
            let plan = InjectionPlan::intermittent(AnomalyKind::Cpu, 1, 15.0, 10.0, 300.0);
            let t = eng.run("persist-test", w.name, &w.stages, &plan);
            for sf in extract_all(&t, cfg.edge_width) {
                let a = analyze_stage(&sf, &mut backend, &cfg);
                reg.fold_stage(&sf, &a);
            }
            reg.job_completed();
        }
        reg
    }

    fn tmp_path(name: &str) -> String {
        let dir = std::env::temp_dir();
        format!("{}/bigroots_{}_{}", dir.display(), std::process::id(), name)
    }

    #[test]
    fn roundtrip_is_bit_identical() {
        let reg = folded_registry(3);
        let doc = encode_registry(&reg);
        let restored = decode_registry(&doc).expect("decode");
        // The re-encoded document is byte-identical — no f64 drift.
        assert_eq!(doc.to_string(), encode_registry(&restored).to_string());
        // And the queryable report (quantiles, incidence, shares) matches
        // exactly.
        assert_eq!(reg.report(), restored.report());
    }

    #[test]
    fn fresh_registry_roundtrips_including_infinities() {
        // A fresh sketch holds min=+inf / max=-inf; the bit codec must
        // carry them (plain JSON numbers could not).
        let reg = FleetRegistry::new(64);
        let restored = decode_registry(&encode_registry(&reg)).expect("decode");
        assert_eq!(reg.report(), restored.report());
    }

    #[test]
    fn restored_registry_keeps_accumulating_identically() {
        // Fold a job, snapshot, then fold a second job into both the
        // original and the restored copy: they must stay in lockstep.
        let mut reg = folded_registry(1);
        let mut restored = decode_registry(&encode_registry(&reg)).expect("decode");
        let cfg = BigRootsConfig::default();
        let mut backend = NativeBackend::new();
        let w = workloads::wordcount(0.2);
        let mut eng = Engine::new(SimConfig { seed: 777, ..Default::default() });
        let t = eng.run("persist-cont", w.name, &w.stages, &InjectionPlan::none());
        for sf in extract_all(&t, cfg.edge_width) {
            let a = analyze_stage(&sf, &mut backend, &cfg);
            reg.fold_stage(&sf, &a);
            restored.fold_stage(&sf, &a);
        }
        reg.job_completed();
        restored.job_completed();
        assert_eq!(reg.report(), restored.report());
        assert_eq!(
            encode_registry(&reg).to_string(),
            encode_registry(&restored).to_string()
        );
    }

    #[test]
    fn whatif_savings_roundtrip_bit_exactly() {
        use crate::analysis::whatif::{CauseSavings, WhatIfReport};
        let mut reg = folded_registry(1);
        reg.fold_whatif(&WhatIfReport {
            job: "persist-whatif".into(),
            seed: 3,
            slots_per_node: 12,
            baseline_secs: 100.0,
            rows: vec![CauseSavings {
                kind: FeatureKind::Cpu,
                tasks_affected: 4,
                stages_affected: 2,
                counterfactual_secs: 87.5,
                saved_secs: 12.5,
                saved_frac: 0.125,
            }],
        });
        let restored = decode_registry(&encode_registry(&reg)).expect("decode");
        assert_eq!(reg.report(), restored.report());
        assert_eq!(restored.report().estimated_saving(FeatureKind::Cpu), 12.5);
    }

    /// Downgrade a current document to `version`, removing the fields that
    /// version predates.
    fn downgraded(doc: &Json, version: u64) -> Json {
        let mut doc = doc.clone();
        doc.set("version", version.into());
        let mut fleet = doc.get("fleet").clone();
        if let Json::Obj(m) = &mut fleet {
            if version < 3 {
                m.remove("confidence");
            }
            if version < 2 {
                m.remove("whatif_saved");
            }
        }
        doc.set("fleet", fleet);
        doc
    }

    #[test]
    fn v1_snapshot_restores_with_zeroed_savings() {
        let reg = folded_registry(1);
        let restored = decode_registry(&downgraded(&encode_registry(&reg), 1)).expect("v1 decode");
        assert!(restored.report().estimated_savings.is_empty());
        // Everything else still matches the original.
        assert_eq!(reg.report(), restored.report());
    }

    #[test]
    fn v1_and_v2_fixtures_decode_with_zeroed_confidence_and_exact_legacy_fields() {
        use crate::analysis::explain::{CauseTrace, VerdictTrace};
        // A registry with non-zero state in EVERY accumulator, including
        // the v3 confidence Welfords.
        let mut reg = folded_registry(2);
        reg.fold_traces(&[VerdictTrace {
            stage_id: 0,
            duration_median: 1.0,
            duration_threshold: 1.5,
            flagged: vec![7],
            causes: vec![CauseTrace {
                row: 0,
                task_id: 7,
                kind: FeatureKind::Cpu,
                value: 0.9,
                threshold: 0.7,
                peer: "both",
                stage_median: 0.4,
                stage_mad: 0.1,
                fleet_percentile: Some(0.97),
                confidence: 0.83,
                group: 0,
            }],
            groups: vec![vec![FeatureKind::Cpu]],
        }]);
        let v3 = encode_registry(&reg);
        for version in [1u64, 2u64] {
            let restored =
                decode_registry(&downgraded(&v3, version)).expect("legacy decode");
            // Confidence aggregates come back zeroed but valid.
            for b in &restored.report().baselines {
                assert_eq!(b.verdicts, 0, "v{version} {}", b.kind.name());
                assert_eq!(b.mean_confidence, 0.0, "v{version} {}", b.kind.name());
            }
            // Legacy fields are bit-exact: re-encoding the restored state
            // reproduces the current document except the accumulators the
            // fixture lacked.
            let reencoded = encode_registry(&restored);
            let strip = |d: &Json| {
                let mut d = downgraded(d, version);
                // Compare at a common version: drop what the fixture never had.
                d.set("version", SNAPSHOT_VERSION.into());
                d
            };
            assert_eq!(strip(&v3).to_string(), strip(&reencoded).to_string());
            if version < 2 {
                assert!(restored.report().estimated_savings.is_empty());
            }
        }
    }

    #[test]
    fn trace_confidence_roundtrips_bit_exactly() {
        use crate::analysis::explain::{CauseTrace, VerdictTrace};
        let mut reg = folded_registry(1);
        let mk = |kind: FeatureKind, confidence: f64| CauseTrace {
            row: 0,
            task_id: 1,
            kind,
            value: 1.0,
            threshold: 0.5,
            peer: "inter_node",
            stage_median: 0.2,
            stage_mad: 0.05,
            fleet_percentile: None,
            confidence,
            group: 0,
        };
        reg.fold_traces(&[VerdictTrace {
            stage_id: 2,
            duration_median: 3.0,
            duration_threshold: 4.5,
            flagged: vec![1],
            causes: vec![
                mk(FeatureKind::Cpu, 0.123456789),
                mk(FeatureKind::Cpu, 0.987654321),
                mk(FeatureKind::Network, 0.5),
            ],
            groups: vec![vec![FeatureKind::Cpu, FeatureKind::Network]],
        }]);
        let restored = decode_registry(&encode_registry(&reg)).expect("decode");
        assert_eq!(reg.report(), restored.report());
        assert_eq!(
            encode_registry(&reg).to_string(),
            encode_registry(&restored).to_string()
        );
        let cpu = restored
            .report()
            .baselines
            .iter()
            .find(|b| b.kind == FeatureKind::Cpu)
            .unwrap()
            .clone();
        assert_eq!(cpu.verdicts, 2);
    }

    #[test]
    fn save_and_load_through_a_file() {
        let reg = folded_registry(2);
        let path = tmp_path("fleet_snapshot.json");
        save_snapshot(&reg, &path).expect("save");
        let restored = load_snapshot(&path).expect("load");
        assert_eq!(reg.report(), restored.report());
        // The tmp file was renamed away.
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn decode_rejects_wrong_kind_version_and_corruption() {
        let reg = folded_registry(1);
        let good = encode_registry(&reg);

        let mut wrong_kind = good.clone();
        wrong_kind.set("kind", "something-else".into());
        assert!(decode_registry(&wrong_kind).unwrap_err().contains("kind"));

        let mut wrong_version = good.clone();
        wrong_version.set("version", 999u64.into());
        assert!(decode_registry(&wrong_version).unwrap_err().contains("version"));

        assert!(decode_registry(&Json::obj()).is_err());
        assert!(load_snapshot("/nonexistent/bigroots.snapshot").is_err());

        // Truncated feature list is rejected, not silently defaulted.
        let mut few = good.clone();
        let fleet = few.get("fleet").clone();
        let mut fleet = fleet;
        let feats = fleet.get("features").as_arr().unwrap().to_vec();
        fleet.set("features", Json::Arr(feats[..3].to_vec()));
        few.set("fleet", fleet);
        let err = decode_registry(&few).unwrap_err();
        assert!(err.contains("feature baselines"), "{err}");
    }
}
