//! Job lifecycle GC — what lets a shard hold an *unbounded* stream of
//! jobs in *bounded* memory.
//!
//! Each shard owns one [`Lifecycle`]: a map of resident
//! [`JobState`] accumulators plus the eviction policy that retires them.
//! A job is evicted when either
//!
//! - **drained**: its `JobEnd` arrived and every announced stage has been
//!   analyzed (the watermark released the last held stage) — nothing the
//!   job can still send would change any result, or
//! - **quiesced**: its `JobEnd` arrived and the job's own event-time
//!   watermark advanced `evict_after` seconds past the end time without
//!   draining (a truncated job that will never complete its stages) — the
//!   remaining held stages are force-flushed so the job still reports, or
//! - **orphaned**: no `JobEnd` ever came and the job received none of the
//!   shard's last `orphan_events` accepted events (its tenant crashed) —
//!   the fallback that keeps memory bounded even for jobs that never end.
//!
//! The quiescence window is floored at the analyzer's edge width: a
//! healthy job's trailing resource samples (the ones its last stages'
//! tail windows need) arrive within `edge_width` seconds of `JobEnd`, so
//! eviction can never race the samples that bit-identical parity needs.
//!
//! **Revival**: each job id carries an incarnation counter. After
//! eviction, stray trailing events of the dead incarnation (resource
//! samples, late task ends) are dropped; only a fresh `JobStart` opens a
//! new incarnation, which is a completely fresh job — nothing of the old
//! state survives. The counter map is the only per-retired-job residue
//! (a dozen bytes per distinct job id ever seen).

use std::collections::HashMap;

use crate::coordinator::streaming::{JobState, ReadyStage};
use crate::trace::eventlog::{Event, TaggedEvent};

/// Eviction policy knobs.
#[derive(Debug, Clone)]
pub struct LifecycleConfig {
    /// Seconds of event-time quiescence after `JobEnd` before a
    /// non-drained job is force-flushed and evicted. Floored at the
    /// analyzer's edge width (see module docs).
    pub evict_after: f64,
    /// Run the eviction scan every this many events (the drain check is
    /// O(resident ended jobs)).
    pub scan_every: usize,
    /// Crashed-tenant fallback: force-flush and evict any job — `JobEnd`
    /// or not — that received none of the shard's last `orphan_events`
    /// accepted events. Counted in events rather than time so streams
    /// that restart the clock per job can't trip it. This is what keeps
    /// memory bounded when a tenant dies mid-job and its `JobEnd` never
    /// arrives. 0 disables.
    pub orphan_events: usize,
}

impl Default for LifecycleConfig {
    fn default() -> Self {
        LifecycleConfig { evict_after: 5.0, scan_every: 64, orphan_events: 100_000 }
    }
}

/// One resident job.
struct JobSlot {
    state: JobState,
    incarnation: u32,
    /// Max event time seen for this job (its private watermark — streams
    /// that restart the clock per job must not share one).
    watermark: f64,
    /// Shard event counter at this job's last accepted event (orphan GC).
    last_seen: u64,
}

/// A retired job, ready to report.
pub struct EvictedJob {
    pub job_id: u64,
    pub incarnation: u32,
    /// A `JobEnd` was seen (false only for end-of-stream drains).
    pub ended: bool,
    /// Stages force-flushed at eviction — analyze these before reporting.
    pub flushed: Vec<ReadyStage>,
    /// Announced stages that never completed.
    pub incomplete: Vec<u64>,
    /// Events this job consumed.
    pub events_seen: usize,
}

/// Per-shard job table + eviction policy. See module docs.
pub struct Lifecycle {
    cfg: LifecycleConfig,
    edge_width: f64,
    jobs: HashMap<u64, JobSlot>,
    /// Next incarnation per job id; presence marks "was evicted before".
    incarnations: HashMap<u64, u32>,
    /// Ids with `JobEnd` seen, pending eviction.
    ended: Vec<u64>,
    /// Accepted events, ever (drives the orphan-GC silence window).
    events_total: u64,
    events_since_scan: usize,
    evictions: Vec<EvictedJob>,
    resident_high: usize,
    evicted_total: usize,
    /// Stray post-eviction events dropped.
    dropped: usize,
}

impl Lifecycle {
    pub fn new(cfg: LifecycleConfig, edge_width: f64) -> Self {
        Lifecycle {
            cfg,
            edge_width,
            jobs: HashMap::new(),
            incarnations: HashMap::new(),
            ended: Vec::new(),
            events_total: 0,
            events_since_scan: 0,
            evictions: Vec::new(),
            resident_high: 0,
            evicted_total: 0,
            dropped: 0,
        }
    }

    /// Feed one event. Returns `(incarnation, ready stages)` when the
    /// event was accepted, `None` when it was a stray trailing event of an
    /// evicted incarnation.
    pub fn feed(&mut self, ev: &TaggedEvent) -> Option<(u32, Vec<ReadyStage>)> {
        let job_id = ev.job_id;
        // A fresh `JobStart` for a resident-but-*ended* job is a revival
        // racing the eviction scan: retire the old incarnation right now
        // so the new job starts clean regardless of scan cadence. (A
        // `JobStart` for a job that has NOT ended is a tenant-side id
        // collision and keeps the merge semantics of the batch service.)
        if matches!(ev.event, Event::JobStart { .. })
            && self.jobs.get(&job_id).map_or(false, |s| s.state.ended)
        {
            self.evict(job_id);
            self.ended.retain(|id| *id != job_id);
        }
        if !self.jobs.contains_key(&job_id) {
            // Previously-evicted id: only a fresh JobStart revives it.
            let was_evicted = self.incarnations.contains_key(&job_id);
            if was_evicted && !matches!(ev.event, Event::JobStart { .. }) {
                self.dropped += 1;
                return None;
            }
            let incarnation = self.incarnations.get(&job_id).copied().unwrap_or(0);
            self.jobs.insert(
                job_id,
                JobSlot {
                    state: JobState::new_deferred(self.edge_width),
                    incarnation,
                    watermark: f64::NEG_INFINITY,
                    last_seen: 0,
                },
            );
            self.resident_high = self.resident_high.max(self.jobs.len());
        }
        self.events_total += 1;
        let events_total = self.events_total;
        let slot = self.jobs.get_mut(&job_id).unwrap();
        slot.last_seen = events_total;
        if let Some(t) = ev.event.time() {
            slot.watermark = slot.watermark.max(t);
        }
        let ready = slot.state.feed(&ev.event);
        let incarnation = slot.incarnation;
        if matches!(ev.event, Event::JobEnd { .. }) && !self.ended.contains(&job_id) {
            self.ended.push(job_id);
        }
        self.events_since_scan += 1;
        if self.events_since_scan >= self.cfg.scan_every.max(1) {
            self.events_since_scan = 0;
            self.scan();
        }
        Some((incarnation, ready))
    }

    /// Evict every ended job that is drained or quiesced, plus orphans.
    fn scan(&mut self) {
        let quiesce = self.cfg.evict_after.max(self.edge_width);
        let pending = std::mem::take(&mut self.ended);
        for job_id in pending {
            let evict = match self.jobs.get(&job_id) {
                None => false, // already gone (shouldn't happen)
                Some(slot) => {
                    let drained = slot.state.incomplete_stages().is_empty();
                    let end_t = slot.state.end_time.unwrap_or(slot.watermark);
                    drained || slot.watermark >= end_t + quiesce
                }
            };
            if evict {
                self.evict(job_id);
            } else {
                self.ended.push(job_id);
            }
        }
        // Orphan GC: any job silent for the shard's last `orphan_events`
        // accepted events is dead (its tenant crashed, or its stream was
        // cut) — force-flush and retire it, `JobEnd` or not.
        if self.cfg.orphan_events > 0 {
            let cutoff = self.events_total.saturating_sub(self.cfg.orphan_events as u64);
            if cutoff > 0 {
                let orphans: Vec<u64> = self
                    .jobs
                    .iter()
                    .filter(|(_, s)| s.last_seen <= cutoff)
                    .map(|(id, _)| *id)
                    .collect();
                for id in orphans {
                    self.ended.retain(|j| *j != id);
                    self.evict(id);
                }
            }
        }
    }

    /// Unconditionally retire one resident job.
    fn evict(&mut self, job_id: u64) {
        let Some(mut slot) = self.jobs.remove(&job_id) else { return };
        let flushed = slot.state.flush();
        let incomplete = slot.state.incomplete_stages();
        self.incarnations.insert(job_id, slot.incarnation + 1);
        self.evicted_total += 1;
        self.evictions.push(EvictedJob {
            job_id,
            incarnation: slot.incarnation,
            ended: slot.state.ended,
            flushed,
            incomplete,
            events_seen: slot.state.events_seen,
        });
    }

    /// Run the eviction scan now, regardless of the event cadence. A job
    /// whose *last* stage drained in the stream's final few events would
    /// otherwise sit resident until the next event arrives — on an idle
    /// persistent source, that is never. The live server sends an idle
    /// tick through each shard queue so `serve --listen` retires drained
    /// jobs promptly.
    pub fn force_scan(&mut self) {
        self.events_since_scan = 0;
        self.scan();
    }

    /// Take the evictions recorded since the last call.
    pub fn take_evictions(&mut self) -> Vec<EvictedJob> {
        std::mem::take(&mut self.evictions)
    }

    /// End of stream: retire every resident job, in job-id order.
    pub fn drain_all(&mut self) -> Vec<EvictedJob> {
        let mut ids: Vec<u64> = self.jobs.keys().copied().collect();
        ids.sort_unstable();
        for id in ids {
            self.evict(id);
        }
        self.ended.clear();
        self.take_evictions()
    }

    /// Currently resident jobs.
    pub fn resident(&self) -> usize {
        self.jobs.len()
    }

    /// Is this job id currently resident?
    pub fn is_resident(&self, job_id: u64) -> bool {
        self.jobs.contains_key(&job_id)
    }

    /// High-water mark of resident jobs.
    pub fn resident_high(&self) -> usize {
        self.resident_high
    }

    /// Jobs evicted so far (including end-of-stream drains).
    pub fn evicted_total(&self) -> usize {
        self.evicted_total
    }

    /// Stray post-eviction events dropped.
    pub fn dropped(&self) -> usize {
        self.dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{workloads, Engine, InjectionPlan, SimConfig};
    use crate::trace::eventlog::interleave_jobs;
    use crate::trace::JobTrace;

    fn trace(seed: u64) -> JobTrace {
        let w = workloads::wordcount(0.2);
        let mut eng = Engine::new(SimConfig { seed, ..Default::default() });
        eng.run("lc-test", w.name, &w.stages, &InjectionPlan::none())
    }

    fn feed_all(lc: &mut Lifecycle, events: &[crate::trace::eventlog::TaggedEvent]) -> usize {
        let mut ready = 0;
        for e in events {
            if let Some((_, r)) = lc.feed(e) {
                ready += r.len();
            }
        }
        ready
    }

    #[test]
    fn complete_job_evicts_after_drain() {
        let t = trace(1);
        let events = interleave_jobs(&[(7, &t)]);
        let mut lc = Lifecycle::new(
            LifecycleConfig { evict_after: 1.0, scan_every: 8, ..Default::default() },
            3.0,
        );
        let ready = feed_all(&mut lc, &events);
        assert_eq!(ready, t.stages.len(), "all stages released by the watermark");
        // Trailing samples extend ~10s past JobEnd, so the drain rule has
        // fired within the stream.
        let evictions = lc.take_evictions();
        assert_eq!(evictions.len(), 1);
        assert_eq!(evictions[0].job_id, 7);
        assert_eq!(evictions[0].incarnation, 0);
        assert!(evictions[0].ended);
        assert!(evictions[0].flushed.is_empty());
        assert!(evictions[0].incomplete.is_empty());
        assert_eq!(lc.resident(), 0);
        assert_eq!(lc.evicted_total(), 1);
    }

    #[test]
    fn stray_samples_after_eviction_are_dropped() {
        let t = trace(2);
        let events = interleave_jobs(&[(3, &t)]);
        let mut lc = Lifecycle::new(
            LifecycleConfig { evict_after: 0.5, scan_every: 4, ..Default::default() },
            3.0,
        );
        // Feed everything except the last few trailing samples.
        let cut = events.len() - 3;
        feed_all(&mut lc, &events[..cut]);
        if lc.resident() > 0 {
            // Force the eviction point before the strays.
            lc.drain_all();
        } else {
            lc.take_evictions();
        }
        let before = lc.dropped();
        feed_all(&mut lc, &events[cut..]);
        assert_eq!(lc.resident(), 0, "strays must not resurrect the job");
        assert!(lc.dropped() >= before + 3);
    }

    #[test]
    fn revived_job_id_is_a_fresh_incarnation() {
        let a = trace(3);
        let b = trace(4);
        let mut stream = interleave_jobs(&[(9, &a)]);
        stream.extend(interleave_jobs(&[(9, &b)]));
        let mut lc = Lifecycle::new(
            LifecycleConfig { evict_after: 1.0, scan_every: 4, ..Default::default() },
            3.0,
        );
        let ready = feed_all(&mut lc, &stream);
        let mut evictions = lc.take_evictions();
        evictions.extend(lc.drain_all());
        assert_eq!(evictions.len(), 2);
        assert_eq!(evictions[0].incarnation, 0);
        assert_eq!(evictions[1].incarnation, 1);
        assert_eq!(ready, a.stages.len() + b.stages.len());
        // Each incarnation consumed at most its own stream (strays of the
        // first may be dropped between eviction and the revival).
        assert!(evictions[1].events_seen <= interleave_jobs(&[(9, &b)]).len());
    }

    #[test]
    fn truncated_job_quiesces_out() {
        let t = trace(5);
        let full = interleave_jobs(&[(1, &t)]);
        // Drop every TaskEnd so no stage ever completes, keeping JobEnd
        // and the trailing samples that advance the watermark past it.
        let events: Vec<_> = full
            .iter()
            .filter(|e| !matches!(e.event, Event::TaskEnd(_)))
            .cloned()
            .collect();
        let mut lc = Lifecycle::new(
            LifecycleConfig { evict_after: 2.0, scan_every: 4, ..Default::default() },
            3.0,
        );
        feed_all(&mut lc, &events);
        let evictions = lc.take_evictions();
        assert_eq!(evictions.len(), 1, "quiescence rule must fire inside the stream");
        assert!(evictions[0].ended);
        assert!(!evictions[0].incomplete.is_empty());
        assert_eq!(lc.resident(), 0);
    }

    #[test]
    fn orphaned_job_without_jobend_is_garbage_collected() {
        // Job 1's tenant crashes mid-job (stream cut, no JobEnd); job 2's
        // traffic keeps flowing on the same shard. The orphan fallback
        // must retire job 1 while the stream is still live.
        let a = trace(6);
        let b = trace(7);
        let a_events = interleave_jobs(&[(1, &a)]);
        let cut = a_events.len() / 2;
        let mut lc = Lifecycle::new(
            LifecycleConfig { evict_after: 1.0, scan_every: 8, orphan_events: 64 },
            3.0,
        );
        feed_all(&mut lc, &a_events[..cut]);
        assert_eq!(lc.resident(), 1);
        feed_all(&mut lc, &interleave_jobs(&[(2, &b)]));
        let evictions = lc.take_evictions();
        assert!(
            evictions.iter().any(|e| e.job_id == 1 && !e.ended),
            "crashed job must be orphan-GC'd mid-stream"
        );
        assert!(!lc.is_resident(1));
    }

    #[test]
    fn jobstart_for_resident_ended_job_revives_immediately() {
        // Revival must not depend on the scan cadence: a JobStart arriving
        // while the ended predecessor is still resident retires it on the
        // spot instead of merging the two jobs' state.
        let a = trace(8);
        let b = trace(9);
        let mut stream = interleave_jobs(&[(4, &a)]);
        stream.extend(interleave_jobs(&[(4, &b)]));
        // A scan interval far larger than either stream: the scan-based
        // eviction can never fire between the two jobs.
        let mut lc = Lifecycle::new(
            LifecycleConfig {
                evict_after: 1.0,
                scan_every: 1_000_000,
                orphan_events: 0,
            },
            3.0,
        );
        let ready = feed_all(&mut lc, &stream);
        let mut evictions = lc.take_evictions();
        evictions.extend(lc.drain_all());
        assert_eq!(evictions.len(), 2);
        assert_eq!(evictions[0].incarnation, 0);
        assert!(evictions[0].ended);
        assert_eq!(evictions[1].incarnation, 1);
        assert_eq!(ready, a.stages.len() + b.stages.len());
    }

    #[test]
    fn sequential_jobs_stay_bounded() {
        let mut stream = Vec::new();
        let mut stage_total = 0;
        for i in 0..6u64 {
            let t = trace(10 + i);
            stage_total += t.stages.len();
            stream.extend(interleave_jobs(&[(i, &t)]));
        }
        let mut lc = Lifecycle::new(
            LifecycleConfig { evict_after: 1.0, scan_every: 8, ..Default::default() },
            3.0,
        );
        let ready = feed_all(&mut lc, &stream);
        let mut evictions = lc.take_evictions();
        evictions.extend(lc.drain_all());
        assert_eq!(evictions.len(), 6);
        assert_eq!(ready, stage_total);
        assert!(
            lc.resident_high() <= 2,
            "resident high-water {} on a sequential stream",
            lc.resident_high()
        );
    }
}
