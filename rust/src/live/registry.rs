//! The cross-job fleet baseline registry.
//!
//! Every per-job analysis the paper (and the PR-2 service) produces
//! compares a straggler against *its own stage's* peers — the stage median
//! is the whole universe. HybridTune-style diagnosis sharpens that by
//! asking the fleet: is this value unusual *for this cluster*, across all
//! jobs and tenants ever seen? [`FleetRegistry`] is the persistent store
//! that makes the question answerable on unbounded streams:
//!
//! - per-feature **streaming quantile sketches** ([`QuantileSketch`], P²
//!   markers — O(1) memory per feature, no samples retained) over every
//!   task value and, separately, over straggler values only;
//! - per-root-cause **incidence counters** (how often each feature kind
//!   explains a straggler, fleet-wide), plus the shuffle-heavy × GC
//!   cross-tab behind the canonical query *"what fraction of
//!   shuffle-heavy stragglers are GC-dominated?"*;
//! - a **second verdict pass** ([`FleetRegistry::fleet_verdict`]): after
//!   the per-stage rules ran, flag straggler features that clear the fleet
//!   P95 even though their own stage's peer tests stayed quiet — the
//!   fleet-anomalous-but-locally-camouflaged case (e.g. a whole stage
//!   running on a degraded node, where every peer is equally slow).
//!
//! Folds are commutative counters and sketches, so the registry tolerates
//! the nondeterministic cross-shard arrival order of the live server; the
//! sketch estimates (not the counters) may differ across runs at the P²
//! approximation level.

use crate::analysis::bigroots::StageAnalysis;
use crate::analysis::explain::VerdictTrace;
use crate::analysis::features::{FeatureCategory, FeatureKind, StageFeatures};
use crate::analysis::whatif::WhatIfReport;
use crate::util::stats::{median, P2Quantile, Welford};
use crate::util::table::{fnum, pct, Align, Table};

/// Streaming distribution summary: count/min/max/mean exactly, p50/p90/p95
/// via P² markers. Constant memory. Fields are crate-visible so
/// [`crate::live::persist`] can round-trip the sketch bit-exactly.
#[derive(Debug, Clone)]
pub struct QuantileSketch {
    pub(crate) count: usize,
    pub(crate) min: f64,
    pub(crate) max: f64,
    pub(crate) mean: Welford,
    pub(crate) p50: P2Quantile,
    pub(crate) p90: P2Quantile,
    pub(crate) p95: P2Quantile,
}

impl QuantileSketch {
    pub fn new() -> Self {
        QuantileSketch {
            count: 0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            mean: Welford::new(),
            p50: P2Quantile::new(0.5),
            p90: P2Quantile::new(0.9),
            p95: P2Quantile::new(0.95),
        }
    }

    pub fn push(&mut self, x: f64) {
        self.count += 1;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.mean.push(x);
        self.p50.push(x);
        self.p90.push(x);
        self.p95.push(x);
    }

    pub fn count(&self) -> usize {
        self.count
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.max }
    }

    pub fn mean(&self) -> f64 {
        self.mean.mean()
    }

    pub fn p50(&self) -> f64 {
        self.p50.value()
    }

    pub fn p90(&self) -> f64 {
        self.p90.value()
    }

    pub fn p95(&self) -> f64 {
        self.p95.value()
    }
}

impl Default for QuantileSketch {
    fn default() -> Self {
        Self::new()
    }
}

/// Fleet-wide distribution state for one feature.
#[derive(Debug, Clone)]
pub struct FeatureBaseline {
    pub kind: FeatureKind,
    /// Every task value seen fleet-wide.
    pub all: QuantileSketch,
    /// Straggler task values only.
    pub stragglers: QuantileSketch,
    /// Times this feature was identified as a root cause.
    pub cause_count: usize,
}

/// One fleet-baseline flag from the second verdict pass: the stage's own
/// peer rules stayed quiet on this (straggler, feature) pair, but the
/// value clears the fleet P95.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetFlag {
    pub row: usize,
    pub task_id: u64,
    pub kind: FeatureKind,
    pub value: f64,
    pub fleet_p95: f64,
}

/// Cross-job accumulator. See module docs. Fields are crate-visible so
/// [`crate::live::persist`] can snapshot and restore the full state.
#[derive(Debug, Clone)]
pub struct FleetRegistry {
    /// A baseline must hold at least this many observations before the
    /// fleet verdict pass trusts it (cold-start guard).
    pub(crate) min_samples: usize,
    pub(crate) jobs_completed: usize,
    pub(crate) stages: usize,
    pub(crate) tasks: usize,
    pub(crate) straggler_tasks: usize,
    pub(crate) features: Vec<FeatureBaseline>,
    /// Distribution of per-stage median task durations.
    pub(crate) stage_medians: QuantileSketch,
    /// Stragglers whose shuffle-read exceeded their stage median.
    pub(crate) shuffle_heavy: usize,
    /// …of those, how many had a JVM-GC root cause.
    pub(crate) shuffle_heavy_gc: usize,
    /// Cumulative what-if savings (seconds of estimated completion time
    /// that removing each cause would have bought), indexed by
    /// [`FeatureKind::index`]. Folded from per-job [`WhatIfReport`]s.
    pub(crate) whatif_saved: Vec<f64>,
    /// Running confidence distribution per cause kind, indexed by
    /// [`FeatureKind::index`] and folded from per-job verdict traces
    /// ([`FleetRegistry::fold_traces`]). `n` doubles as the fleet-wide
    /// verdict count behind `bigroots_verdicts_total{cause=…}`.
    pub(crate) confidence: Vec<Welford>,
}

impl FleetRegistry {
    pub fn new(min_samples: usize) -> Self {
        FleetRegistry {
            min_samples: min_samples.max(1),
            jobs_completed: 0,
            stages: 0,
            tasks: 0,
            straggler_tasks: 0,
            features: FeatureKind::ALL
                .iter()
                .map(|&kind| FeatureBaseline {
                    kind,
                    all: QuantileSketch::new(),
                    stragglers: QuantileSketch::new(),
                    cause_count: 0,
                })
                .collect(),
            stage_medians: QuantileSketch::new(),
            shuffle_heavy: 0,
            shuffle_heavy_gc: 0,
            whatif_saved: vec![0.0; FeatureKind::COUNT],
            confidence: vec![Welford::new(); FeatureKind::COUNT],
        }
    }

    /// Fold one completed stage into the fleet state.
    pub fn fold_stage(&mut self, sf: &StageFeatures, analysis: &StageAnalysis) {
        self.stages += 1;
        self.tasks += sf.num_tasks();
        self.straggler_tasks += analysis.stragglers.rows.len();
        self.stage_medians.push(analysis.stragglers.median);
        for baseline in &mut self.features {
            let col = sf.column(baseline.kind);
            for &v in &col {
                baseline.all.push(v);
            }
            for &row in &analysis.stragglers.rows {
                baseline.stragglers.push(col[row]);
            }
        }
        for cause in &analysis.causes {
            self.features[cause.kind.index()].cause_count += 1;
        }
        // Shuffle-heavy × GC cross-tab over this stage's stragglers.
        let shuffle_col = sf.column(FeatureKind::ShuffleReadBytes);
        let shuffle_median = median(&shuffle_col);
        for &row in &analysis.stragglers.rows {
            if shuffle_col[row] > shuffle_median && shuffle_col[row] > 0.0 {
                self.shuffle_heavy += 1;
                if analysis
                    .causes
                    .iter()
                    .any(|c| c.row == row && c.kind == FeatureKind::JvmGcTime)
                {
                    self.shuffle_heavy_gc += 1;
                }
            }
        }
    }

    /// Mark one job fully analyzed (lifecycle eviction or stream end).
    pub fn job_completed(&mut self) {
        self.jobs_completed += 1;
    }

    /// Fold one job's counterfactual verdict into the fleet accumulator:
    /// each cause's estimated seconds saved adds to its running total, so
    /// the fleet report can rank causes by *total estimated time lost*,
    /// not just incidence. Plain commutative sums — shard arrival order
    /// does not matter.
    pub fn fold_whatif(&mut self, report: &WhatIfReport) {
        for row in &report.rows {
            self.whatif_saved[row.kind.index()] += row.saved_secs;
        }
    }

    /// Fold one job's verdict provenance traces: each cause's confidence
    /// joins its kind's running distribution. Welford pushes commute up to
    /// f64 rounding, and the counts are exact — arrival order across
    /// shards does not change what the verdict counters report.
    pub fn fold_traces(&mut self, traces: &[VerdictTrace]) {
        for t in traces {
            for c in &t.causes {
                self.confidence[c.kind.index()].push(c.confidence);
            }
        }
    }

    /// Second verdict pass: straggler features that clear the fleet P95
    /// while the stage's own analysis did *not* list them as a cause.
    /// Discrete features (locality) have no meaningful fleet quantile and
    /// are skipped; baselines below `min_samples` observations are too
    /// cold to trust and stay silent.
    pub fn fleet_verdict(&self, sf: &StageFeatures, analysis: &StageAnalysis) -> Vec<FleetFlag> {
        let mut flags = Vec::new();
        for &row in &analysis.stragglers.rows {
            for baseline in &self.features {
                if baseline.kind.category() == FeatureCategory::Discrete {
                    continue;
                }
                if baseline.all.count() < self.min_samples {
                    continue;
                }
                let value = sf.get(row, baseline.kind);
                let p95 = baseline.all.p95();
                if value <= p95 {
                    continue;
                }
                let already =
                    analysis.causes.iter().any(|c| c.row == row && c.kind == baseline.kind);
                if already {
                    continue;
                }
                flags.push(FleetFlag {
                    row,
                    task_id: sf.task_ids[row],
                    kind: baseline.kind,
                    value,
                    fleet_p95: p95,
                });
            }
        }
        flags
    }

    /// Is this stage slow versus the fleet, not just internally skewed?
    /// Returns `(stage median, fleet p95 of stage medians)` when the
    /// stage's median task duration clears the fleet P95.
    pub fn stage_anomalous(&self, analysis: &StageAnalysis) -> Option<(f64, f64)> {
        if self.stage_medians.count() < self.min_samples {
            return None;
        }
        let p95 = self.stage_medians.p95();
        if analysis.stragglers.median > p95 {
            Some((analysis.stragglers.median, p95))
        } else {
            None
        }
    }

    pub fn stages_folded(&self) -> usize {
        self.stages
    }

    /// Point-in-time snapshot for printing and queries.
    pub fn report(&self) -> FleetReport {
        let mut cause_incidence: Vec<(FeatureKind, usize)> = self
            .features
            .iter()
            .filter(|b| b.cause_count > 0)
            .map(|b| (b.kind, b.cause_count))
            .collect();
        cause_incidence.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.index().cmp(&b.0.index())));
        let mut estimated_savings: Vec<(FeatureKind, f64)> = FeatureKind::ALL
            .iter()
            .map(|&k| (k, self.whatif_saved[k.index()]))
            .filter(|(_, s)| *s > 0.0)
            .collect();
        estimated_savings
            .sort_by(|a, b| b.1.total_cmp(&a.1).then_with(|| a.0.index().cmp(&b.0.index())));
        FleetReport {
            jobs_completed: self.jobs_completed,
            stages: self.stages,
            tasks: self.tasks,
            straggler_tasks: self.straggler_tasks,
            cause_incidence,
            baselines: self
                .features
                .iter()
                .map(|b| FeatureSnapshot {
                    kind: b.kind,
                    count: b.all.count(),
                    p50: b.all.p50(),
                    p95: b.all.p95(),
                    straggler_p50: b.stragglers.p50(),
                    cause_count: b.cause_count,
                    mean_confidence: self.confidence[b.kind.index()].mean(),
                    verdicts: self.confidence[b.kind.index()].count() as usize,
                })
                .collect(),
            stage_median_p50: self.stage_medians.p50(),
            stage_median_p95: self.stage_medians.p95(),
            shuffle_heavy: self.shuffle_heavy,
            shuffle_heavy_gc: self.shuffle_heavy_gc,
            estimated_savings,
        }
    }
}

impl Default for FleetRegistry {
    /// 64-observation cold-start guard before fleet verdicts fire.
    fn default() -> Self {
        Self::new(64)
    }
}

/// Per-feature slice of a [`FleetReport`]. `PartialEq` backs the
/// restart-parity tests: a restored registry's report must equal the
/// uninterrupted run's bit for bit.
#[derive(Debug, Clone, PartialEq)]
pub struct FeatureSnapshot {
    pub kind: FeatureKind,
    pub count: usize,
    pub p50: f64,
    pub p95: f64,
    pub straggler_p50: f64,
    pub cause_count: usize,
    /// Mean verdict-trace confidence for this cause kind (0 when never
    /// implicated).
    pub mean_confidence: f64,
    /// Fleet-wide count of cause verdicts folded for this kind.
    pub verdicts: usize,
}

/// Queryable point-in-time snapshot of the fleet baseline.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetReport {
    pub jobs_completed: usize,
    pub stages: usize,
    pub tasks: usize,
    pub straggler_tasks: usize,
    /// (feature, cause count), most frequent first.
    pub cause_incidence: Vec<(FeatureKind, usize)>,
    pub baselines: Vec<FeatureSnapshot>,
    pub stage_median_p50: f64,
    pub stage_median_p95: f64,
    pub shuffle_heavy: usize,
    pub shuffle_heavy_gc: usize,
    /// (feature, cumulative estimated completion-time saved in seconds)
    /// from the per-job what-if verdicts, largest saving first. Empty
    /// until the first what-if report is folded.
    pub estimated_savings: Vec<(FeatureKind, f64)>,
}

impl FleetReport {
    /// Fleet-wide straggler rate (straggler tasks / all tasks).
    pub fn straggler_rate(&self) -> f64 {
        if self.tasks == 0 {
            0.0
        } else {
            self.straggler_tasks as f64 / self.tasks as f64
        }
    }

    /// The canonical query: of stragglers whose shuffle-read exceeded
    /// their stage median, what fraction carried a JVM-GC root cause?
    pub fn shuffle_heavy_gc_fraction(&self) -> f64 {
        if self.shuffle_heavy == 0 {
            0.0
        } else {
            self.shuffle_heavy_gc as f64 / self.shuffle_heavy as f64
        }
    }

    /// Cumulative estimated completion-time saved (s) for one cause kind,
    /// from the folded what-if verdicts; 0 when never implicated.
    pub fn estimated_saving(&self, kind: FeatureKind) -> f64 {
        self.estimated_savings
            .iter()
            .find(|(k, _)| *k == kind)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    }

    /// Fraction of all identified root causes attributed to `kind`.
    pub fn cause_fraction(&self, kind: FeatureKind) -> f64 {
        let total: usize = self.cause_incidence.iter().map(|(_, n)| n).sum();
        if total == 0 {
            return 0.0;
        }
        let mine = self
            .cause_incidence
            .iter()
            .find(|(k, _)| *k == kind)
            .map(|(_, n)| *n)
            .unwrap_or(0);
        mine as f64 / total as f64
    }

    /// Render the snapshot as printable tables.
    pub fn render(&self) -> String {
        let mut out = format!(
            "fleet baseline: {} jobs, {} stages, {} tasks, {} stragglers ({}), \
             stage-median p50 {}s / p95 {}s\n",
            self.jobs_completed,
            self.stages,
            self.tasks,
            self.straggler_tasks,
            pct(self.straggler_rate()),
            fnum(self.stage_median_p50, 2),
            fnum(self.stage_median_p95, 2),
        );
        if self.shuffle_heavy > 0 {
            out.push_str(&format!(
                "shuffle-heavy stragglers: {} — GC-dominated: {} ({})\n",
                self.shuffle_heavy,
                self.shuffle_heavy_gc,
                pct(self.shuffle_heavy_gc_fraction()),
            ));
        }
        if !self.cause_incidence.is_empty() {
            let mut t = Table::new("Fleet root-cause incidence")
                .header(&["feature", "causes", "share", "est. saved s"])
                .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right]);
            for (kind, n) in &self.cause_incidence {
                t.row(vec![
                    kind.name().to_string(),
                    n.to_string(),
                    pct(self.cause_fraction(*kind)),
                    fnum(self.estimated_saving(*kind), 2),
                ]);
            }
            out.push_str(&t.render());
        }
        let mut t = Table::new("Fleet feature baselines (all tasks)")
            .header(&["feature", "n", "p50", "p95", "straggler p50"])
            .aligns(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
        for b in &self.baselines {
            if b.count == 0 {
                continue;
            }
            t.row(vec![
                b.kind.name().to_string(),
                b.count.to_string(),
                fnum(b.p50, 3),
                fnum(b.p95, 3),
                fnum(b.straggler_p50, 3),
            ]);
        }
        out.push_str(&t.render());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::bigroots::{analyze_stage, BigRootsConfig};
    use crate::analysis::features::extract_all;
    use crate::analysis::stats::NativeBackend;
    use crate::sim::{workloads, Engine, InjectionPlan, SimConfig};
    use crate::trace::{AnomalyKind, JobTrace};

    fn trace(seed: u64, inject: bool) -> JobTrace {
        let w = workloads::wordcount(0.25);
        let mut eng = Engine::new(SimConfig { seed, ..Default::default() });
        let plan = if inject {
            InjectionPlan::intermittent(AnomalyKind::Cpu, 1, 15.0, 10.0, 300.0)
        } else {
            InjectionPlan::none()
        };
        eng.run("fleet-test", w.name, &w.stages, &plan)
    }

    fn fold_trace(reg: &mut FleetRegistry, t: &JobTrace) {
        let cfg = BigRootsConfig::default();
        let mut backend = NativeBackend::new();
        for sf in extract_all(t, cfg.edge_width) {
            let a = analyze_stage(&sf, &mut backend, &cfg);
            reg.fold_stage(&sf, &a);
        }
        reg.job_completed();
    }

    #[test]
    fn fold_counts_are_exact() {
        let t = trace(11, true);
        let mut reg = FleetRegistry::new(8);
        fold_trace(&mut reg, &t);
        let r = reg.report();
        assert_eq!(r.jobs_completed, 1);
        assert_eq!(r.stages, t.stages.len());
        assert_eq!(r.tasks, t.tasks.len());
        // Every feature baseline saw exactly one value per task.
        for b in &r.baselines {
            assert_eq!(b.count, t.tasks.len(), "{}", b.kind.name());
        }
        assert!(r.straggler_rate() >= 0.0 && r.straggler_rate() <= 1.0);
    }

    #[test]
    fn cause_incidence_matches_analyses() {
        let t = trace(12, true);
        let cfg = BigRootsConfig::default();
        let mut backend = NativeBackend::new();
        let mut reg = FleetRegistry::new(8);
        let mut want_total = 0usize;
        for sf in extract_all(&t, cfg.edge_width) {
            let a = analyze_stage(&sf, &mut backend, &cfg);
            want_total += a.causes.len();
            reg.fold_stage(&sf, &a);
        }
        let r = reg.report();
        let got_total: usize = r.cause_incidence.iter().map(|(_, n)| n).sum();
        assert_eq!(got_total, want_total);
        // Fractions sum to 1 when any causes exist.
        if want_total > 0 {
            let sum: f64 =
                FeatureKind::ALL.iter().map(|&k| r.cause_fraction(k)).sum();
            assert!((sum - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn fleet_verdict_flags_outlier_against_warm_baseline() {
        // Warm the registry on clean jobs, then ask for a verdict on an
        // analysis whose straggler has an absurd feature value: the fleet
        // pass must flag it even where the per-stage rules stayed quiet.
        let mut reg = FleetRegistry::new(8);
        for seed in 0..4 {
            fold_trace(&mut reg, &trace(20 + seed, false));
        }
        let t = trace(30, false);
        let cfg = BigRootsConfig::default();
        let mut backend = NativeBackend::new();
        let mut sf_list = extract_all(&t, cfg.edge_width);
        let sf = &mut sf_list[0];
        let a = {
            let mut a = analyze_stage(sf, &mut backend, &cfg);
            if a.stragglers.rows.is_empty() {
                // Force one straggler row so the verdict pass has a target.
                a.stragglers.rows.push(0);
            }
            a
        };
        let row = a.stragglers.rows[0];
        // Blow up the straggler's bytes_read far past any fleet value.
        let idx = row * FeatureKind::COUNT + FeatureKind::BytesRead.index();
        sf.matrix[idx] = 1e15;
        let flags = reg.fleet_verdict(sf, &a);
        assert!(
            flags.iter().any(|f| f.row == row && f.kind == FeatureKind::BytesRead),
            "expected a bytes_read fleet flag, got {flags:?}"
        );
        for f in &flags {
            assert!(f.value > f.fleet_p95);
        }
    }

    #[test]
    fn cold_registry_stays_silent() {
        let t = trace(40, true);
        let cfg = BigRootsConfig::default();
        let mut backend = NativeBackend::new();
        let reg = FleetRegistry::new(1_000_000);
        for sf in extract_all(&t, cfg.edge_width) {
            let a = analyze_stage(&sf, &mut backend, &cfg);
            assert!(reg.fleet_verdict(&sf, &a).is_empty());
            assert!(reg.stage_anomalous(&a).is_none());
        }
    }

    #[test]
    fn sketch_tracks_distribution() {
        let mut s = QuantileSketch::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.min(), 0.0);
        for i in 0..1000 {
            s.push(i as f64);
        }
        assert_eq!(s.count(), 1000);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 999.0);
        assert!((s.mean() - 499.5).abs() < 1e-9);
        assert!((s.p50() - 499.5).abs() < 25.0);
        assert!((s.p95() - 949.0).abs() < 25.0);
    }

    #[test]
    fn whatif_savings_accumulate_and_rank() {
        use crate::analysis::whatif::{CauseSavings, WhatIfReport};
        let mut reg = FleetRegistry::new(8);
        let mk = |kind: FeatureKind, saved: f64| CauseSavings {
            kind,
            tasks_affected: 1,
            stages_affected: 1,
            counterfactual_secs: 10.0 - saved,
            saved_secs: saved,
            saved_frac: saved / 10.0,
        };
        reg.fold_whatif(&WhatIfReport {
            job: "a".into(),
            seed: 1,
            slots_per_node: 12,
            baseline_secs: 10.0,
            rows: vec![mk(FeatureKind::JvmGcTime, 2.0), mk(FeatureKind::Cpu, 3.0)],
        });
        reg.fold_whatif(&WhatIfReport {
            job: "b".into(),
            seed: 1,
            slots_per_node: 12,
            baseline_secs: 10.0,
            rows: vec![mk(FeatureKind::Cpu, 4.0)],
        });
        let r = reg.report();
        assert_eq!(
            r.estimated_savings,
            vec![(FeatureKind::Cpu, 7.0), (FeatureKind::JvmGcTime, 2.0)]
        );
        assert_eq!(r.estimated_saving(FeatureKind::Cpu), 7.0);
        assert_eq!(r.estimated_saving(FeatureKind::Locality), 0.0);
    }

    #[test]
    fn trace_confidence_folds_into_baselines() {
        use crate::analysis::explain::{CauseTrace, VerdictTrace};
        let mut reg = FleetRegistry::new(8);
        let mk = |kind: FeatureKind, confidence: f64| CauseTrace {
            row: 0,
            task_id: 0,
            kind,
            value: 1.0,
            threshold: 0.5,
            peer: "both",
            stage_median: 0.2,
            stage_mad: 0.1,
            fleet_percentile: None,
            confidence,
            group: 0,
        };
        reg.fold_traces(&[VerdictTrace {
            stage_id: 0,
            duration_median: 1.0,
            duration_threshold: 1.5,
            flagged: vec![0],
            causes: vec![mk(FeatureKind::Cpu, 0.8), mk(FeatureKind::JvmGcTime, 0.4)],
            groups: vec![vec![FeatureKind::Cpu, FeatureKind::JvmGcTime]],
        }]);
        reg.fold_traces(&[VerdictTrace {
            stage_id: 1,
            duration_median: 1.0,
            duration_threshold: 1.5,
            flagged: vec![0],
            causes: vec![mk(FeatureKind::Cpu, 0.6)],
            groups: vec![vec![FeatureKind::Cpu]],
        }]);
        let r = reg.report();
        let cpu = r.baselines.iter().find(|b| b.kind == FeatureKind::Cpu).unwrap();
        assert_eq!(cpu.verdicts, 2);
        assert!((cpu.mean_confidence - 0.7).abs() < 1e-12);
        let gc = r.baselines.iter().find(|b| b.kind == FeatureKind::JvmGcTime).unwrap();
        assert_eq!(gc.verdicts, 1);
        assert_eq!(gc.mean_confidence, 0.4);
        let disk = r.baselines.iter().find(|b| b.kind == FeatureKind::Disk).unwrap();
        assert_eq!(disk.verdicts, 0);
        assert_eq!(disk.mean_confidence, 0.0);
    }

    #[test]
    fn render_snapshot_is_printable() {
        let mut reg = FleetRegistry::new(8);
        fold_trace(&mut reg, &trace(50, true));
        let text = reg.report().render();
        assert!(text.contains("fleet baseline"));
        assert!(text.contains("Fleet feature baselines"));
    }
}
